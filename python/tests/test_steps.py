"""L2 step-program tests: MeZO semantics, Adam semantics, determinism.

These test the exact functions that get lowered to HLO artifacts, so green
here + green kernel tests means the artifacts compute the right thing.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model, steps
from compile.kernels import ref, rng

_rng = np.random.default_rng(2)

CFG = model.CONFIGS["pocket-tiny-fast"]


def batch(cfg=CFG, n=4):
    ids = _rng.integers(0, cfg.vocab, (n, cfg.max_seq)).astype(np.int32)
    mask = np.ones((n, cfg.max_seq), np.float32)
    if cfg.kind == "encoder":
        labels = _rng.integers(0, cfg.n_classes, (n,)).astype(np.int32)
    else:
        labels = ids
    return ids, mask, labels


def scal(x, dt=jnp.float32):
    return jnp.asarray([x], dt)


class TestMezoStep:
    def test_matches_manual_spsa(self):
        """mezo_step == hand-computed perturb/eval/flip/eval/update."""
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        seed, lr, eps = 11, 1e-2, 1e-3
        out = steps.mezo_step(CFG, params, ids, mask, labels,
                              scal(seed, jnp.uint32), scal(lr), scal(eps))
        new_params, loss = out[:-1], out[-1]

        specs = model.param_specs(CFG)
        s32 = jnp.uint32(seed)
        wp = [ref.mezo_perturb(w, s32, sp.offset, eps)
              for w, sp in zip(params, specs)]
        lp = float(model.loss_fn(CFG, wp, ids, mask, labels))
        wm = [ref.mezo_perturb(w, s32, sp.offset, -2 * eps)
              for w, sp in zip(wp, specs)]
        lm = float(model.loss_fn(CFG, wm, ids, mask, labels))
        g = (lp - lm) / (2 * eps)
        want = [ref.mezo_update(
                    ref.mezo_perturb(w, s32, sp.offset, eps),  # restore
                    s32, sp.offset, lr, g)
                for w, sp in zip(wm, specs)]
        assert abs(float(loss) - 0.5 * (lp + lm)) < 1e-5
        for a, b in zip(new_params, want):
            assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                            atol=1e-5)

    def test_deterministic(self):
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        a = steps.mezo_step(CFG, params, ids, mask, labels,
                            scal(5, jnp.uint32), scal(1e-3), scal(1e-3))
        b = steps.mezo_step(CFG, params, ids, mask, labels,
                            scal(5, jnp.uint32), scal(1e-3), scal(1e-3))
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    def test_zero_lr_restores_params(self):
        """lr=0 must leave parameters exactly where they started — the
        perturb/flip/restore cycle is lossless (to fp32 roundoff)."""
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        out = steps.mezo_step(CFG, params, ids, mask, labels,
                              scal(7, jnp.uint32), scal(0.0), scal(1e-3))
        for a, w in zip(out[:-1], params):
            assert_allclose(np.asarray(a), w, atol=2e-6)

    def test_descends_on_average(self):
        """Over many steps MeZO must reduce the training loss on a fixed
        batch — Fig. 1's 'slightly but steadily' claim, in miniature."""
        params = [jnp.asarray(w) for w in model.init_params(CFG)]
        ids, mask, labels = batch(n=8)
        first = None
        for step in range(40):
            out = steps.mezo_step(CFG, params, ids, mask, labels,
                                  scal(1000 + step, jnp.uint32),
                                  scal(5e-4), scal(1e-3))
            params, loss = list(out[:-1]), float(out[-1])
            if first is None:
                first = loss
        assert loss < first

    def test_different_seed_different_step(self):
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        a = steps.mezo_step(CFG, params, ids, mask, labels,
                            scal(1, jnp.uint32), scal(1e-2), scal(1e-3))
        b = steps.mezo_step(CFG, params, ids, mask, labels,
                            scal(2, jnp.uint32), scal(1e-2), scal(1e-3))
        assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))


class TestMezoMultiQuery:
    def test_q1_differs_from_plain_only_by_seed_derivation(self):
        """mezo_step_multi(k=1) is plain SPSA with a derived seed — it
        must move the params and report a chance-level loss."""
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        out = steps.mezo_step_multi(CFG, params, ids, mask, labels,
                                    scal(5, jnp.uint32), scal(1e-3),
                                    scal(1e-3), 1)
        assert abs(float(out[-1]) - 0.6931) < 0.05
        assert float(jnp.abs(out[0] - params[0]).max()) > 0

    def test_zero_lr_restores_for_any_k(self):
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        for k in [1, 2, 3]:
            out = steps.mezo_step_multi(CFG, params, ids, mask, labels,
                                        scal(7, jnp.uint32), scal(0.0),
                                        scal(1e-3), k)
            for a, w in zip(out[:-1], params):
                assert_allclose(np.asarray(a), w, atol=5e-6)

    def test_queries_use_distinct_seeds(self):
        """k=2 must not be 2x the k=1 update (distinct z per query)."""
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        one = steps.mezo_step_multi(CFG, params, ids, mask, labels,
                                    scal(5, jnp.uint32), scal(1e-2),
                                    scal(1e-3), 1)
        two = steps.mezo_step_multi(CFG, params, ids, mask, labels,
                                    scal(5, jnp.uint32), scal(1e-2),
                                    scal(1e-3), 2)
        d1 = np.asarray(one[0]) - np.asarray(params[0])
        d2 = np.asarray(two[0]) - np.asarray(params[0])
        # directions differ (not colinear)
        cos = float((d1 * d2).sum()
                    / (np.linalg.norm(d1) * np.linalg.norm(d2) + 1e-12))
        assert cos < 0.99, cos

    def test_variance_reduction_on_quadratic_proxy(self):
        """Averaged SPSA has lower estimator variance: over repeated
        seeds, k=4 updates scatter less than k=1 updates."""
        params = model.init_params(CFG)
        ids, mask, labels = batch()

        def update_norm(seed, k):
            out = steps.mezo_step_multi(CFG, params, ids, mask, labels,
                                        scal(seed, jnp.uint32),
                                        scal(1e-2), scal(1e-3), k)
            return float(jnp.abs(out[0] - params[0]).max())

        n1 = [update_norm(s, 1) for s in range(20, 28)]
        n4 = [update_norm(s, 4) for s in range(20, 28)]
        assert np.std(n4) < np.std(n1) * 1.2  # averaged => no larger


class TestAdamStep:
    def test_loss_matches_forward(self):
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        m = [np.zeros_like(w) for w in params]
        v = [np.zeros_like(w) for w in params]
        out = steps.adam_step(CFG, params, m, v, ids, mask, labels,
                              scal(1.0), scal(1e-3))
        want = float(model.loss_fn(CFG, params, ids, mask, labels))
        assert abs(float(out[-1]) - want) < 1e-5

    def test_descends_fast(self):
        """Adam's descent on a fixed batch should be much steeper than
        MeZO's — the Fig. 1 contrast."""
        params = [jnp.asarray(w) for w in model.init_params(CFG)]
        m = [jnp.zeros_like(w) for w in params]
        v = [jnp.zeros_like(w) for w in params]
        ids, mask, labels = batch(n=8)
        n = len(params)
        losses = []
        for step in range(10):
            out = steps.adam_step(CFG, params, m, v, ids, mask, labels,
                                  scal(float(step + 1)), scal(1e-3))
            params = list(out[:n])
            m = list(out[n:2 * n])
            v = list(out[2 * n:3 * n])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] * 0.7

    def test_state_shapes_preserved(self):
        params = model.init_params(CFG)
        m = [np.zeros_like(w) for w in params]
        v = [np.zeros_like(w) for w in params]
        ids, mask, labels = batch()
        out = steps.adam_step(CFG, params, m, v, ids, mask, labels,
                              scal(1.0), scal(1e-3))
        n = len(params)
        assert len(out) == 3 * n + 1
        for i, w in enumerate(params):
            assert out[i].shape == w.shape
            assert out[n + i].shape == w.shape
            assert out[2 * n + i].shape == w.shape


class TestEvalSteps:
    def test_eval_logits(self):
        params = model.init_params(CFG)
        ids, mask, _ = batch()
        (logits,) = steps.eval_step(CFG, params, ids, mask)
        assert logits.shape == (4, CFG.n_classes)

    def test_loss_eval_matches_loss_fn(self):
        params = model.init_params(CFG)
        ids, mask, labels = batch()
        (loss,) = steps.loss_eval_step(CFG, params, ids, mask, labels)
        want = model.loss_fn(CFG, params, ids, mask, labels)
        assert abs(float(loss) - float(want)) < 1e-6


class TestMezoVsAdamMemoryShape:
    """Not a device test — a *structural* check that the MeZO program
    carries no optimizer state through its signature while Adam carries
    3x params.  This is the paper's Table 1 mechanism at the type level."""

    def test_signature_sizes(self):
        from compile import aot
        _, _, ins_m, outs_m = aot.program_signature(CFG, "mezo_step", 4)
        _, _, ins_a, outs_a = aot.program_signature(CFG, "adam_step", 4)
        n = len(model.param_specs(CFG))
        # mezo: params + ids/mask/labels + 3 scalars
        assert len(ins_m) == n + 3 + 3
        # adam: 3x params + ids/mask/labels + 2 scalars
        assert len(ins_a) == 3 * n + 3 + 2
        assert len(outs_m) == n + 1
        assert len(outs_a) == 3 * n + 1
