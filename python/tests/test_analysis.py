"""Tests for the L1 analytical performance model."""

from compile.kernels import analysis


class TestKernelProfiles:
    def test_all_kernels_fit_vmem_double_buffered(self):
        """The chosen default BlockSpecs must leave double-buffer room."""
        for p in analysis.profiles_for(1024, 4096, 512, 16, 50265, 8):
            assert p.fits(double_buffered=True), \
                f"{p.name} uses {p.vmem_bytes} bytes"

    def test_mxu_utilization_high_at_aligned_dims(self):
        p = analysis.linear_profile(1024, 4096, 1024)
        assert p.mxu_utilization > 0.95, p

    def test_mxu_utilization_degrades_for_tiny_tiles(self):
        tiny = analysis.linear_profile(8, 8, 8)
        big = analysis.linear_profile(1024, 1024, 1024)
        assert tiny.mxu_utilization < 0.01
        assert big.mxu_utilization > tiny.mxu_utilization

    def test_flash_attention_vmem_independent_of_seq(self):
        """The point of the online-softmax kernel: O(block), not O(seq)."""
        short = analysis.attention_profile(256, 64)
        long = analysis.attention_profile(4096, 64)
        assert short.vmem_bytes == long.vmem_bytes

    def test_mezo_kernel_is_streaming(self):
        p = analysis.mezo_profile()
        assert p.vmem_bytes < 64 * 1024  # tiny working set
        assert p.arithmetic_intensity > 1.0  # RNG work is free flops

    def test_report_renders(self):
        s = analysis.report()
        assert "flash_attention" in s
        assert "mezo_perturb" in s
        for line in s.splitlines()[2:]:
            assert "NO" not in line, f"kernel overflows VMEM: {line}"

    def test_tile_util_bounds(self):
        for d in [1, 64, 127, 128, 129, 255, 256, 1000]:
            u = analysis._tile_util(d)
            assert 0.0 < u <= 1.0
        assert analysis._tile_util(128) == 1.0
        assert analysis._tile_util(256) == 1.0
