"""L2 model tests: shapes, loss sanity, and the pallas≡jnp path equivalence
that licenses using the fast path for training-scale artifacts."""

import dataclasses

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model

_rng = np.random.default_rng(1)


def make_batch(cfg, batch):
    ids = _rng.integers(0, cfg.vocab, (batch, cfg.max_seq)).astype(np.int32)
    mask = np.ones((batch, cfg.max_seq), np.float32)
    mask[:, cfg.max_seq // 2:] = 0.0
    if cfg.kind == "encoder":
        labels = _rng.integers(0, cfg.n_classes, (batch,)).astype(np.int32)
    else:
        labels = ids
    return ids, mask, labels


class TestParamSpecs:
    @pytest.mark.parametrize("name", list(model.CONFIGS))
    def test_offsets_are_contiguous(self, name):
        cfg = model.CONFIGS[name]
        off = 0
        for spec in model.param_specs(cfg):
            assert spec.offset == off
            off += spec.size
        assert off == model.num_params(cfg)

    def test_paper_scale_param_counts(self):
        """The analytical configs must land on the paper's model sizes."""
        rl = model.num_params(model.CONFIGS["roberta-large"])
        opt = model.num_params(model.CONFIGS["opt-1.3b"])
        assert 330e6 < rl < 380e6          # "RoBERTa-large" ~355M
        assert 1.25e9 < opt < 1.40e9       # "OPT-1.3B"
        # paper §4.4: OPT-1.3B is "over 5 times larger" than RoBERTa-large
        assert opt / rl > 3.5

    def test_init_deterministic(self):
        cfg = model.CONFIGS["pocket-tiny-fast"]
        a = model.init_params(cfg, seed=0)
        b = model.init_params(cfg, seed=0)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)

    def test_init_matches_specs(self):
        cfg = model.CONFIGS["pocket-tiny-fast"]
        for w, spec in zip(model.init_params(cfg), model.param_specs(cfg)):
            assert w.shape == spec.shape
            assert w.dtype == np.float32


class TestForward:
    @pytest.mark.parametrize("name", ["pocket-tiny-fast", "pocket-opt"])
    def test_logits_shape(self, name):
        cfg = model.CONFIGS[name]
        params = model.init_params(cfg)
        ids, mask, _ = make_batch(cfg, 2)
        out = model.logits_fn(cfg, params, ids, mask)
        if cfg.kind == "encoder":
            assert out.shape == (2, cfg.n_classes)
        else:
            assert out.shape == (2, cfg.max_seq, cfg.vocab)

    @pytest.mark.parametrize("name", ["pocket-tiny-fast", "pocket-opt"])
    def test_loss_finite_near_chance(self, name):
        cfg = model.CONFIGS[name]
        params = model.init_params(cfg)
        ids, mask, labels = make_batch(cfg, 2)
        loss = float(model.loss_fn(cfg, params, ids, mask, labels))
        assert np.isfinite(loss)
        chance = np.log(cfg.n_classes if cfg.kind == "encoder" else cfg.vocab)
        assert abs(loss - chance) < 0.25 * chance + 0.5

    def test_padding_invariance(self):
        """Tokens behind the mask must not affect encoder logits."""
        cfg = model.CONFIGS["pocket-tiny-fast"]
        params = model.init_params(cfg)
        ids, mask, _ = make_batch(cfg, 2)
        a = model.logits_fn(cfg, params, ids, mask)
        ids2 = ids.copy()
        ids2[:, cfg.max_seq // 2:] = 7  # rewrite only masked positions
        b = model.logits_fn(cfg, params, ids2, mask)
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_causality(self):
        """Decoder logits at position t must ignore tokens > t."""
        cfg = model.CONFIGS["pocket-opt"]
        cfg = dataclasses.replace(cfg, n_layers=2)
        params = model.init_params(cfg)
        ids, mask, _ = make_batch(cfg, 1)
        mask[:] = 1.0
        t = 10
        a = np.asarray(model.logits_fn(cfg, params, ids, mask))[:, :t]
        ids2 = ids.copy()
        ids2[:, t + 1:] = (ids2[:, t + 1:] + 13) % cfg.vocab
        b = np.asarray(model.logits_fn(cfg, params, ids2, mask))[:, :t]
        assert_allclose(a, b, atol=1e-4)


class TestPathEquivalence:
    """pocket-tiny (Pallas kernels) vs pocket-tiny-fast (XLA-native ops)
    must agree — this is what allows the fast path at training scale."""

    def test_logits_agree(self):
        k = model.CONFIGS["pocket-tiny"]
        f = model.CONFIGS["pocket-tiny-fast"]
        params = model.init_params(f)
        ids, mask, _ = make_batch(f, 4)
        a = model.logits_fn(k, params, ids, mask)
        b = model.logits_fn(f, params, ids, mask)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)

    def test_loss_agrees(self):
        k = model.CONFIGS["pocket-tiny"]
        f = model.CONFIGS["pocket-tiny-fast"]
        params = model.init_params(f)
        ids, mask, labels = make_batch(f, 4)
        a = float(model.loss_fn(k, params, ids, mask, labels))
        b = float(model.loss_fn(f, params, ids, mask, labels))
        assert abs(a - b) < 1e-4
