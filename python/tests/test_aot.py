"""AOT pipeline tests: manifest structure, program signatures, merge
semantics of partial rebuilds — the cross-language contract."""

import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestProgramSignature:
    def test_mezo_io_counts(self):
        cfg = model.CONFIGS["pocket-tiny-fast"]
        _, args, ins, outs = aot.program_signature(cfg, "mezo_step", 4)
        n = len(model.param_specs(cfg))
        assert len(args) == len(ins) == n + 6
        assert len(outs) == n + 1
        assert ins[-3]["name"] == "seed" and ins[-3]["dtype"] == "u32"
        assert outs[-1]["name"] == "loss"

    def test_multi_query_signature_matches_plain(self):
        """q-variants must be drop-in (identical calling convention)."""
        cfg = model.CONFIGS["pocket-tiny-fast"]
        _, _, ins_a, outs_a = aot.program_signature(cfg, "mezo_step", 4)
        _, _, ins_b, outs_b = aot.program_signature(cfg, "mezo_step_q4", 4)
        assert [i["shape"] for i in ins_a] == [i["shape"] for i in ins_b]
        assert [o["shape"] for o in outs_a] == [o["shape"] for o in outs_b]

    def test_decoder_labels_are_2d(self):
        cfg = model.CONFIGS["pocket-opt"]
        _, _, ins, _ = aot.program_signature(cfg, "loss_eval", 2)
        labels = [i for i in ins if i["name"] == "labels"][0]
        assert labels["shape"] == [2, cfg.max_seq]

    def test_unknown_kind_rejected(self):
        cfg = model.CONFIGS["pocket-tiny-fast"]
        with pytest.raises(ValueError):
            aot.program_signature(cfg, "bogus", 4)


class TestBuildAndMerge:
    def _mini_plan(self):
        return [("pocket-tiny-fast", ["eval"], [4])]

    def test_build_writes_manifest_and_params(self):
        with tempfile.TemporaryDirectory() as d:
            m = aot.build(d, self._mini_plan(), verbose=False)
            assert os.path.exists(os.path.join(d, "manifest.json"))
            assert os.path.exists(
                os.path.join(d, "pocket-tiny-fast", "init_params.bin"))
            cfg = model.CONFIGS["pocket-tiny-fast"]
            size = os.path.getsize(
                os.path.join(d, "pocket-tiny-fast", "init_params.bin"))
            assert size == model.num_params(cfg) * 4
            assert len(m["programs"]) == 1

    def test_partial_rebuild_merges(self):
        """`--configs X` must not orphan other configs' entries."""
        with tempfile.TemporaryDirectory() as d:
            aot.build(d, [("pocket-tiny-fast", ["eval"], [4])],
                      verbose=False)
            aot.build(d, [("pocket-tiny", ["eval"], [4])], verbose=False)
            with open(os.path.join(d, "manifest.json")) as f:
                m = json.load(f)
            assert set(m["configs"]) == {"pocket-tiny", "pocket-tiny-fast"}
            assert len(m["programs"]) == 2

    def test_rebuild_replaces_own_entries(self):
        with tempfile.TemporaryDirectory() as d:
            aot.build(d, self._mini_plan(), verbose=False)
            aot.build(d, self._mini_plan(), verbose=False)
            with open(os.path.join(d, "manifest.json")) as f:
                m = json.load(f)
            assert len(m["programs"]) == 1  # no duplicates

    def test_hlo_text_is_parseable_prefix(self):
        with tempfile.TemporaryDirectory() as d:
            aot.build(d, self._mini_plan(), verbose=False)
            path = os.path.join(d, "pocket-tiny-fast", "eval_bs4.hlo.txt")
            head = open(path).read(200)
            assert "HloModule" in head


class TestInitParams:
    def test_offsets_cover_file(self):
        cfg = model.CONFIGS["pocket-roberta"]
        specs = model.param_specs(cfg)
        total = sum(int(np.prod(s.shape)) for s in specs)
        assert total == model.num_params(cfg)

    def test_zero_head_init(self):
        cfg = model.CONFIGS["pocket-roberta"]
        params = model.init_params(cfg)
        byname = {s.name: i for i, s in enumerate(model.param_specs(cfg))}
        assert np.all(params[byname["head.w"]] == 0.0)
        # but the trunk is not degenerate
        assert np.abs(params[byname["layer0.attn.wq"]]).max() > 0
