"""L1 correctness gate: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes / block sizes / seeds; assert_allclose against
``compile.kernels.ref``.  This suite runs as part of ``make test`` and must
be green before ``make artifacts`` output is trusted.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import (adam, attention, layernorm, linear, mezo, ref,
                             rng, softmax_xent)

F32 = np.float32
_rng = np.random.default_rng(0)


def randn(*shape):
    return _rng.standard_normal(shape).astype(F32)


# ---------------------------------------------------------------------------
# rng: the determinism backbone of MeZO
# ---------------------------------------------------------------------------

class TestRng:
    def test_deterministic(self):
        a = rng.gaussian_block(jnp.uint32(5), 17, (256,))
        b = rng.gaussian_block(jnp.uint32(5), 17, (256,))
        assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_seed_changes_stream(self):
        a = np.asarray(rng.gaussian_block(jnp.uint32(5), 0, (256,)))
        b = np.asarray(rng.gaussian_block(jnp.uint32(6), 0, (256,)))
        assert not np.allclose(a, b)

    def test_offset_is_flat_slicing(self):
        """Tensor at offset k must see the same stream as slice [k:] of the
        virtual flat vector — the invariant that lets per-tensor kernels
        share one logical z."""
        whole = np.asarray(rng.gaussian_block(jnp.uint32(9), 0, (512,)))
        part = np.asarray(rng.gaussian_block(jnp.uint32(9), 128, (384,)))
        assert np.array_equal(whole[128:], part)

    def test_gaussian_moments(self):
        z = np.asarray(rng.gaussian_block(jnp.uint32(1), 0, (200_000,)))
        assert abs(z.mean()) < 0.02
        assert abs(z.std() - 1.0) < 0.02

    def test_uniform_range(self):
        u = np.asarray(rng.uniform01(jnp.uint32(2),
                                     jnp.arange(10_000, dtype=jnp.uint32)))
        assert u.min() >= 0.0 and u.max() < 1.0

    @given(seed=st.integers(0, 2**32 - 1), idx=st.integers(0, 2**32 - 1))
    @settings(max_examples=50, deadline=None)
    def test_hash_matches_cpu_reference(self, seed, idx):
        """uint32 wraparound semantics == a plain-python murmur3 fmix."""
        def fmix(s, i):
            x = (i * 0x9E3779B9 + s) & 0xFFFFFFFF
            x ^= x >> 16
            x = (x * 0x85EBCA6B) & 0xFFFFFFFF
            x ^= x >> 13
            x = (x * 0xC2B2AE35) & 0xFFFFFFFF
            x ^= x >> 16
            return x

        got = int(rng.hash_u32(jnp.uint32(seed), jnp.uint32(idx)))
        assert got == fmix(seed, idx)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

class TestLinear:
    @given(
        m=st.sampled_from([8, 32, 64]),
        k=st.sampled_from([16, 48, 96]),
        n=st.sampled_from([8, 40, 80]),
        act=st.sampled_from(["none", "gelu"]),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_ref(self, m, k, n, act):
        x, w, b = randn(m, k), randn(k, n), randn(n)
        got = linear.linear(x, w, b, activation=act, bm=m, bn=n, bk=k)
        assert_allclose(np.asarray(got), np.asarray(ref.linear(x, w, b, act)),
                        rtol=2e-5, atol=2e-5)

    def test_blocked_equals_single_cell(self):
        x, w, b = randn(64, 96), randn(96, 80), randn(80)
        one = linear.linear(x, w, b, bm=64, bn=80, bk=96)
        many = linear.linear(x, w, b, bm=16, bn=20, bk=24)
        assert_allclose(np.asarray(one), np.asarray(many), rtol=1e-5,
                        atol=1e-5)

    def test_rejects_ragged_blocks(self):
        with pytest.raises(AssertionError):
            linear.linear(randn(10, 8), randn(8, 8), randn(8), bm=4, bn=8,
                          bk=8)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

class TestLayerNorm:
    @given(m=st.sampled_from([4, 16, 64]), d=st.sampled_from([8, 48, 128]),
           bm=st.sampled_from([2, 4, 1 << 10]))
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, m, d, bm):
        if m % min(bm, m) != 0:
            return
        x, g, b = randn(m, d), randn(d), randn(d)
        got = layernorm.layernorm(x, g, b, bm=bm)
        assert_allclose(np.asarray(got), np.asarray(ref.layernorm(x, g, b)),
                        rtol=1e-4, atol=1e-5)

    def test_normalizes(self):
        x = randn(8, 64) * 10 + 3
        y = np.asarray(layernorm.layernorm(x, np.ones(64, F32),
                                           np.zeros(64, F32)))
        assert_allclose(y.mean(-1), 0, atol=1e-4)
        assert_allclose(y.std(-1), 1, atol=1e-2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    def _run(self, b, h, s, d, causal, bq, bk, mask_frac=0.3):
        q, k, v = randn(b * h, s, d), randn(b * h, s, d), randn(b * h, s, d)
        mask = (_rng.random((b, s)) > mask_frac).astype(F32)
        mask[:, 0] = 1  # never a fully-masked row
        mbh = np.repeat(mask, h, axis=0)
        got = attention.flash_attention(q, k, v, mbh, causal=causal, bq=bq,
                                        bk=bk)
        want = ref.attention(q.reshape(b, h, s, d), k.reshape(b, h, s, d),
                             v.reshape(b, h, s, d), mask=mask, causal=causal)
        assert_allclose(np.asarray(got), np.asarray(want).reshape(b * h, s, d),
                        rtol=2e-4, atol=2e-5)

    @given(causal=st.booleans(), s=st.sampled_from([16, 32, 64]),
           bq=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]))
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, causal, s, bq, bk):
        self._run(2, 2, s, 16, causal, bq, bk)

    def test_unmasked(self):
        self._run(1, 4, 32, 8, False, 16, 8, mask_frac=0.0)

    def test_single_block_equals_many(self):
        q, k, v = randn(4, 32, 16), randn(4, 32, 16), randn(4, 32, 16)
        m = np.ones((4, 32), F32)
        a = attention.flash_attention(q, k, v, m, bq=32, bk=32)
        b = attention.flash_attention(q, k, v, m, bq=8, bk=8)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

class TestSoftmaxXent:
    @given(n=st.sampled_from([8, 32, 128]), v=st.sampled_from([5, 33, 257]),
           bm=st.sampled_from([4, 8, 1 << 10]))
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, n, v, bm):
        if n % min(bm, n) != 0:
            return
        logits = randn(n, v) * 3
        labels = _rng.integers(0, v, n).astype(np.int32)
        mask = (_rng.random(n) > 0.3).astype(F32)
        got = softmax_xent.softmax_xent(logits, labels, mask, bm=bm)
        want = ref.softmax_xent(logits, labels, mask)
        assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)

    def test_all_masked_is_zero(self):
        logits, labels = randn(8, 11), np.zeros(8, np.int32)
        got = softmax_xent.softmax_xent(logits, labels, np.zeros(8, F32))
        assert float(got) == 0.0

    def test_perfect_prediction_low_loss(self):
        labels = np.arange(8, dtype=np.int32)
        logits = np.full((8, 8), -20.0, F32)
        logits[np.arange(8), labels] = 20.0
        got = softmax_xent.softmax_xent(logits, labels, np.ones(8, F32))
        assert float(got) < 1e-3


# ---------------------------------------------------------------------------
# mezo perturb / update
# ---------------------------------------------------------------------------

class TestMezo:
    @given(n=st.sampled_from([64, 1000, 4096]),
           seed=st.integers(0, 2**31),
           off=st.sampled_from([0, 7, 123456]),
           bm=st.sampled_from([64, 512]))
    @settings(max_examples=10, deadline=None)
    def test_perturb_matches_ref(self, n, seed, off, bm):
        if n % min(bm, n) != 0:
            return
        w = randn(n)
        got = mezo.perturb(w, seed, 0.02, base_offset=off, bm=bm)
        want = ref.mezo_perturb(w, jnp.uint32(seed), off, 0.02)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6,
                        atol=1e-6)

    def test_restore_roundtrip(self):
        """The MeZO invariant: +eps*z then -eps*z returns w (to fp32 ulp).

        This is what lets the optimizer run with zero stored state."""
        w = randn(4096)
        eps = 1e-3
        p = mezo.perturb(w, 42, eps)
        back = mezo.perturb(np.asarray(p), 42, -eps)
        assert_allclose(np.asarray(back), w, rtol=0, atol=1e-6)

    def test_update_matches_ref(self):
        w = randn(2048)
        got = mezo.update(w, 9, 1e-3, -1.7, base_offset=11, bm=256)
        want = ref.mezo_update(w, jnp.uint32(9), 11, 1e-3, -1.7)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6,
                        atol=1e-6)

    def test_2d_tensor_uses_flat_stream(self):
        w = randn(32, 64)
        got = mezo.perturb(w, 3, 0.5, base_offset=100)
        want = ref.mezo_perturb(w, jnp.uint32(3), 100, 0.5)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6,
                        atol=1e-6)


# ---------------------------------------------------------------------------
# adam
# ---------------------------------------------------------------------------

class TestAdam:
    @given(n=st.sampled_from([128, 1024]), t=st.integers(1, 100),
           wd=st.sampled_from([0.0, 0.01]))
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, n, t, wd):
        p, g, m = randn(n), randn(n), randn(n)
        v = randn(n) ** 2
        got = adam.adam_update(p, g, m, v, t, 1e-3, weight_decay=wd, bm=128)
        want = ref.adam_update(p, g, m, v, t, 1e-3, weight_decay=wd)
        for a, b in zip(got, want):
            assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                            atol=2e-6)

    def test_zero_grad_keeps_params_near(self):
        p = randn(256)
        m = np.zeros(256, F32)
        v = np.zeros(256, F32)
        p2, m2, v2 = adam.adam_update(p, np.zeros(256, F32), m, v, 1, 1e-3)
        assert_allclose(np.asarray(p2), p, atol=1e-6)

    def test_descends_quadratic(self):
        """Adam on f(w)=||w||^2/2 must shrink the norm."""
        w = randn(128)
        m = np.zeros(128, F32)
        v = np.zeros(128, F32)
        for t in range(1, 30):
            g = np.asarray(w)
            w, m, v = (np.asarray(a) for a in
                       adam.adam_update(w, g, m, v, t, 0.05))
        assert np.linalg.norm(w) < np.linalg.norm(randn(128)) * 0.9
