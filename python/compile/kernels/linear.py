"""Fused linear (+bias, +GELU) Pallas kernel.

The transformer's dense layers are the FLOPs hot-spot of both the MeZO
double-forward and the Adam forward.  The kernel is a classic MXU-tiled
matmul: grid (M/bm, N/bn, K/bk), f32 accumulation in a VMEM scratch tile,
bias-add and activation fused into the K-epilogue so the activation tensor
is never re-read from HBM.

Hardware adaptation (DESIGN.md §4): the paper runs dense layers through
PyTorch on a phone CPU, where the analogous trick is cache blocking.  Here
BlockSpec expresses the HBM↔VMEM schedule; default blocks are sized for the
128×128 MXU with bf16-friendly multiples, clamped to the problem size so
tiny test shapes use a single grid cell.

interpret=True everywhere — see DESIGN.md; real-TPU lowering would emit a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref


def _pick(block: int, dim: int) -> int:
    """Clamp a preferred block size to the actual dimension."""
    return dim if dim < block else block


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                   activation: str):
    """One (bm, bn) output tile; grid axis 2 walks the K blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...][None, :]
        if activation == "gelu":
            y = ref.gelu(y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def linear(x, w, b, activation: str = "none", bm: int = 128, bn: int = 128,
           bk: int = 512):
    """act(x @ w + b) with x [M,K], w [K,N], b [N] -> [M,N] float32.

    Shapes must tile evenly by the (clamped) block sizes; model dims are
    chosen as multiples of 64 so this always holds in practice.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_linear_kernel, n_k=n_k, activation=activation),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, b)
