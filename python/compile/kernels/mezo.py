"""MeZO perturb / update Pallas kernels — the paper's core memory trick.

``z ~ N(0, I)`` is a parameter-sized tensor that classic SPSA would store.
MeZO regenerates it from ``(seed, flat element index)`` at every use, so
the optimizer carries ZERO state beyond the parameters themselves.  These
kernels express that: each grid cell hashes its own index range (VMEM-local
counter stream, no HBM read for z) and applies ``w + scale*z`` in place of
ever materializing z at HBM scale.

The same ``rng.gaussian`` stream is used by:
  * perturb(+eps)   before forward #1
  * perturb(-2eps)  before forward #2
  * perturb(+eps)   to restore w exactly (bitwise, see tests)
  * update(-lr * projected_grad) for the final SGD step
so a single uint32 seed is the entire "gradient" state between phases.

Tensors are processed in their flat layout; ``base_offset`` situates each
parameter tensor inside the virtual flat parameter vector so streams never
overlap across tensors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rng


def _axpy_kernel(w_ref, seed_ref, scale_ref, o_ref, *, bm: int,
                 base_offset: int):
    i = pl.program_id(0)
    idx = ((i * bm).astype(jnp.uint32) + base_offset
           + jax.lax.broadcasted_iota(jnp.uint32, (bm,), 0))
    z = rng.gaussian(seed_ref[0], idx)
    o_ref[...] = w_ref[...] + scale_ref[0] * z


def _apply(w_flat, seed, scale, base_offset: int, bm: int):
    n = w_flat.shape[0]
    bm = n if n < bm else bm
    assert n % bm == 0, (n, bm)
    return pl.pallas_call(
        functools.partial(_axpy_kernel, bm=bm, base_offset=base_offset),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w_flat, seed, scale)


@functools.partial(jax.jit, static_argnames=("base_offset", "bm"))
def perturb(w, seed, scale, base_offset: int = 0, bm: int = 4096):
    """w + scale * z(seed); works on any-shaped w via flat view.

    ``seed`` uint32 scalar array, ``scale`` float32 scalar array (traced,
    so one compiled kernel serves +eps / -2eps / restore).
    """
    seed = jnp.asarray(seed, jnp.uint32).reshape((1,))
    scale = jnp.asarray(scale, jnp.float32).reshape((1,))
    flat = w.reshape((-1,))
    return _apply(flat, seed, scale, base_offset, bm).reshape(w.shape)


@functools.partial(jax.jit, static_argnames=("base_offset", "bm"))
def update(w, seed, lr, projected_grad, base_offset: int = 0, bm: int = 4096):
    """One MeZO-SGD parameter update: w - lr * g_proj * z(seed)."""
    scale = -jnp.asarray(lr, jnp.float32) * jnp.asarray(projected_grad,
                                                        jnp.float32)
    return perturb(w, seed, scale, base_offset=base_offset, bm=bm)
