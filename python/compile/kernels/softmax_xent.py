"""Fused masked softmax-cross-entropy Pallas kernel.

The loss epilogue of every MeZO forward.  Fusing logsumexp + pick + mask
into one pass means the [N, V] logits are read once and nothing of size
[N, V] is ever written back — the final activation is a scalar, which is
the whole point for the memory ledger.

Grid walks row blocks; each cell emits partial (masked nll sum, mask sum)
into a [n_blocks, 2] output that a trailing jnp reduction folds to the
scalar mean.  (The reduction is O(n_blocks) — negligible.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(logits_ref, labels_ref, mask_ref, o_ref):
    x = logits_ref[...]                       # [bm, V]
    m = jnp.max(x, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1)) + m
    bm, v = x.shape
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (bm, v), 1)
              == labels_ref[...][:, None])
    picked = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
    w = mask_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum((lse - picked) * w)
    o_ref[0, 1] = jnp.sum(w)


def pick_bm(n: int, v: int, budget_bytes: int = 4 * 1024 * 1024) -> int:
    """Largest row block whose [bm, V] tile fits the VMEM budget.

    Found by the L1 analysis pass (EXPERIMENTS.md §Perf): at V=50k the
    old fixed bm=128 put a 25 MiB tile in VMEM.  Cap the tile at 4 MiB
    (leaving double-buffer headroom) and divide n evenly.
    """
    bm = max(1, budget_bytes // (4 * v))
    bm = min(bm, n)
    while n % bm != 0:  # need an even grid; n is a power-of-two-ish batch
        bm -= 1
    return bm


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax_xent(logits, labels, label_mask, bm: int = 0):
    """Masked mean token cross-entropy; logits [N,V], labels/mask [N].

    ``bm=0`` (default) picks the largest VMEM-safe row block.
    """
    n, v = logits.shape
    if bm == 0:
        bm = pick_bm(n, v)
    bm = n if n < bm else bm
    assert n % bm == 0, (n, bm)
    partial_sums = pl.pallas_call(
        _xent_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, v), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // bm, 2), jnp.float32),
        interpret=True,
    )(logits, labels.astype(jnp.int32), label_mask)
    total = jnp.sum(partial_sums, axis=0)
    return total[0] / jnp.maximum(total[1], 1.0)
