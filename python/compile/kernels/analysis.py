"""Analytical performance model for the Pallas kernels (L1 perf pass).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
optimization target is *structural*: VMEM working set per grid cell (must
fit the ~16 MiB scratchpad with headroom for double-buffering) and MXU
tile utilization (how much of each 128x128 systolic pass is real work).
This module computes both for every kernel's BlockSpec, and `report()`
prints the table recorded in EXPERIMENTS.md §Perf.

Run:  python -m compile.kernels.analysis
"""

from __future__ import annotations

import dataclasses

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPUs
MXU = 128                      # systolic array edge


@dataclasses.dataclass
class KernelProfile:
    name: str
    vmem_bytes: int
    mxu_utilization: float     # 0..1; 1.0 = every MXU pass fully used
    arithmetic_intensity: float  # flops per HBM byte

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    def fits(self, double_buffered: bool = True) -> bool:
        k = 2 if double_buffered else 1
        return self.vmem_bytes * k <= VMEM_BYTES


def _tile_util(dim: int, tile: int = MXU) -> float:
    """Fraction of an MXU pass doing useful work along one axis."""
    if dim >= tile:
        full = dim // tile
        rem = dim % tile
        passes = full + (1 if rem else 0)
        return dim / (passes * tile)
    return dim / tile


def linear_profile(m: int, n: int, k: int, bm: int = 128, bn: int = 128,
                   bk: int = 512) -> KernelProfile:
    """Fused linear kernel: grid (m/bm, n/bn, k/bk), f32 acc in scratch."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    vmem = 4 * (bm * bk + bk * bn + bm * bn + bn) + 4 * (bm * bn)  # +acc
    util = _tile_util(bm) * _tile_util(bn) * _tile_util(min(bk, MXU))
    flops = 2.0 * m * n * k
    hbm = 4.0 * (m * k * (n // bn) + k * n * (m // bm) + m * n)
    return KernelProfile("linear", vmem, util, flops / hbm)


def attention_profile(seq: int, d_head: int, bq: int = 128,
                      bk: int = 128) -> KernelProfile:
    """Flash attention: q block resident, kv streamed, O(block) memory."""
    bq, bk = min(bq, seq), min(bk, seq)
    vmem = 4 * (bq * d_head        # q block
                + 2 * bk * d_head  # k, v blocks
                + bk               # mask
                + bq * bk          # scores tile
                + bq * d_head      # acc scratch
                + 2 * bq)          # m, l scratch
    util = _tile_util(bq) * _tile_util(min(d_head, MXU))
    flops = 4.0 * seq * seq * d_head  # qk^T + pv per head
    hbm = 4.0 * (3 * seq * d_head + seq * d_head)  # q,k,v in; o out
    return KernelProfile("flash_attention", vmem, util, flops / hbm)


def layernorm_profile(d: int, bm: int = 256) -> KernelProfile:
    vmem = 4 * (bm * d * 2 + 2 * d)
    # VPU-bound (no MXU); utilization = lane occupancy of the last axis
    util = _tile_util(d, 128)
    return KernelProfile("layernorm", vmem, util, 9.0 / 8.0)


def mezo_profile(block: int = 4096) -> KernelProfile:
    """Perturb/update kernel: pure streaming axpy with on-the-fly RNG."""
    vmem = 4 * (block * 2)  # w block in, out block
    # z never touches HBM: ~12 uint32 ops + Box-Muller per element, all
    # in-register; intensity = flops / (read w + write w)
    flops_per_elem = 20.0
    return KernelProfile("mezo_perturb", vmem, _tile_util(block, 128),
                         flops_per_elem / 8.0)


def xent_profile(v: int, bm: int = 0, n: int = 1 << 20) -> KernelProfile:
    if bm == 0:
        # mirror the kernel's adaptive block (see softmax_xent.pick_bm)
        bm = max(1, (4 * 1024 * 1024) // (4 * v))
        bm = min(bm, n)
    vmem = 4 * (bm * v + 2 * bm + 2)
    return KernelProfile("softmax_xent", vmem, _tile_util(v, 128), 5.0 / 4.0)


def profiles_for(d_model: int, d_ff: int, seq: int, heads: int,
                 vocab: int, batch: int):
    """The kernel set as instantiated by one model config."""
    tokens = batch * seq
    return [
        linear_profile(tokens, d_ff, d_model),
        linear_profile(tokens, d_model, d_ff),
        attention_profile(seq, d_model // heads),
        layernorm_profile(d_model),
        mezo_profile(),
        xent_profile(vocab),
    ]


def report(d_model=1024, d_ff=4096, seq=128, heads=16, vocab=50265,
           batch=8) -> str:
    rows = [f"kernel profiles @ d={d_model} ff={d_ff} seq={seq} "
            f"heads={heads} bs={batch}",
            f"{'kernel':<18}{'VMEM':>10}{'%VMEM':>8}{'2xbuf?':>8}"
            f"{'MXU util':>10}{'AI f/B':>8}"]
    for p in profiles_for(d_model, d_ff, seq, heads, vocab, batch):
        rows.append(
            f"{p.name:<18}{p.vmem_bytes/1024:>8.0f}Ki{p.vmem_frac:>7.1%}"
            f"{'yes' if p.fits() else 'NO':>8}{p.mxu_utilization:>10.1%}"
            f"{p.arithmetic_intensity:>8.1f}"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    print(report())
    print()
    # the pocket configs actually lowered
    print(report(d_model=256, d_ff=1024, seq=64, heads=8, vocab=4096,
                 batch=8))
