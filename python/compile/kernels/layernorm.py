"""Row-blocked LayerNorm Pallas kernel.

Each grid cell normalizes a (bm, D) slab: mean/variance reductions stay in
VMEM and the scale/shift is fused, so the row is read from HBM exactly once
— the memory-traffic structure a phone implementation would want too (LN is
bandwidth-bound, not FLOP-bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x - mu) * inv * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("eps", "bm"))
def layernorm(x, gamma, beta, eps: float = 1e-5, bm: int = 256):
    """LayerNorm over the last axis of x [M, D]; gamma/beta [D]."""
    m, d = x.shape
    bm = m if m < bm else bm
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
