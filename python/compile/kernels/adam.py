"""Fused Adam update Pallas kernel — the derivative-based comparator.

One grid cell updates a flat block of (p, m, v) given g and scalar
hyperparameters.  Fusing the four-tensor pointwise chain keeps HBM traffic
at the streaming minimum (read p,g,m,v; write p,m,v), but nothing can fix
Adam's *capacity* problem: g, m, v are three extra parameter-sized tensors,
which is exactly what Table 1 charges Adam for and why it OOMs at bs 64.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, s_ref, po_ref, mo_ref, vo_ref,
                 *, beta1: float, beta2: float, eps: float,
                 weight_decay: float):
    t, lr = s_ref[0], s_ref[1]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    m_hat = m / (1.0 - jnp.float32(beta1) ** t)
    v_hat = v / (1.0 - jnp.float32(beta2) ** t)
    step = lr * m_hat / (jnp.sqrt(v_hat) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p_ref[...]
    po_ref[...] = p_ref[...] - step
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps",
                                             "weight_decay", "bm"))
def adam_update(p, g, m, v, t, lr, beta1: float = 0.9, beta2: float = 0.999,
                eps: float = 1e-8, weight_decay: float = 0.0, bm: int = 4096):
    """Fused Adam step over flat views; returns (p', m', v')."""
    shape = p.shape
    pf, gf, mf, vf = (a.reshape((-1,)) for a in (p, g, m, v))
    n = pf.shape[0]
    bm = n if n < bm else bm
    assert n % bm == 0, (n, bm)
    scalars = jnp.stack([jnp.asarray(t, jnp.float32),
                         jnp.asarray(lr, jnp.float32)])
    outs = pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps,
                          weight_decay=weight_decay),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(pf, gf, mf, vf, scalars)
    return tuple(o.reshape(shape) for o in outs)
