"""Counter-based RNG shared by the MeZO kernels and the reference oracle.

PocketLLM's memory story hinges on MeZO's central trick (Malladi et al.,
2024): the Gaussian perturbation ``z`` is never materialized as a second
parameter-sized tensor.  Instead it is *regenerated* from ``(seed, element
index)`` every time it is needed — once for the ``+eps*z`` forward, once for
the ``-2*eps*z`` flip, and once for the final ``-lr*g*z`` update.  Peak
memory therefore stays at one copy of the parameters.

To make regeneration bit-exact across (a) the Pallas kernels, (b) the
pure-jnp reference oracle, and (c) every call site inside one fused HLO
program, all of them share this module: a stateless murmur3-finalizer hash
over uint32 counters, turned into N(0,1) samples via Box-Muller.

Implementation note: all constants are Python literals (weak-typed scalars)
rather than jnp arrays — Pallas kernels may not close over array constants,
and weak-typed literals fold into the uint32 ops without promotion.
"""

from __future__ import annotations

import jax.numpy as jnp

_TWO_PI = 6.283185307179586
# 2**-32; multiplying a uint32 by this gives a uniform in [0, 1).
_U32_INV = 2.3283064365386963e-10


def _mul_u32(x: jnp.ndarray, c: int) -> jnp.ndarray:
    """x * c (mod 2^32) for a uint32 array and a Python constant.

    Constants above 2^31 can't ride in as weak-typed literals (jax parses
    them as int32), so split c = 2*(c>>1) + (c&1):  the halves fit, and
    uint32 arithmetic wraps exactly like the single multiply would.
    """
    if c < 0x80000000:
        return x * c
    y = (x * (c >> 1)) << 1
    return y + x if (c & 1) else y


def hash_u32(seed: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Stateless hash (seed: uint32, idx: uint32) -> uint32.

    murmur3 fmix32 applied to ``idx * GOLDEN + seed``.  Passes through
    Pallas interpret mode untouched (shifts/xors/mults on uint32).
    """
    seed = seed.astype(jnp.uint32)
    idx = idx.astype(jnp.uint32)
    x = _mul_u32(idx, 0x9E3779B9) + seed
    x = x ^ (x >> 16)
    x = _mul_u32(x, 0x85EBCA6B)
    x = x ^ (x >> 13)
    x = _mul_u32(x, 0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def uniform01(seed: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Uniform in [0, 1) as float32, from one hash evaluation."""
    return hash_u32(seed, idx).astype(jnp.float32) * _U32_INV


def gaussian(seed: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Standard-normal sample for element index ``idx`` under ``seed``.

    Box-Muller over two decorrelated hash streams (2*idx, 2*idx+1).
    ``idx`` may be any uint32 array shape; the result is float32 of the
    same shape.  A tiny floor keeps log() finite when u1 == 0.
    """
    idx = idx.astype(jnp.uint32)
    u1 = uniform01(seed, idx * 2)
    u2 = uniform01(seed, idx * 2 + 1)
    u1 = jnp.maximum(u1, 1e-12)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(_TWO_PI * u2)


def gaussian_block(seed, base_offset, shape) -> jnp.ndarray:
    """Gaussian samples for a contiguous flat slab of ``prod(shape)``
    elements starting at flat index ``base_offset``.

    This is the form the MeZO kernels use: each parameter tensor owns a
    disjoint offset range inside one virtual flat parameter vector, so the
    same (seed, global element index) pair always regenerates the same z
    regardless of which kernel/block asks for it.
    """
    n = 1
    for s in shape:
        n *= int(s)
    idx = jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(base_offset)
    return gaussian(seed, idx).reshape(shape)
