"""Pure-jnp reference oracle for every Pallas kernel in this package.

Each function here is the semantic ground truth its kernel twin is tested
against (``python/tests/test_kernels.py`` sweeps shapes/dtypes/seeds with
hypothesis and asserts allclose).  They are also what ``model.py`` uses when
lowering the *fast* artifact variants: XLA's native dot/softmax fusions are
much quicker under the CPU PJRT plugin than interpret-mode Pallas, and the
test suite proves the two paths are numerically interchangeable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rng


# ---------------------------------------------------------------------------
# dense compute
# ---------------------------------------------------------------------------

def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the kernel exactly)."""
    c = jnp.float32(0.7978845608028654)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def linear(x, w, b, activation: str = "none"):
    """y = act(x @ w + b); x [M,K], w [K,N], b [N]."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "gelu":
        y = gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def layernorm(x, gamma, beta, eps: float = 1e-5):
    """Row-wise LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def attention(q, k, v, mask=None, causal: bool = False):
    """Scaled dot-product attention.

    q,k,v: [B, H, S, D].  ``mask``: [B, S] with 1 = valid token, or None.
    ``causal`` adds the autoregressive triangle.  Returns [B, H, S, D].
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    neg = jnp.float32(-1e30)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :] > 0, scores, neg)
    if causal:
        s = q.shape[2]
        tri = jnp.tril(jnp.ones((s, s), dtype=jnp.bool_))
        scores = jnp.where(tri[None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def softmax_xent(logits, labels, label_mask=None):
    """Mean token cross-entropy.

    logits [N, V], labels [N] int32; ``label_mask`` [N] (1 = contributes).
    Returns a scalar: sum(masked nll) / max(sum(mask), 1).
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    nll = lse - picked
    if label_mask is None:
        return jnp.mean(nll)
    m = label_mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def mezo_perturb(w, seed, base_offset, scale):
    """w + scale * z  with z regenerated from (seed, flat element index).

    ``base_offset`` is this tensor's first index in the virtual flat
    parameter vector; see kernels.rng.gaussian_block.
    """
    z = rng.gaussian_block(seed, base_offset, w.shape)
    return w + jnp.float32(scale) * z


def mezo_update(w, seed, base_offset, lr, projected_grad):
    """One MeZO-SGD step: w - lr * g_proj * z (z regenerated, never stored)."""
    z = rng.gaussian_block(seed, base_offset, w.shape)
    return w - jnp.float32(lr) * jnp.float32(projected_grad) * z


def adam_update(p, g, m, v, t, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                weight_decay=0.0):
    """One Adam(W) step; ``t`` is the 1-based step count.

    Returns (p_new, m_new, v_new).  This is the comparator the paper OOMs:
    m and v are two extra parameter-sized states, and g a third.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    t = jnp.float32(t)
    m_hat = m_new / (1.0 - jnp.float32(beta1) ** t)
    v_hat = v_new / (1.0 - jnp.float32(beta2) ** t)
    step = lr * m_hat / (jnp.sqrt(v_hat) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p
    return p - step, m_new, v_new
