"""Flash-attention-style Pallas kernel (online softmax, O(block) memory).

This kernel is what makes PocketLLM's "derivative-free methods do not
require activation saving" claim structurally true even *within* one
forward: naive attention materializes the [S, S] score matrix, which at
batch 64 is exactly the kind of activation blow-up Table 1 punishes Adam
for.  The online-softmax formulation keeps peak intermediate memory at
O(bq * bk) per grid cell regardless of sequence length.

Hardware adaptation: the CUDA original tiles over threadblocks + shared
memory; here the q-block lives in VMEM across the kv loop (grid axis 2 is
the kv walk), with running (max, denominator, accumulator) carried in VMEM
scratch — the BlockSpec expresses the same HBM↔scratchpad schedule.

Layout: q, k, v are [BH, S, D] (batch*heads flattened on axis 0).
``mask`` is [BH, S] with 1 = valid key; ``causal`` adds the triangle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, n_kv: int, bq: int, bk: int,
                  scale: float, causal: bool):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                      # [bq, d]
    k = k_ref[0]                      # [bk, d]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    valid = mask_ref[0][None, :] > 0  # [1, bk]
    if causal:
        iq = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        ik = kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.logical_and(valid, ik <= iq)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...]               # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_cur[:, None])
    # Rows where everything so far is masked keep m == _NEG; exp(0)=1 rows
    # of garbage are zeroed by the mask above (p=exp(_NEG - _NEG)=1 only
    # when s==_NEG == m_cur; suppress them explicitly).
    p = jnp.where(jnp.logical_and(s <= _NEG / 2, m_cur[:, None] <= _NEG / 2),
                  0.0, p)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kv == n_kv - 1)
    def _fini():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, mask, causal: bool = False, bq: int = 128,
                    bk: int = 128):
    """Online-softmax attention; q,k,v [BH,S,D], mask [BH,S] -> [BH,S,D]."""
    bh, s, d = q.shape
    bq = s if s < bq else bq
    bk = s if s < bk else bk
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / float(d) ** 0.5
    n_kv = s // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=n_kv, bq=bq, bk=bk,
                          scale=scale, causal=causal),
        grid=(bh, s // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, kv: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kv: (b, kv, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, kv: (b, kv, 0)),
            pl.BlockSpec((1, bk), lambda b, i, kv: (b, kv)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, kv: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)
