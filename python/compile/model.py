"""Layer-2: the transformer family PocketLLM fine-tunes, in pure JAX.

Two architectures, mirroring the paper's two subjects:

* ``encoder``  — RoBERTa-style: bidirectional encoder + masked mean-pool +
  classification head (the paper fine-tunes RoBERTa-large on SST-2).
* ``decoder``  — OPT-style: causal LM with tied output embedding (the paper
  fine-tunes OPT-1.3B on SuperGLUE prompts).

Everything is a function of an *ordered list* of parameter tensors — no
pytrees cross the AOT boundary.  ``param_specs(cfg)`` defines the canonical
order, shapes and flat offsets; ``aot.py`` writes the same specs into
``manifest.json`` so the Rust coordinator addresses tensors by index.

``use_pallas`` selects the compute path:
  True  — L1 Pallas kernels (interpret=True) lower into the HLO program;
          used for the kernel-path artifacts and the composition tests.
  False — the pure-jnp reference ops (XLA-native dot/softmax fusions);
          used for the training-scale artifacts where interpret-mode
          overhead would dominate.  ``tests/test_model.py`` proves the two
          paths agree to fp32 tolerance, so they are interchangeable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as k_attention
from .kernels import layernorm as k_layernorm
from .kernels import linear as k_linear
from .kernels import ref
from .kernels import softmax_xent as k_xent


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one model variant."""

    name: str
    kind: str                 # "encoder" (classifier) | "decoder" (causal LM)
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    n_classes: int = 2        # encoder head width
    use_pallas: bool = False

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Registry of every configuration the system knows about.  The pocket-*
# entries are lowered to artifacts and actually trained; roberta-large /
# opt-1.3b exist so the device model can compute the paper's footprints
# from the real dimensions (they are never lowered on this host).
CONFIGS = {
    # tiny: unit/integration tests + kernel-path (pallas) artifacts
    "pocket-tiny": ModelConfig("pocket-tiny", "encoder", vocab=512,
                               d_model=64, n_layers=2, n_heads=2, d_ff=128,
                               max_seq=32, use_pallas=True),
    # same dims, fast path — used to prove path equivalence end-to-end
    "pocket-tiny-fast": ModelConfig("pocket-tiny-fast", "encoder", vocab=512,
                                    d_model=64, n_layers=2, n_heads=2,
                                    d_ff=128, max_seq=32, use_pallas=False),
    # the Fig. 1 subject: RoBERTa-style classifier at pocket scale (~6M)
    "pocket-roberta": ModelConfig("pocket-roberta", "encoder", vocab=4096,
                                  d_model=256, n_layers=6, n_heads=8,
                                  d_ff=1024, max_seq=64, use_pallas=False),
    # the §4.3/4.4 subject: OPT-style causal LM at pocket scale
    "pocket-opt": ModelConfig("pocket-opt", "decoder", vocab=4096,
                              d_model=256, n_layers=6, n_heads=8, d_ff=1024,
                              max_seq=64, use_pallas=False),
    # paper-scale configs — device-model inputs only, never lowered here
    "roberta-large": ModelConfig("roberta-large", "encoder", vocab=50265,
                                 d_model=1024, n_layers=24, n_heads=16,
                                 d_ff=4096, max_seq=512),
    "opt-1.3b": ModelConfig("opt-1.3b", "decoder", vocab=50272,
                            d_model=2048, n_layers=24, n_heads=32,
                            d_ff=8192, max_seq=2048),
}


# ---------------------------------------------------------------------------
# parameter specification (the AOT manifest contract)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    offset: int               # first index in the virtual flat param vector

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """Canonical ordered parameter list.

    The order here IS the artifact calling convention: mezo_step /
    adam_step take and return tensors in exactly this order, and the flat
    ``offset`` situates each tensor in the shared MeZO z-stream.
    """
    shapes: List[Tuple[str, Tuple[int, ...]]] = []
    d, ff, s = cfg.d_model, cfg.d_ff, cfg.max_seq
    shapes.append(("embed.tok", (cfg.vocab, d)))
    shapes.append(("embed.pos", (s, d)))
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "ln1.g", (d,)), (p + "ln1.b", (d,)),
            (p + "attn.wq", (d, d)), (p + "attn.bq", (d,)),
            (p + "attn.wk", (d, d)), (p + "attn.bk", (d,)),
            (p + "attn.wv", (d, d)), (p + "attn.bv", (d,)),
            (p + "attn.wo", (d, d)), (p + "attn.bo", (d,)),
            (p + "ln2.g", (d,)), (p + "ln2.b", (d,)),
            (p + "ffn.w1", (d, ff)), (p + "ffn.b1", (ff,)),
            (p + "ffn.w2", (ff, d)), (p + "ffn.b2", (d,)),
        ]
    shapes.append(("final_ln.g", (d,)))
    shapes.append(("final_ln.b", (d,)))
    if cfg.kind == "encoder":
        shapes.append(("head.w", (d, cfg.n_classes)))
        shapes.append(("head.b", (cfg.n_classes,)))
    # decoder ties the output projection to embed.tok — no extra tensors
    specs, off = [], 0
    for name, shp in shapes:
        specs.append(ParamSpec(name, shp, off))
        off += int(np.prod(shp))
    return specs


def num_params(cfg: ModelConfig) -> int:
    sp = param_specs(cfg)
    return sp[-1].offset + sp[-1].size


def init_params(cfg: ModelConfig, seed: int = 0) -> List[np.ndarray]:
    """Deterministic scaled-normal init, matching spec order."""
    g = np.random.default_rng(seed)
    out = []
    for spec in param_specs(cfg):
        if spec.name.endswith((".b", ".bq", ".bk", ".bv", ".bo", ".b1",
                               ".b2")):
            w = np.zeros(spec.shape, np.float32)
        elif spec.name.endswith(".g"):
            w = np.ones(spec.shape, np.float32)
        elif spec.name == "head.w":
            # zero-init the classifier head: training starts at exactly
            # ln(n_classes) for every batch, which keeps Fig.-1-style
            # loss curves interpretable (standard fine-tuning practice)
            w = np.zeros(spec.shape, np.float32)
        elif spec.name.startswith("embed."):
            w = (g.standard_normal(spec.shape) * 0.02).astype(np.float32)
        else:
            fan_in = spec.shape[0]
            w = (g.standard_normal(spec.shape)
                 / math.sqrt(fan_in)).astype(np.float32)
        out.append(w)
    return out


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _linear(cfg, x, w, b, act="none"):
    if cfg.use_pallas:
        return k_linear.linear(x, w, b, activation=act)
    return ref.linear(x, w, b, activation=act)


def _layernorm(cfg, x, g, b):
    if cfg.use_pallas:
        return k_layernorm.layernorm(x, g, b)
    return ref.layernorm(x, g, b)


def _attention(cfg, q, k, v, mask, causal):
    bsz, h, s, dh = q.shape
    if cfg.use_pallas:
        mbh = jnp.repeat(mask.astype(jnp.float32), h, axis=0)
        out = k_attention.flash_attention(
            q.reshape(bsz * h, s, dh), k.reshape(bsz * h, s, dh),
            v.reshape(bsz * h, s, dh), mbh, causal=causal)
        return out.reshape(bsz, h, s, dh)
    return ref.attention(q, k, v, mask=mask, causal=causal)


def _xent(cfg, logits, labels, mask):
    if cfg.use_pallas:
        return k_xent.softmax_xent(logits, labels, mask)
    return ref.softmax_xent(logits, labels, mask)


def encode(cfg: ModelConfig, params: Sequence[jnp.ndarray], ids, mask):
    """Shared transformer trunk.  ids/mask [B, S] -> hidden [B, S, D]."""
    specs = param_specs(cfg)
    byname = {s.name: i for i, s in enumerate(specs)}

    def p(name):
        return params[byname[name]]

    bsz, s = ids.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    causal = cfg.kind == "decoder"

    x = jnp.take(p("embed.tok"), ids.astype(jnp.int32), axis=0)
    x = x + p("embed.pos")[None, :s, :]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        # --- attention block (pre-LN) ---
        hidden = _layernorm(cfg, x.reshape(bsz * s, d), p(pre + "ln1.g"),
                            p(pre + "ln1.b"))
        q = _linear(cfg, hidden, p(pre + "attn.wq"), p(pre + "attn.bq"))
        k = _linear(cfg, hidden, p(pre + "attn.wk"), p(pre + "attn.bk"))
        v = _linear(cfg, hidden, p(pre + "attn.wv"), p(pre + "attn.bv"))
        q = q.reshape(bsz, s, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, s, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, s, h, dh).transpose(0, 2, 1, 3)
        a = _attention(cfg, q, k, v, mask, causal)
        a = a.transpose(0, 2, 1, 3).reshape(bsz * s, d)
        a = _linear(cfg, a, p(pre + "attn.wo"), p(pre + "attn.bo"))
        x = x + a.reshape(bsz, s, d)
        # --- ffn block (pre-LN) ---
        hidden = _layernorm(cfg, x.reshape(bsz * s, d), p(pre + "ln2.g"),
                            p(pre + "ln2.b"))
        hidden = _linear(cfg, hidden, p(pre + "ffn.w1"), p(pre + "ffn.b1"),
                         act="gelu")
        hidden = _linear(cfg, hidden, p(pre + "ffn.w2"), p(pre + "ffn.b2"))
        x = x + hidden.reshape(bsz, s, d)

    x = _layernorm(cfg, x.reshape(bsz * s, d), p("final_ln.g"),
                   p("final_ln.b")).reshape(bsz, s, d)
    return x


def logits_fn(cfg: ModelConfig, params: Sequence[jnp.ndarray], ids, mask):
    """Task head.

    encoder: [B, n_classes] from masked mean-pool.
    decoder: [B, S, vocab] via the tied embedding.
    """
    specs = param_specs(cfg)
    byname = {s.name: i for i, s in enumerate(specs)}
    x = encode(cfg, params, ids, mask)
    if cfg.kind == "encoder":
        m = mask.astype(jnp.float32)[:, :, None]
        pooled = jnp.sum(x * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
        return _linear(cfg, pooled, params[byname["head.w"]],
                       params[byname["head.b"]])
    return jnp.einsum("bsd,vd->bsv", x, params[byname["embed.tok"]])


def loss_fn(cfg: ModelConfig, params: Sequence[jnp.ndarray], ids, mask,
            labels):
    """Scalar training loss.

    encoder: cross-entropy over class logits, labels [B].
    decoder: next-token cross-entropy, labels [B, S] (usually == ids),
             padding excluded via the mask.
    """
    logits = logits_fn(cfg, params, ids, mask)
    if cfg.kind == "encoder":
        bsz = logits.shape[0]
        return _xent(cfg, logits, labels.reshape(bsz),
                     jnp.ones((bsz,), jnp.float32))
    # decoder: predict token t+1 from position t
    bsz, s, v = logits.shape
    pred = logits[:, :-1, :].reshape(bsz * (s - 1), v)
    tgt = labels[:, 1:].reshape(bsz * (s - 1))
    lm_mask = (mask[:, 1:] * mask[:, :-1]).reshape(bsz * (s - 1))
    return _xent(cfg, pred, tgt, lm_mask.astype(jnp.float32))
