"""AOT pipeline: lower every (config, program, batch) to HLO text + manifest.

This is the ONLY place Python runs in the whole system, and it runs once:
``make artifacts`` invokes it, after which the Rust binary is self-contained.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs, under ``--out`` (default ../artifacts):

    manifest.json                     — the complete calling convention:
                                        configs, param specs, program I/O
    <config>/<program>_bs<B>.hlo.txt  — one XLA program per step variant
    <config>/init_params.bin          — deterministic init, raw f32 LE
                                        concatenated in param_specs order
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, steps


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_io(cfg, suffix=""):
    return [_io_entry(s.name + suffix, s.shape, "f32")
            for s in model.param_specs(cfg)]


def program_signature(cfg: model.ModelConfig, kind: str, batch: int):
    """(jax callable, example arg specs, input io list, output io list)."""
    s = cfg.max_seq
    ids = _spec((batch, s), jnp.int32)
    mask = _spec((batch, s), jnp.float32)
    if cfg.kind == "encoder":
        labels = _spec((batch,), jnp.int32)
        labels_io = _io_entry("labels", (batch,), "i32")
    else:
        labels = _spec((batch, s), jnp.int32)
        labels_io = _io_entry("labels", (batch, s), "i32")
    pspecs = [_spec(p.shape, jnp.float32) for p in model.param_specs(cfg)]
    data_io = [_io_entry("ids", (batch, s), "i32"),
               _io_entry("mask", (batch, s), "f32")]
    scalar = lambda n, d: _io_entry(n, (1,), d)

    if kind in ("mezo_step", "mezo_step_naive") or \
            kind.startswith("mezo_step_q"):
        if kind == "mezo_step":
            step_fn = steps.mezo_step
        elif kind == "mezo_step_naive":
            step_fn = steps.mezo_step_naive
        else:
            k = int(kind.removeprefix("mezo_step_q"))

            def step_fn(cfg_, params_, i_, m_, l_, seed_, lr_, eps_, _k=k):
                return steps.mezo_step_multi(cfg_, params_, i_, m_, l_,
                                             seed_, lr_, eps_, _k)

        def fn(*args):
            n = len(pspecs)
            params, (i, m, l, seed, lr, eps) = args[:n], args[n:]
            return step_fn(cfg, params, i, m, l, seed, lr, eps)

        args = pspecs + [ids, mask, labels, _spec((1,), jnp.uint32),
                         _spec((1,), jnp.float32), _spec((1,), jnp.float32)]
        ins = (_param_io(cfg) + data_io
               + [labels_io, scalar("seed", "u32"), scalar("lr", "f32"),
                  scalar("eps", "f32")])
        outs = _param_io(cfg) + [_io_entry("loss", (), "f32")]
    elif kind == "adam_step":
        def fn(*args):
            n = len(pspecs)
            params = args[:n]
            m_st = args[n:2 * n]
            v_st = args[2 * n:3 * n]
            i, m, l, t, lr = args[3 * n:]
            return steps.adam_step(cfg, params, m_st, v_st, i, m, l, t, lr)

        args = (pspecs + pspecs + pspecs
                + [ids, mask, labels, _spec((1,), jnp.float32),
                   _spec((1,), jnp.float32)])
        ins = (_param_io(cfg) + _param_io(cfg, ".m") + _param_io(cfg, ".v")
               + data_io + [labels_io, scalar("t", "f32"),
                            scalar("lr", "f32")])
        outs = (_param_io(cfg) + _param_io(cfg, ".m") + _param_io(cfg, ".v")
                + [_io_entry("loss", (), "f32")])
    elif kind == "eval":
        def fn(*args):
            n = len(pspecs)
            return steps.eval_step(cfg, args[:n], args[n], args[n + 1])

        args = pspecs + [ids, mask]
        ins = _param_io(cfg) + data_io
        if cfg.kind == "encoder":
            outs = [_io_entry("logits", (batch, cfg.n_classes), "f32")]
        else:
            outs = [_io_entry("logits", (batch, s, cfg.vocab), "f32")]
    elif kind == "loss_eval":
        def fn(*args):
            n = len(pspecs)
            return steps.loss_eval_step(cfg, args[:n], args[n], args[n + 1],
                                        args[n + 2])

        args = pspecs + [ids, mask, labels]
        ins = _param_io(cfg) + data_io + [labels_io]
        outs = [_io_entry("loss", (), "f32")]
    else:
        raise ValueError(kind)
    return fn, args, ins, outs


# What gets lowered.  (config, program kinds, batch sizes.)
# pocket-tiny runs the Pallas-kernel path; MeZO needs no AD so the
# forward-only programs are exactly what zeroth-order buys us there.
# The -fast twin (identical dims, XLA-native ops) carries adam_step, and
# the training-scale configs carry the full grid used by the benches.
DEFAULT_PLAN = [
    ("pocket-tiny", ["mezo_step", "eval", "loss_eval"], [4]),
    ("pocket-tiny-fast", ["mezo_step", "adam_step", "eval", "loss_eval"],
     [4]),
    ("pocket-roberta", ["mezo_step", "adam_step", "eval", "loss_eval"],
     [8, 64]),
    # perf-ablation artifact (fused vs naive restore+update; §Perf L2)
    # + §6.3 extension: k-query SPSA (variance/compute trade)
    ("pocket-roberta", ["mezo_step_naive", "mezo_step_q4"], [8]),
    ("pocket-opt", ["mezo_step", "adam_step", "eval", "loss_eval"], [8]),
]


def build(out_dir: str, plan=None, verbose: bool = True) -> dict:
    plan = plan or DEFAULT_PLAN
    os.makedirs(out_dir, exist_ok=True)
    # merge into an existing manifest so `--configs X` partial rebuilds
    # don't orphan the other configs' artifacts
    manifest = {"format": 1, "configs": {}, "programs": []}
    manifest_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("format") == 1:
            rebuilt = {name for name, _, _ in plan}
            manifest["configs"] = {k: v for k, v in old["configs"].items()
                                   if k not in rebuilt}
            manifest["programs"] = [p for p in old["programs"]
                                    if p["config"] not in rebuilt]

    for cfg_name, kinds, batches in plan:
        cfg = model.CONFIGS[cfg_name]
        cfg_dir = os.path.join(out_dir, cfg_name)
        os.makedirs(cfg_dir, exist_ok=True)

        manifest["configs"][cfg_name] = {
            "kind": cfg.kind, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "n_classes": cfg.n_classes, "use_pallas": cfg.use_pallas,
            "n_params": model.num_params(cfg),
            "params": [{"name": p.name, "shape": list(p.shape),
                        "offset": p.offset}
                       for p in model.param_specs(cfg)],
        }

        # deterministic init the rust side loads as the pre-trained model
        params = model.init_params(cfg, seed=0)
        with open(os.path.join(cfg_dir, "init_params.bin"), "wb") as f:
            for w in params:
                f.write(np.ascontiguousarray(w, np.float32).tobytes())

        for kind in kinds:
            for batch in batches:
                t0 = time.time()
                fn, args, ins, outs = program_signature(cfg, kind, batch)
                text = to_hlo_text(jax.jit(fn).lower(*args))
                rel = f"{cfg_name}/{kind}_bs{batch}.hlo.txt"
                with open(os.path.join(out_dir, rel), "w") as f:
                    f.write(text)
                manifest["programs"].append({
                    "config": cfg_name, "kind": kind, "batch": batch,
                    "file": rel, "inputs": ins, "outputs": outs,
                })
                if verbose:
                    print(f"  {rel:48s} {len(text)/1e6:6.2f} MB "
                          f"{time.time()-t0:6.1f}s", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=None,
                    help="subset of config names to build")
    args = ap.parse_args()
    plan = DEFAULT_PLAN
    if args.configs:
        plan = [p for p in DEFAULT_PLAN if p[0] in args.configs]
    t0 = time.time()
    m = build(args.out, plan)
    print(f"wrote {len(m['programs'])} programs to {args.out} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
