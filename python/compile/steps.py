"""Layer-2 step programs — the units the Rust coordinator executes.

Each function below becomes exactly one AOT artifact per (config, batch)
pair.  Signatures are flat positional tensor lists (see model.param_specs)
plus shape-(1,) scalar tensors, because that is what crosses the HLO text
boundary to the ``xla`` crate.

``mezo_step`` is the paper's contribution as a single fused program:

    seed ~ given by the coordinator (uint32)
    w+  = w  + eps * z(seed)          # perturb, z regenerated per element
    L+  = loss(w+)
    w-  = w+ - 2 eps * z(seed)        # flip to the antithetic point
    L-  = loss(w-)
    g   = (L+ - L-) / (2 eps)         # SPSA projected gradient (scalar!)
    w'  = w- + (eps - lr * g) * z(seed)
        #  ^ restore (+eps z) and update (-lr g z) folded into ONE axpy —
        #    see EXPERIMENTS.md §Perf (saves a full parameter sweep).

Peak live state inside the program: one parameter set + one forward's
activations.  No gradients, no optimizer state, no stored z — this is the
memory profile Table 1 measures.

``adam_step`` is the derivative-based comparator: jax.value_and_grad plus
the fused Adam kernel, carrying m and v (2 extra parameter sets) and
materializing grads (a 3rd) — the footprint that OOMs the phone at bs 64.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from . import model
from .kernels import adam as k_adam
from .kernels import mezo as k_mezo
from .kernels import ref
from .kernels import rng


def _perturb_all(cfg, params, seed, scale):
    """Apply w += scale*z(seed) to every tensor, sharing one flat stream."""
    specs = model.param_specs(cfg)
    out = []
    for spec, w in zip(specs, params):
        if cfg.use_pallas:
            out.append(k_mezo.perturb(w, seed, scale,
                                      base_offset=spec.offset))
        else:
            out.append(ref.mezo_perturb(w, seed, spec.offset, scale))
    return out


def mezo_step(cfg: model.ModelConfig, params: Sequence[jnp.ndarray], ids,
              mask, labels, seed, lr, eps):
    """One fused MeZO-SGD step.  Returns (new_params..., loss).

    ``seed`` uint32[1]; ``lr``, ``eps`` float32[1].  The reported loss is
    the mean of the two perturbed evaluations — an unbiased estimate of
    the unperturbed loss to O(eps^2), without a third forward.
    """
    seed_s = seed.reshape(())
    lr_s = lr.reshape(())
    eps_s = eps.reshape(())

    w_plus = _perturb_all(cfg, params, seed_s, eps_s)
    loss_plus = model.loss_fn(cfg, w_plus, ids, mask, labels)
    w_minus = _perturb_all(cfg, w_plus, seed_s, -2.0 * eps_s)
    loss_minus = model.loss_fn(cfg, w_minus, ids, mask, labels)

    g = (loss_plus - loss_minus) / (2.0 * eps_s)
    # restore + update in one pass: w- + (eps - lr*g) * z
    new_params = _perturb_all(cfg, w_minus, seed_s, eps_s - lr_s * g)
    loss = 0.5 * (loss_plus + loss_minus)
    return tuple(new_params) + (loss,)


def mezo_step_multi(cfg: model.ModelConfig, params: Sequence[jnp.ndarray],
                    ids, mask, labels, seed, lr, eps, n_queries: int):
    """k-query SPSA: average ``n_queries`` independent two-point estimates.

    The paper's §6.3 points out that derivative-free methods have
    *inherent parallelization potential* that phones underuse: the k
    query pairs are data-parallel (each is an independent forward).  On
    this CPU lowering they run sequentially inside one program; on a
    parallel backend XLA can overlap them, and the Rust native backend
    fans them out over a worker pool.  Variance of the SPSA estimator
    drops ~1/k, buying smoother descent per step at k× the forward cost
    — the ``ablation_zo`` bench measures that trade.

    Memory stays at ONE parameter set plus one perturbed copy.  Every
    query evaluates BOTH sides directly from the base point (w ± eps z,
    classic averaged SPSA at a single point) — queries are therefore
    order-independent, which is exactly what makes them parallelizable
    without changing results; the k averaged updates are applied to the
    untouched base as k axpy sweeps at the end.  The Rust
    ``runtime::native`` interpreter mirrors these semantics bit-for-bit
    across worker counts.
    """
    seed_s = seed.reshape(())
    lr_s = lr.reshape(())
    eps_s = eps.reshape(())

    w = list(params)
    q_seeds = [rng.hash_u32(seed_s, jnp.uint32(q + 1))
               for q in range(n_queries)]
    gs, losses = [], []
    for sq in q_seeds:
        w_plus = _perturb_all(cfg, w, sq, eps_s)
        loss_plus = model.loss_fn(cfg, w_plus, ids, mask, labels)
        w_minus = _perturb_all(cfg, w, sq, -eps_s)  # from the BASE
        loss_minus = model.loss_fn(cfg, w_minus, ids, mask, labels)
        gs.append((loss_plus - loss_minus) / (2.0 * eps_s))
        losses.append(0.5 * (loss_plus + loss_minus))

    scale = lr_s / float(n_queries)
    for sq, g in zip(q_seeds, gs):
        w = _perturb_all(cfg, w, sq, -scale * g)
    loss = sum(losses) / float(n_queries)
    return tuple(w) + (loss,)


def mezo_step_naive(cfg: model.ModelConfig, params: Sequence[jnp.ndarray],
                    ids, mask, labels, seed, lr, eps):
    """Unfused MeZO step — the perf-ablation baseline.

    Identical math to :func:`mezo_step`, but the restore (+eps z) and the
    update (-lr g z) are two separate parameter sweeps, the way a direct
    transcription of the MeZO pseudocode reads.  The fused version saves
    one full parameter-sized regenerate+axpy pass per step; the
    ``hotpath`` bench measures the difference (EXPERIMENTS.md §Perf L2).
    """
    seed_s = seed.reshape(())
    lr_s = lr.reshape(())
    eps_s = eps.reshape(())

    w_plus = _perturb_all(cfg, params, seed_s, eps_s)
    loss_plus = model.loss_fn(cfg, w_plus, ids, mask, labels)
    w_minus = _perturb_all(cfg, w_plus, seed_s, -2.0 * eps_s)
    loss_minus = model.loss_fn(cfg, w_minus, ids, mask, labels)

    g = (loss_plus - loss_minus) / (2.0 * eps_s)
    restored = _perturb_all(cfg, w_minus, seed_s, eps_s)   # pass 3
    new_params = _perturb_all(cfg, restored, seed_s, -lr_s * g)  # pass 4
    loss = 0.5 * (loss_plus + loss_minus)
    return tuple(new_params) + (loss,)


def adam_step(cfg: model.ModelConfig, params: Sequence[jnp.ndarray],
              m_state: Sequence[jnp.ndarray], v_state: Sequence[jnp.ndarray],
              ids, mask, labels, t, lr):
    """One Adam fine-tuning step (the paper's comparator).

    Returns (new_params..., new_m..., new_v..., loss).  ``t`` float32[1]
    (1-based), ``lr`` float32[1].
    """
    t_s = t.reshape(())
    lr_s = lr.reshape(())

    def scalar_loss(plist: List[jnp.ndarray]):
        return model.loss_fn(cfg, plist, ids, mask, labels)

    loss, grads = jax.value_and_grad(scalar_loss)(list(params))

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, m_state, v_state):
        if cfg.use_pallas:
            p2, m2, v2 = k_adam.adam_update(p, g, m, v, t_s, lr_s)
        else:
            p2, m2, v2 = ref.adam_update(p, g, m, v, t_s, lr_s)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)


def eval_step(cfg: model.ModelConfig, params: Sequence[jnp.ndarray], ids,
              mask):
    """Inference: returns task logits (encoder [B,C]; decoder [B,S,V])."""
    return (model.logits_fn(cfg, params, ids, mask),)


def loss_eval_step(cfg: model.ModelConfig, params: Sequence[jnp.ndarray],
                   ids, mask, labels):
    """Validation loss without any parameter update."""
    return (model.loss_fn(cfg, params, ids, mask, labels),)
