//! Quickstart: fine-tune a pocket model with MeZO in ~30 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT manifest, fine-tunes `pocket-tiny` (the Pallas-kernel
//! artifact) on synthetic SST-2 with derivative-free optimization, and
//! reports accuracy before and after.  Note what is *absent*: no Python,
//! no gradients, no optimizer state — the entire optimizer state is a
//! seed and a step counter.

use pocketllm::prelude::*;
use pocketllm::optim::Schedule;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let rt = Runtime::new(manifest)?;
    println!("PJRT platform: {}", rt.platform());

    let mut session = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .task(TaskKind::Sst2)
        .lr(Schedule::Constant(1e-4))
        .seed(42)
        .build()?;

    let acc_before = session.eval_accuracy()?;
    println!("accuracy before fine-tuning: {:.3}", acc_before);

    let stats = session.run_steps(40)?;
    println!(
        "ran {} MeZO steps: loss {:.4} -> {:.4} ({:.0} ms/step on host)",
        stats.steps,
        stats.first_loss,
        stats.last_loss,
        stats.mean_host_step_s * 1e3
    );

    let acc_after = session.eval_accuracy()?;
    println!("accuracy after fine-tuning:  {:.3}", acc_after);
    println!(
        "optimizer state carried between steps: 12 bytes (seed + counter)"
    );
    Ok(())
}
