//! D002 fixture: a wall-clock read inside `src/telemetry/` but NOT in
//! `trace.rs` — the allowlist names the tracer's single capture point
//! (`trace::host_now_us`), not the whole telemetry tree.  Expected:
//! one D002 finding.
use std::time::SystemTime;

pub fn sneaky_timestamp() -> SystemTime {
    SystemTime::now()
}
