//! D002 fixture: a host wall-clock read outside the telemetry
//! allowlist.  Expected: one D002 finding (the `Instant::now` call;
//! the type mention in the signature must NOT fire).
use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}

pub fn span(since: Instant) -> f64 {
    since.elapsed().as_secs_f64()
}
