//! Suppression fixture: every rule violated, every violation
//! carrying a justified pragma.  Expected: ZERO findings, five
//! allows (one file-scope, four inline), six suppressed (the
//! file-scope D001 covers both HashMap mentions).
// lint:allow-file(D001): lookup-only tables; nothing iterates them
use std::collections::HashMap;
use std::time::Instant;

pub struct Table {
    slots: HashMap<String, u64>,
}

pub fn read(t: &Table, k: &str) -> u64 {
    // lint:allow(D004): fixture invariant — key is always present
    let v = t.slots.get(k).unwrap();
    *v
}

pub fn stamp() -> f64 {
    // lint:allow(D002): fixture models a telemetry-only wall read
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

pub fn bytes(v: &[f32]) -> &[u8] {
    // lint:allow(D003): demonstrating suppression; prefer a real
    // SAFETY comment in shipping code
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

pub fn spawn_once() {
    // lint:allow(D005): fixture exercises the suppression path
    let h = std::thread::spawn(|| ());
    let _ = h.join();
}
