//! P000 fixture: a pragma with no justification.  Expected: one P000
//! finding AND the D001 it failed to suppress (the justified pragma
//! further down suppresses the signature's HashMap cleanly).

// lint:allow(D001)
use std::collections::HashMap;

// lint:allow(D001): lookup-only table threaded through a signature
pub fn lookup(m: &HashMap<String, u64>, k: &str) -> u64 {
    m.get(k).copied().unwrap_or(0)
}
