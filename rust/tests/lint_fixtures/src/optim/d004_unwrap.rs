//! D004 fixture: unwrap / expect / panic in library code, plus the
//! two shapes that must NOT fire — `.lock().unwrap()` (poison
//! propagation is the intended panic) and `unwrap_or`.  Expected:
//! three D004 findings.
use std::sync::Mutex;

pub fn fallible(v: Option<u32>, m: &Mutex<u32>) -> u32 {
    let a = v.unwrap();
    let b = v.expect("caller promised Some");
    if a != b {
        panic!("impossible");
    }
    let c = *m.lock().unwrap();
    a + b + c + v.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
