//! D003 fixture: one undocumented `unsafe` (a finding) and one whose
//! safety argument is written in range (clean).  Expected: one D003.
//!
//! (The word the rule greps for is deliberately not spelled in this
//! header — it would land within range of the first block below.)

pub fn undocumented(v: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

pub fn documented(v: &[f32]) -> &[u8] {
    // SAFETY: f32 is plain-old data; size_of_val gives the exact
    // byte length and the borrow pins the source slice alive.
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}
