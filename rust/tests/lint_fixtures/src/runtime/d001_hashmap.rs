//! D001 fixture: hash-ordered collections in a determinism-critical
//! tree.  Expected: two D001 findings (the import and the field).
use std::collections::HashMap;

pub struct Cache {
    slots: HashMap<String, u64>,
}

pub fn total(c: &Cache) -> u64 {
    // iterating a HashMap here is exactly the bug D001 exists for:
    // the fold order differs per process
    c.slots.values().sum()
}
