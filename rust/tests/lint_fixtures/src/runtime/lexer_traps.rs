//! Lexer-trap fixture: every construct that could trick a naive
//! scanner into a false positive.  Expected: ZERO findings.

pub fn raw_strings() -> Vec<&'static str> {
    vec![
        r"a raw string mentioning .unwrap() and HashMap",
        r#"fence depth one: panic!("boom") and .expect("x")"#,
        r##"fence depth two: "# still inside: thread::spawn"##,
    ]
}

pub fn plain_strings() -> String {
    let a = "escaped quote \" then .unwrap() still inside";
    let b = "multi-line string \
             with Instant::now() inside";
    format!("{a}{b}")
}

pub fn byte_strings() -> (&'static [u8], &'static [u8]) {
    (b"bytes: panic!()", br#"raw bytes: .expect("q")"#)
}

/* block comment mentioning .unwrap()
   /* nested block comment: HashMap, SystemTime::now() */
   still inside the outer comment: thread::spawn */
pub fn after_comments(c: char) -> bool {
    // the '"' char literal must not open a string; if it did, the
    // rest of this file would be swallowed and `lifetime_soup`
    // below would vanish from the token stream (a test asserts it)
    c == '"' || c == '\'' || c == 'x'
}

pub fn lifetime_soup<'a>(x: &'a str) -> &'a str {
    x
}
