//! D005 fixture: a raw `thread::spawn` (finding) next to the scoped
//! form every subsystem is supposed to use (clean).  Expected: one
//! D005 finding.

pub fn raw() {
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}

pub fn scoped(work: &[u32]) -> u32 {
    let mut total = 0;
    std::thread::scope(|s| {
        let h = s.spawn(|| work.iter().sum::<u32>());
        total = h.join().unwrap_or(0);
    });
    total
}
