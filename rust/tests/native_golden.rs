//! Cross-language golden tests: the native backend pinned against the
//! Python reference stack.
//!
//! Every constant below was produced by the repo's own Python layer
//! (`python/compile/kernels/rng.py`, `kernels/ref.py`, `steps.py`,
//! jax 0.4 on CPU, float32).  Regenerate with:
//!
//! ```text
//! cd python && python - <<'EOF'
//! import numpy as np, jax.numpy as jnp
//! from compile import model, steps
//! from compile.kernels import rng
//! # hash/gaussian: print rng.hash_u32/gaussian for the pairs below.
//! # model goldens: params[i] = uniform01(1234, offset+i)*0.2-0.1 over
//! # the golden-enc/golden-dec configs, then loss_fn/logits_fn/
//! # mezo_step/mezo_step_multi/adam_step on the fixed batch below.
//! EOF
//! ```
//!
//! The integer hash and the uniform stream are bit-exact; everything
//! that crosses libm (gaussian, forwards) is pinned to tolerances far
//! above any observed deviation (~1e-6) but far below optimizer scales.

use pocketllm::runtime::manifest::ConfigInfo;
use pocketllm::runtime::native::params::make_config;
use pocketllm::runtime::native::rng::{gaussian, hash_u32, uniform01};
use pocketllm::runtime::native::{adam_step, mezo_step, model, ProgramKind,
                                 SpsaPool};

// ---------------------------------------------------------------- rng

#[test]
fn hash_u32_matches_python_exactly() {
    let cases: [(u32, u32, u32); 7] = [
        (0x0, 0x0, 0x0000_0000),
        (0x0, 0x1, 0x92CA_2F0E),
        (0x1, 0x0, 0x514E_28B7),
        (0x2A, 0x7, 0x21A2_7BDB),
        (0xDEAD_BEEF, 0x3039, 0x6124_B765),
        (0xFFFF_FFFF, 0xFFFF_FFFF, 0x3B66_B2AA),
        (0x3039, 0x8000_0003, 0x789B_4631),
    ];
    for (seed, idx, want) in cases {
        assert_eq!(hash_u32(seed, idx), want,
                   "hash_u32({seed:#x}, {idx:#x})");
    }
}

#[test]
fn uniform01_matches_python_bit_for_bit() {
    let want_bits: [u32; 4] =
        [0x3DC6_4D76, 0x3E0C_5A8D, 0x3EE6_F441, 0x3F7F_8391];
    for (idx, want) in want_bits.into_iter().enumerate() {
        let got = uniform01(7, idx as u32);
        assert_eq!(got.to_bits(), want,
                   "uniform01(7, {idx}) = {got} bits {:#010x}",
                   got.to_bits());
    }
}

#[test]
fn gaussian_matches_python_stream() {
    let want: [f32; 8] = [
        1.127_803_8, 1.313_020_7, -0.190_180_2, -0.155_015_42,
        -0.530_648_23, 1.271_272_8, 0.653_417, -0.386_771_5,
    ];
    for (idx, w) in want.into_iter().enumerate() {
        let got = gaussian(0xDEAD_BEEF, idx as u32);
        assert!((got - w).abs() < 1e-4, "gaussian idx {idx}: {got} vs {w}");
    }
    // offset slab (rng.gaussian_block(seed=42, base_offset=1000, (6,)))
    let want_off: [f32; 6] = [
        2.266_634_2, -1.568_671, -1.162_987, -0.156_606_73, 1.220_620_5,
        0.707_487_6,
    ];
    for (i, w) in want_off.into_iter().enumerate() {
        let got = gaussian(42, 1000 + i as u32);
        assert!((got - w).abs() < 1e-4, "offset idx {i}: {got} vs {w}");
    }
}

// ------------------------------------------------------------- models

fn golden_enc() -> ConfigInfo {
    make_config("golden-enc", "encoder", 13, 8, 2, 2, 16, 6, 3, false)
}

fn golden_dec() -> ConfigInfo {
    make_config("golden-dec", "decoder", 13, 8, 2, 2, 16, 6, 2, false)
}

/// params[i] = uniform01(1234, offset + i) * 0.2 - 0.1 — bit-exact on
/// both sides, so forward mismatches isolate forward bugs.
fn golden_params(cfg: &ConfigInfo) -> Vec<Vec<f32>> {
    cfg.params
        .iter()
        .map(|spec| {
            (0..spec.elements())
                .map(|i| {
                    uniform01(1234, (spec.offset + i) as u32) * 0.2f32
                        - 0.1f32
                })
                .collect()
        })
        .collect()
}

const IDS: [i32; 12] = [1, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
const MASK: [f32; 12] =
    [1., 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
const LABELS_CLS: [i32; 2] = [2, 0];

fn close(got: f32, want: f32, tol: f32, what: &str) {
    assert!((got - want).abs() < tol,
            "{what}: got {got}, python says {want}");
}

#[test]
fn golden_configs_match_python_param_layout() {
    // python: model.num_params / len(model.param_specs(cfg))
    let enc = golden_enc();
    assert_eq!(enc.params.len(), 38);
    assert_eq!(enc.n_params, 1395);
    let dec = golden_dec();
    assert_eq!(dec.params.len(), 36);
    assert_eq!(dec.n_params, 1368);
}

#[test]
fn encoder_loss_and_logits_match_jax() {
    let cfg = golden_enc();
    let p = golden_params(&cfg);
    let l = model::loss(&cfg, &p, &IDS, &MASK, &LABELS_CLS, 2, 6,
                        &mut model::Scratch::new());
    close(l, 1.060_763_6, 2e-4, "encoder loss_eval");
    let lg = model::logits(&cfg, &p, &IDS, &MASK, 2, 6,
                           &mut model::Scratch::new());
    let want: [f32; 6] = [
        0.012_931_107, -0.083_361_536, 0.058_144_696, 0.013_024_121,
        -0.083_118_81, 0.058_435_928,
    ];
    for (i, w) in want.into_iter().enumerate() {
        close(lg[i], w, 2e-4, "encoder logit");
    }
}

#[test]
fn decoder_loss_and_logits_match_jax() {
    let cfg = golden_dec();
    let p = golden_params(&cfg);
    let l = model::loss(&cfg, &p, &IDS, &MASK, &IDS, 2, 6,
                        &mut model::Scratch::new());
    close(l, 2.568_747_3, 3e-4, "decoder loss_eval");
    let lg = model::logits(&cfg, &p, &IDS, &MASK, 2, 6,
                           &mut model::Scratch::new());
    let want: [f32; 6] = [
        0.022_800_053, -0.000_762_739_2, 0.001_808_712_5, 0.014_508_689,
        0.004_410_263, -0.005_158_985,
    ];
    for (i, w) in want.into_iter().enumerate() {
        close(lg[i], w, 2e-4, "decoder logit");
    }
}

#[test]
fn encoder_mezo_step_matches_jax() {
    let cfg = golden_enc();
    let mut w = golden_params(&cfg);
    let loss = mezo_step(&cfg, &mut w, &IDS, &MASK, &LABELS_CLS, 2, 6, 77,
                         1e-2, 1e-3, ProgramKind::Mezo,
                 &mut SpsaPool::new(), &mut model::Scratch::new())
        .unwrap();
    close(loss, 1.060_764_6, 2e-4, "mezo loss");
    // embed.tok head of the update stream
    let want_p0: [f32; 4] =
        [-0.084_797_435, -0.013_533_172, 0.045_290_843, 0.089_610_75];
    for (i, want) in want_p0.into_iter().enumerate() {
        close(w[0][i], want, 2e-4, "mezo p0");
    }
    // head.b — the far end of the z-stream
    let last = w.last().unwrap();
    let want_last: [f32; 3] =
        [0.017_041_584, -0.083_037_49, 0.045_541_067];
    for (i, want) in want_last.into_iter().enumerate() {
        close(last[i], want, 2e-4, "mezo plast");
    }
}

#[test]
fn decoder_mezo_step_matches_jax() {
    let cfg = golden_dec();
    let mut w = golden_params(&cfg);
    let loss = mezo_step(&cfg, &mut w, &IDS, &MASK, &IDS, 2, 6, 77, 1e-2,
                         1e-3, ProgramKind::Mezo,
                 &mut SpsaPool::new(), &mut model::Scratch::new())
        .unwrap();
    close(loss, 2.568_747_5, 3e-4, "mezo loss");
    let want_p0: [f32; 4] =
        [-0.087_249_13, -0.012_435_146, 0.044_555_154, 0.092_124_58];
    for (i, want) in want_p0.into_iter().enumerate() {
        close(w[0][i], want, 2e-4, "mezo p0");
    }
    let last = w.last().unwrap(); // final_ln.b (decoder ties the head)
    let want_last: [f32; 4] =
        [-0.043_252_83, -0.054_199_3, 0.097_400_85, 0.067_621_216];
    for (i, want) in want_last.into_iter().enumerate() {
        close(last[i], want, 2e-4, "mezo plast");
    }
}

#[test]
fn multi_query_mezo_matches_jax() {
    let cfg = golden_enc();
    let mut w = golden_params(&cfg);
    let loss = mezo_step(&cfg, &mut w, &IDS, &MASK, &LABELS_CLS, 2, 6, 77,
                         1e-2, 1e-3, ProgramKind::MezoMulti(2),
                 &mut SpsaPool::new(), &mut model::Scratch::new())
        .unwrap();
    close(loss, 1.060_764_9, 2e-4, "q2 loss");
    let want_p0: [f32; 4] =
        [-0.089_060_865, -0.013_062_127, 0.043_244_63, 0.089_557_44];
    for (i, want) in want_p0.into_iter().enumerate() {
        close(w[0][i], want, 2e-4, "q2 p0");
    }

    let cfg = golden_dec();
    let mut w = golden_params(&cfg);
    let loss = mezo_step(&cfg, &mut w, &IDS, &MASK, &IDS, 2, 6, 77, 1e-2,
                         1e-3, ProgramKind::MezoMulti(2),
                 &mut SpsaPool::new(), &mut model::Scratch::new())
        .unwrap();
    close(loss, 2.568_747, 3e-4, "q2 dec loss");
    let want_p0: [f32; 4] =
        [-0.087_981_4, -0.012_158_867, 0.044_249_527, 0.092_467_87];
    for (i, want) in want_p0.into_iter().enumerate() {
        close(w[0][i], want, 2e-4, "q2 dec p0");
    }
}

#[test]
fn encoder_adam_step_matches_jax_autodiff() {
    // the strongest pin: jax computed these with value_and_grad; the
    // native backend with its hand-derived backward pass
    let cfg = golden_enc();
    let mut w = golden_params(&cfg);
    let init = w.clone();
    let zeros = |cfg: &ConfigInfo| -> Vec<Vec<f32>> {
        cfg.params.iter().map(|s| vec![0.0; s.elements()]).collect()
    };
    let mut m = zeros(&cfg);
    let mut v = zeros(&cfg);
    let loss = adam_step(&cfg, &mut w, &mut m, &mut v, &IDS, &MASK,
                         &LABELS_CLS, 2, 6, 1.0, 1e-3,
                         &mut model::Scratch::new())
        .unwrap();
    close(loss, 1.060_763_6, 2e-4, "adam loss");
    // PAD-token embedding gets exactly zero gradient -> unchanged
    for i in 0..4 {
        close(w[0][i], init[0][i], 1e-7, "adam pad-row");
    }
    // head.b: nonzero grads flow
    let n = cfg.params.len();
    let want_p: [f32; 3] =
        [0.014_345_845, -0.081_009_53, 0.045_785_606];
    let want_m: [f32; 3] =
        [-0.016_154_712, 0.030_740_53, -0.014_585_814];
    let want_v: [f32; 3] =
        [2.609_747_4e-5, 9.449_802e-5, 2.127_459_6e-5];
    for i in 0..3 {
        close(w[n - 1][i], want_p[i], 2e-4, "adam plast");
        close(m[n - 1][i], want_m[i], 2e-4, "adam mlast");
        close(v[n - 1][i], want_v[i], 1e-6, "adam vlast");
    }
    // aggregate over the whole gradient field
    let sum_m: f64 = m
        .iter()
        .flat_map(|t| t.iter())
        .map(|x| x.abs() as f64)
        .sum();
    let want_sum = 0.163_962_957;
    assert!((sum_m - want_sum).abs() < 1e-3 * want_sum.max(1.0),
            "sum|m| {sum_m} vs {want_sum}");
}

#[test]
fn decoder_adam_step_matches_jax_autodiff() {
    let cfg = golden_dec();
    let mut w = golden_params(&cfg);
    let mut m: Vec<Vec<f32>> =
        cfg.params.iter().map(|s| vec![0.0; s.elements()]).collect();
    let mut v = m.clone();
    let loss = adam_step(&cfg, &mut w, &mut m, &mut v, &IDS, &MASK, &IDS,
                         2, 6, 1.0, 1e-3,
                         &mut model::Scratch::new())
        .unwrap();
    close(loss, 2.568_747_3, 3e-4, "adam dec loss");
    // tied embedding: grads flow into embed.tok row 0 via the LM head
    let want_p0: [f32; 4] =
        [-0.087_144_695, -0.011_034_067, 0.043_286_43, 0.092_042_83];
    for (i, want) in want_p0.into_iter().enumerate() {
        close(w[0][i], want, 2e-4, "adam dec p0");
    }
    let n = cfg.params.len();
    let want_plast: [f32; 4] =
        [-0.043_578_822, -0.054_226_268, 0.098_123_33, 0.067_177_21];
    let want_mlast: [f32; 4] = [
        0.002_094_867_4, -0.003_387_581_6, -0.001_208_957_6,
        0.003_870_208_5,
    ];
    for i in 0..4 {
        close(w[n - 1][i], want_plast[i], 2e-4, "adam dec plast");
        close(m[n - 1][i], want_mlast[i], 2e-4, "adam dec mlast");
    }
    let sum_m: f64 = m
        .iter()
        .flat_map(|t| t.iter())
        .map(|x| x.abs() as f64)
        .sum();
    let want_sum = 0.123_515_071;
    assert!((sum_m - want_sum).abs() < 1e-3 * want_sum.max(1.0),
            "sum|m| {sum_m} vs {want_sum}");
}
