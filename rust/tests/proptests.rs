//! Property-based tests over the coordinator substrates.
//!
//! The offline environment has no proptest crate, so this file carries a
//! tiny deterministic property harness (`for_cases`): N seeded random
//! cases per property, with the failing seed printed for reproduction.
//! Shrinking is traded for case volume — each property runs hundreds of
//! random cases.

use pocketllm::data::batcher::Batcher;
use pocketllm::data::bpe::Bpe;
use pocketllm::data::corpus::{self, Sample};
use pocketllm::device::memory::{finetune_footprint, Category, MemoryLedger};
use pocketllm::device::spec::preset;
use pocketllm::device::{ComputeModel, ModelDims, OptimizerFamily};
use pocketllm::optim::Schedule;
use pocketllm::util::json::{self, Json};
use pocketllm::util::rng::Rng;

/// Run `n` seeded cases of a property.
fn for_cases(n: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(HARNESS_SALT ^ seed);
        prop(&mut rng);
    }
}

// 0xP isn't valid rust — constant for the harness:
#[allow(dead_code)]
const HARNESS_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

// ---------------------------------------------------------------------
// memory ledger invariants
// ---------------------------------------------------------------------

#[test]
fn prop_ledger_never_exceeds_budget_and_balances() {
    for_cases(300, |rng| {
        let budget = 1 + rng.below(1 << 30) as u64;
        let mut ledger = MemoryLedger::new(budget);
        let mut shadow: Vec<(Category, u64)> = Vec::new();
        for _ in 0..rng.below(40) {
            let cat = *rng.choose(&Category::ALL);
            if rng.chance(0.6) || shadow.is_empty() {
                let bytes = rng.below(1 << 28) as u64;
                if ledger.alloc(cat, bytes).is_ok() {
                    shadow.push((cat, bytes));
                }
            } else {
                let i = rng.below(shadow.len());
                let (cat, bytes) = shadow.swap_remove(i);
                ledger.free(cat, bytes);
            }
            // invariants
            assert!(ledger.in_use() <= ledger.budget());
            assert!(ledger.peak() >= ledger.in_use());
            let sum: u64 =
                Category::ALL.iter().map(|&c| ledger.category(c)).sum();
            assert_eq!(sum, ledger.in_use());
        }
        // free everything -> exactly zero
        for (cat, bytes) in shadow.drain(..) {
            ledger.free(cat, bytes);
        }
        assert_eq!(ledger.in_use(), 0);
        assert_eq!(ledger.overfree_events(), 0);
    });
}

#[test]
fn prop_oom_iff_over_budget() {
    for_cases(300, |rng| {
        let budget = rng.below(1 << 30) as u64;
        let mut ledger = MemoryLedger::new(budget);
        let req = rng.below(1 << 31) as u64;
        let fits = req <= budget;
        assert_eq!(ledger.alloc(Category::Workspace, req).is_ok(), fits);
        assert_eq!(ledger.oom_events(), (!fits) as u64);
    });
}

// ---------------------------------------------------------------------
// footprint model properties (the Table 1 mechanism)
// ---------------------------------------------------------------------

fn random_dims(rng: &mut Rng) -> ModelDims {
    let d = 64 << rng.below(5); // 64..1024
    ModelDims {
        name: "prop".into(),
        vocab: 512 + rng.below(50_000),
        d_model: d,
        n_layers: 1 + rng.below(30),
        n_heads: [1, 2, 4, 8][rng.below(4)],
        d_ff: d * 4,
        max_seq: 16 << rng.below(5),
        decoder: rng.chance(0.5),
        param_bytes: if rng.chance(0.5) { 2 } else { 4 },
    }
}

#[test]
fn prop_mezo_footprint_never_exceeds_adam() {
    for_cases(200, |rng| {
        let dims = random_dims(rng);
        let b = 1 + rng.below(128);
        let s = 8 + rng.below(512);
        let m = finetune_footprint(&dims, OptimizerFamily::DerivativeFree,
                                   b, s);
        let a = finetune_footprint(&dims, OptimizerFamily::DerivativeBased,
                                   b, s);
        assert!(m.total() <= a.total(),
                "mezo {} > adam {} for {dims:?} b={b} s={s}",
                m.total(), a.total());
        // and the structural zeros hold
        assert_eq!(m.gradients, 0);
        assert_eq!(m.optimizer_state, 0);
    });
}

#[test]
fn prop_footprints_monotone_in_batch_and_seq() {
    for_cases(150, |rng| {
        let dims = random_dims(rng);
        let b = 1 + rng.below(64);
        let s = 8 + rng.below(256);
        for fam in [OptimizerFamily::DerivativeFree,
                    OptimizerFamily::DerivativeBased] {
            let base = finetune_footprint(&dims, fam, b, s).total();
            let bigger_b = finetune_footprint(&dims, fam, b * 2, s).total();
            let bigger_s = finetune_footprint(&dims, fam, b, s * 2).total();
            assert!(bigger_b >= base);
            assert!(bigger_s >= base);
        }
    });
}

// ---------------------------------------------------------------------
// compute model properties (the Table 2 mechanism)
// ---------------------------------------------------------------------

#[test]
fn prop_step_time_positive_and_sublinear_in_batch() {
    for_cases(100, |rng| {
        let dims = random_dims(rng);
        let name = *rng.choose(pocketllm::device::spec::preset_names());
        let cm = ComputeModel::new(preset(name).unwrap());
        let b = 1 + rng.below(64);
        let s = 8 + rng.below(256);
        for fam in [OptimizerFamily::DerivativeFree,
                    OptimizerFamily::DerivativeBased] {
            let t1 = cm.step_time(&dims, fam, b, s).total_s();
            let t2 = cm.step_time(&dims, fam, b * 8, s).total_s();
            assert!(t1 > 0.0 && t1.is_finite());
            // 8x batch must cost at most 8x time (utilization saturates)
            assert!(t2 <= t1 * 8.0 + 1e-9, "{name}: {t1} -> {t2}");
            assert!(t2 >= t1, "more work cannot be faster");
        }
    });
}

#[test]
fn prop_utilization_bounded() {
    for_cases(100, |rng| {
        let name = *rng.choose(pocketllm::device::spec::preset_names());
        let cm = ComputeModel::new(preset(name).unwrap());
        let b = 1 + rng.below(100_000);
        let u = cm.utilization(b);
        assert!(u > 0.0 && u < 1.0);
    });
}

// ---------------------------------------------------------------------
// JSON codec: random documents round-trip
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            // grid of integers and dyadic fractions survives f64 exactly
            Json::Num(rng.range(-1_000_000, 1_000_000) as f64
                      + [0.0, 0.5, 0.25][rng.below(3)])
        }
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32; // printable ascii
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for_cases(500, |rng| {
        let v = random_json(rng, 3);
        let text = v.dump();
        let back = json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse failed: {e} on {text}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}

// ---------------------------------------------------------------------
// BPE: random word corpora round-trip
// ---------------------------------------------------------------------

fn random_word(rng: &mut Rng) -> String {
    let len = 1 + rng.below(10);
    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

#[test]
fn prop_bpe_roundtrips_any_ascii_words() {
    for_cases(40, |rng| {
        let vocab_words: Vec<String> =
            (0..20).map(|_| random_word(rng)).collect();
        let corpus: Vec<String> = (0..50)
            .map(|_| {
                (0..1 + rng.below(8))
                    .map(|_| rng.choose(&vocab_words).clone())
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect();
        let bpe = Bpe::train(&corpus, 260 + rng.below(200));
        // in-vocabulary text
        let text = corpus[rng.below(corpus.len())].clone();
        assert_eq!(bpe.decode(&bpe.encode(&text)), text);
        // out-of-vocabulary text still round-trips (byte fallback)
        let novel = format!("{} {}", random_word(rng), random_word(rng));
        assert_eq!(bpe.decode(&bpe.encode(&novel)), novel);
        // save/load preserves the encoding function
        let restored = Bpe::load(&bpe.save()).unwrap();
        assert_eq!(bpe.encode(&text), restored.encode(&text));
    });
}

// ---------------------------------------------------------------------
// batcher: geometry and masking invariants under random shapes
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_mask_matches_pad() {
    let texts = corpus::tokenizer_corpus(1, 100);
    let bpe = Bpe::train(&texts, 300);
    for_cases(60, |rng| {
        let n = 4 + rng.below(60);
        let samples: Vec<Sample> = {
            let mut r2 = rng.fork(1);
            (0..n)
                .map(|_| corpus::sentiment_sample(&mut r2))
                .collect()
        };
        let batch = 1 + rng.below(8);
        let seq = 8 + rng.below(24);
        let lm = rng.chance(0.3);
        let mut b = Batcher::new(&bpe, &samples, batch, seq, lm, 512,
                                 rng.next_u64());
        for _ in 0..3 {
            let out = b.next();
            assert_eq!(out.ids.len(), batch * seq);
            assert_eq!(out.mask.len(), batch * seq);
            assert_eq!(out.labels.len(),
                       if lm { batch * seq } else { batch });
            for (i, &id) in out.ids.iter().enumerate() {
                let live = out.mask[i] > 0.0;
                assert_eq!(live, id != 0, "mask/pad mismatch at {i}");
                assert!(id >= 0 && (id as usize) < 512);
            }
            // every row starts with BOS
            for r in 0..batch {
                assert_eq!(out.ids[r * seq], 1);
            }
            assert!(out.density() > 0.0);
        }
    });
}

// ---------------------------------------------------------------------
// schedules: output always within the hull of endpoints
// ---------------------------------------------------------------------

#[test]
fn prop_schedule_bounded() {
    for_cases(200, |rng| {
        let a = rng.next_f64();
        let b = rng.next_f64();
        let steps = 1 + rng.below(1000) as u64;
        let lo = a.min(b);
        let hi = a.max(b);
        let lin = Schedule::Linear { start: a, end: b, steps };
        let cos = Schedule::WarmupCosine {
            peak: hi,
            floor: lo,
            warmup: steps / 4,
            total: steps,
        };
        for probe in 0..20 {
            let t = (probe * (steps + 10)) / 20;
            let v = lin.at(t);
            assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
            // warmup ramps from ~0, so the cosine hull is [0, peak]
            let v = cos.at(t);
            assert!(v >= -1e-12 && v <= hi + 1e-12);
        }
    });
}

// ---------------------------------------------------------------------
// rng: fork independence, shuffle preserves multiset
// ---------------------------------------------------------------------

#[test]
fn prop_fork_streams_diverge() {
    for_cases(100, |rng| {
        let mut a = rng.fork(1);
        let mut b = rng.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    });
}

#[test]
fn prop_shuffle_is_permutation() {
    for_cases(100, |rng| {
        let n = 1 + rng.below(200);
        let mut v: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
    });
}

// ---------------------------------------------------------------------
// fleet EDF queue: deadline order with FIFO tie-breaking
// ---------------------------------------------------------------------

use pocketllm::coordinator::fleet::QueueKey;
use pocketllm::runtime::native::math;

// ---------------------------------------------------------------------
// blocked kernels: bit-identical to the naive references over ragged
// shapes (non-multiples of the KC/NC/TB block sizes, degenerate 1xN /
// Mx1 / empty extents) and under varied pool-worker registrations
// ---------------------------------------------------------------------

/// Random values spanning magnitudes so reassociation WOULD show up as
/// a bit difference if a kernel reordered its per-element reduction.
fn random_tensor(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            let v = (rng.next_f32() * 2.0 - 1.0)
                * [1.0, 1e-3, 1e3][rng.below(3)];
            if rng.chance(0.02) { 0.0 } else { v }
        })
        .collect()
}

/// Ragged extent: mostly off-block sizes, with the degenerate 0 and 1
/// extents drawn often enough to pin the edge paths.
fn ragged(rng: &mut Rng, hi: usize) -> usize {
    match rng.below(10) {
        0 => 0,
        1 => 1,
        // straddle the 64-wide KC/NC panel boundary
        2 => 63 + rng.below(3),
        _ => 1 + rng.below(hi),
    }
}

#[test]
fn prop_blocked_matmul_bit_identical_to_reference() {
    for_cases(150, |rng| {
        let (m, k, n) = (ragged(rng, 20), ragged(rng, 70), ragged(rng, 70));
        let a = random_tensor(rng, m * k);
        let b = random_tensor(rng, k * n);
        let mut blocked = random_tensor(rng, m * n); // += semantics
        let mut naive = blocked.clone();
        math::matmul_into(&a, &b, m, k, n, &mut blocked);
        math::reference::matmul_into(&a, &b, m, k, n, &mut naive);
        assert_eq!(blocked, naive, "m={m} k={k} n={n}");
    });
}

#[test]
fn prop_blocked_matmul_bias_bit_identical_to_reference() {
    for_cases(150, |rng| {
        let (m, k, n) = (ragged(rng, 20), ragged(rng, 70), ragged(rng, 70));
        let a = random_tensor(rng, m * k);
        let b = random_tensor(rng, k * n);
        let bias = random_tensor(rng, n);
        // overwrite semantics: stale contents must not leak through
        let mut blocked = vec![f32::NAN; m * n];
        let mut naive = vec![f32::NAN; m * n];
        math::matmul_bias_into(&a, &b, &bias, m, k, n, &mut blocked);
        math::reference::matmul_bias_into(&a, &b, &bias, m, k, n,
                                          &mut naive);
        assert!(blocked.iter().zip(&naive)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "m={m} k={k} n={n}");
    });
}

#[test]
fn prop_blocked_matmul_at_bit_identical_to_reference() {
    for_cases(150, |rng| {
        let (m, k, n) = (ragged(rng, 40), ragged(rng, 40), ragged(rng, 70));
        let a = random_tensor(rng, m * k);
        let b = random_tensor(rng, m * n);
        let mut blocked = random_tensor(rng, k * n); // += semantics
        let mut naive = blocked.clone();
        math::matmul_at_into(&a, &b, m, k, n, &mut blocked);
        math::reference::matmul_at_into(&a, &b, m, k, n, &mut naive);
        assert_eq!(blocked, naive, "m={m} k={k} n={n}");
    });
}

#[test]
fn prop_blocked_matmul_bt_bit_identical_to_reference() {
    for_cases(150, |rng| {
        let (m, n, k) = (ragged(rng, 20), ragged(rng, 70), ragged(rng, 20));
        let a = random_tensor(rng, m * n);
        let b = random_tensor(rng, k * n);
        let mut blocked = vec![f32::NAN; m * k]; // overwrite semantics
        let mut naive = vec![f32::NAN; m * k];
        math::matmul_bt_into(&a, &b, m, n, k, &mut blocked);
        math::reference::matmul_bt_into(&a, &b, m, n, k, &mut naive);
        assert!(blocked.iter().zip(&naive)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "m={m} n={n} k={k}");
    });
}

#[test]
fn prop_blocked_col_sums_bit_identical_to_reference() {
    for_cases(200, |rng| {
        let (rows, n) = (ragged(rng, 30), ragged(rng, 70));
        let a = random_tensor(rng, rows * n);
        let mut blocked = random_tensor(rng, n); // += semantics
        let mut naive = blocked.clone();
        math::col_sums_into(&a, n, &mut blocked);
        math::reference::col_sums_into(&a, n, &mut naive);
        assert_eq!(blocked, naive, "rows={rows} n={n}");
    });
}

#[test]
fn prop_worker_count_never_changes_kernel_bits() {
    // Above PAR_FLOPS the kernels split across n_threads() row chunks;
    // registering pool workers shrinks that budget.  Neither the
    // threaded split nor the worker registration may change a single
    // output bit versus the serial references.
    let mut rng = Rng::new(HARNESS_SALT ^ 0xC0_FFEE);
    let (m, k, n) = (96, 130, 190); // ragged, > 2^21 flops
    let a = random_tensor(&mut rng, m * k);
    let b = random_tensor(&mut rng, k * n);
    let bias = random_tensor(&mut rng, n);
    let mut want = vec![0f32; m * n];
    math::reference::matmul_into(&a, &b, m, k, n, &mut want);
    let mut want_bias = vec![0f32; m * n];
    math::reference::matmul_bias_into(&a, &b, &bias, m, k, n,
                                      &mut want_bias);
    let bm = random_tensor(&mut rng, m * n); // [m,n] operand for a^T @ bm
    let mut want_at = vec![0f32; k * n];
    math::reference::matmul_at_into(&a, &bm, m, k, n, &mut want_at);
    let mut want_bt = vec![0f32; m * k];
    math::reference::matmul_bt_into(&bm, &b, m, n, k, &mut want_bt);
    for workers in [0, 1, 2, 7] {
        let _guard = (workers > 0)
            .then(|| math::register_pool_workers(workers));
        let mut got = vec![0f32; m * n];
        math::matmul_into(&a, &b, m, k, n, &mut got);
        assert_eq!(got, want, "matmul under {workers} workers");
        let mut got = vec![0f32; m * n];
        math::matmul_bias_into(&a, &b, &bias, m, k, n, &mut got);
        assert_eq!(got, want_bias, "matmul_bias under {workers} workers");
        let mut got = vec![0f32; k * n];
        math::matmul_at_into(&a, &bm, m, k, n, &mut got);
        assert_eq!(got, want_at, "matmul_at under {workers} workers");
        let mut got = vec![0f32; m * k];
        math::matmul_bt_into(&bm, &b, m, n, k, &mut got);
        assert_eq!(got, want_bt, "matmul_bt under {workers} workers");
    }
}

#[test]
fn prop_edf_queue_pops_by_deadline_then_fifo() {
    use std::cmp::Ordering;
    for_cases(200, |rng| {
        // few distinct deadlines over many keys = heavy tie pressure;
        // a quarter of the jobs are best-effort (INFINITY)
        let n = 1 + rng.below(64) as u64;
        let mut q: std::collections::BTreeMap<QueueKey, u64> =
            std::collections::BTreeMap::new();
        for seq in 0..n {
            let deadline = if rng.chance(0.25) {
                f64::INFINITY
            } else {
                (1 + rng.below(4)) as f64 * 15.0
            };
            q.insert(QueueKey { deadline, seq }, seq);
        }
        assert_eq!(q.len(), n as usize,
                   "seq must keep every key unique");
        let popped: Vec<QueueKey> =
            std::iter::from_fn(|| q.pop_first().map(|(k, _)| k))
                .collect();
        for w in popped.windows(2) {
            match w[0].deadline.total_cmp(&w[1].deadline) {
                Ordering::Less => {}
                Ordering::Equal => assert!(
                    w[0].seq < w[1].seq,
                    "equal deadlines must dispatch FIFO: {:?} then \
                     {:?}",
                    w[0], w[1]
                ),
                Ordering::Greater => panic!(
                    "later deadline dispatched first: {:?} then {:?}",
                    w[0], w[1]
                ),
            }
        }
        // best-effort jobs form a contiguous FIFO tail
        if let Some(first_inf) = popped
            .iter()
            .position(|k| k.deadline.is_infinite())
        {
            assert!(
                popped[first_inf..]
                    .iter()
                    .all(|k| k.deadline.is_infinite()),
                "a real deadline sorted after best-effort"
            );
        }
    });
}

// ---------------------------------------------------------------------
// split tuning invariants
// ---------------------------------------------------------------------

#[test]
fn prop_split_footprint_never_exceeds_local_mezo() {
    // The inequality the mode policy (and BENCH_link.json's headline)
    // trades on: at ANY geometry and storage precision, split tuning
    // keeps no more bytes resident than local MeZO — same single-
    // forward live set, minus the server-side side module.
    for_cases(300, |rng| {
        let d = 8 * (1 + rng.below(64));
        let dims = ModelDims {
            name: "prop".into(),
            vocab: 64 + rng.below(5000),
            d_model: d,
            n_layers: 1 + rng.below(12),
            n_heads: 1 + rng.below(8),
            d_ff: d * (1 + rng.below(4)),
            max_seq: 16 + rng.below(240),
            decoder: false,
            param_bytes: *rng.choose(&[1u64, 2, 4]),
        };
        let batch = 1 + rng.below(64);
        let seq = 8 + rng.below(120);
        let local = finetune_footprint(
            &dims, OptimizerFamily::DerivativeFree, batch, seq);
        let split = finetune_footprint(
            &dims, OptimizerFamily::SplitForward, batch, seq);
        assert!(split.total() <= local.total(),
                "split resident {} > local {} at {dims:?}",
                split.total(), local.total());
        // identical single-forward live set; the saving is exactly the
        // shipped side module's parameter bytes
        assert_eq!(split.activations, local.activations);
        assert_eq!(split.gradients, 0);
        assert_eq!(split.optimizer_state, 0);
        assert!(split.parameters <= local.parameters);
    });
}

#[test]
fn prop_link_trace_is_stateless_and_round_trips_conserve() {
    use pocketllm::link::{LinkSpec, LinkTrace};
    for_cases(200, |rng| {
        let code = *rng.choose(&[0u8, 1, 2, 3, 4]);
        let spec = LinkSpec::from_code(code).unwrap();
        let seed = rng.below(1 << 30) as u64;
        let t = LinkTrace::new(spec.clone(), seed);
        // stateless: sampling any window twice, in any order, from a
        // clone, is bit-identical
        let i = rng.below(500) as u64;
        let j = rng.below(500) as u64;
        let (wi, wj) = (t.window(i), t.window(j));
        assert_eq!(t.window(j), wj);
        assert_eq!(t.window(i), wi);
        assert_eq!(LinkTrace::new(spec.clone(), seed).window(i), wi);
        // conservation: a round trip never moves more than requested,
        // never takes less than two latencies, and bills energy
        // proportional to bytes actually moved
        let up = rng.below(1 << 20) as u64;
        let down = rng.below(1 << 16) as u64;
        let x = t.round_trip(&wi, up, down);
        assert!(x.bytes_moved <= up + down);
        assert!(x.seconds >= 2.0 * spec.latency_s - 1e-12);
        assert!((x.wh - x.bytes_moved as f64 * spec.wh_per_byte).abs()
                < 1e-12);
        assert_eq!(x.dropped, wi.drop_at.is_some());
        if !x.dropped {
            assert_eq!(x.bytes_moved, up + down);
        }
    });
}

// ---------------------------------------------------------------------
// log2 latency histograms (telemetry::hist)
// ---------------------------------------------------------------------

#[test]
fn prop_histogram_merge_is_order_invariant() {
    use pocketllm::telemetry::LogHistogram;
    // the fleet folds per-worker histograms in job order, but the
    // determinism contract wants the fold to be a free monoid: any
    // partition of the value stream into any number of shards, merged
    // in any order, must equal recording sequentially into one
    for_cases(150, |rng| {
        let n = rng.below(400);
        let values: Vec<u64> = (0..n)
            .map(|_| match rng.below(4) {
                0 => rng.below(1000) as u64,
                1 => rng.next_u64() >> rng.below(64),
                2 => 1u64 << rng.below(64),
                _ => rng.next_u64(),
            })
            .collect();
        let mut oracle = LogHistogram::new();
        for &v in &values {
            oracle.record(v);
        }
        for &shards in &[1usize, 2, 4, 7] {
            let mut parts = vec![LogHistogram::new(); shards];
            for &v in &values {
                parts[rng.below(shards)].record(v);
            }
            rng.shuffle(&mut parts);
            let mut merged = LogHistogram::new();
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged, oracle,
                       "merge of {shards} shards diverged");
        }
    });
}

#[test]
fn prop_histogram_summary_stats_hold() {
    use pocketllm::telemetry::LogHistogram;
    for_cases(150, |rng| {
        let n = 1 + rng.below(200);
        let values: Vec<u64> = (0..n)
            .map(|_| rng.next_u64() >> rng.below(64))
            .collect();
        let mut h = LogHistogram::new();
        for &v in &values {
            h.record(v);
        }
        let lo = *values.iter().min().unwrap();
        let hi = *values.iter().max().unwrap();
        assert_eq!(h.count(), n as u64);
        assert_eq!(h.min(), Some(lo));
        assert_eq!(h.max(), Some(hi));
        assert_eq!(h.sum(),
                   values.iter().map(|&v| v as u128).sum::<u128>());
        // percentiles are bucket-floor approximations clamped into
        // [min, max]; p0+ and p100 still pin the exact extremes'
        // buckets, and every percentile is monotone in p
        let mut prev = 0u64;
        for i in 0..=20 {
            let p = i as f64 / 20.0;
            let q = h.percentile(p);
            assert!(q >= lo && q <= hi,
                    "percentile({p}) = {q} outside [{lo}, {hi}]");
            assert!(q >= prev, "percentile not monotone at p={p}");
            prev = q;
        }
    });
}

#[test]
fn prop_histogram_bucket_edges() {
    use pocketllm::telemetry::hist::{LogHistogram, BUCKETS};
    // the edge cases that break naive log2 bucketing: 0 (no leading
    // zero math), u64::MAX (top bucket), and exact powers of two
    // (must land in the bucket whose floor IS the value)
    let mut h = LogHistogram::new();
    h.record(0);
    h.record(u64::MAX);
    for k in 0..64 {
        h.record(1u64 << k);
    }
    assert_eq!(h.count(), 66);
    assert_eq!(h.min(), Some(0));
    assert_eq!(h.max(), Some(u64::MAX));
    assert_eq!(h.counts()[0], 1, "0 gets the dedicated first bucket");
    assert_eq!(h.counts()[BUCKETS - 1], 2,
               "2^63 and u64::MAX share the top bucket");
    for k in 0..64usize {
        assert!(h.counts()[k + 1] >= 1,
                "2^{k} missing from bucket {}", k + 1);
    }
    for_cases(200, |rng| {
        let k = rng.below(64);
        let v = 1u64 << k;
        let mut h = LogHistogram::new();
        h.record(v);
        let idx =
            h.counts().iter().position(|&c| c > 0).unwrap();
        // bucket floor of an exact power of two is the value itself
        assert_eq!(idx, k + 1);
        assert_eq!(h.percentile(0.5), v,
                   "single power-of-two value must be exact");
    });
}
