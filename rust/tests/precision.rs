//! Precision-polymorphic tensor API: the fp16/int8 parity harness.
//!
//! Contract (pattern of `native_golden.rs`'s tolerance pins):
//!
//! * `Precision::F32` sessions are **bit-identical** to the default
//!   (pre-precision-API) trajectories — same path, no conversion
//!   anywhere.
//! * An fp16 session must *track* the f32 golden trajectory within
//!   documented tolerances: parameters are stored at ~2^-11 relative
//!   rounding between steps, so per-step losses stay within
//!   `F16_LOSS_TOL` of the f32 run while trajectories slowly diverge
//!   (they must still both descend / stay finite).
//! * The native in-place path and the literal `run()` bridge must be
//!   bit-identical *to each other* at every precision (both dequantize
//!   with the same decode and re-quantize with the same rounding).
//! * fp16 resident parameter bytes are exactly half the f32 run's.

use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Precision, Runtime};
use pocketllm::tuner::session::SessionBuilder;

fn runtime() -> Runtime {
    let m = Manifest::load_or_builtin("artifacts/manifest.json")
        .expect("manifest");
    Runtime::new(m).expect("native runtime")
}

/// Max per-step |loss_f16 - loss_f32| on pocket-tiny.  fp16 parameter
/// rounding is ~5e-4 relative; through the loss it stays ~1e-3, with
/// slow trajectory drift on top.  An order of magnitude of headroom
/// keeps the pin meaningful without being flaky.
const F16_LOSS_TOL: f64 = 0.05;

fn run_losses(
    rt: &Runtime,
    config: &str,
    opt: OptimizerKind,
    precision: Precision,
    compat: bool,
    steps: usize,
) -> (Vec<f64>, Vec<u8>, u64) {
    let mut s = SessionBuilder::new(rt, config)
        .optimizer(opt)
        .seed(77)
        .precision(precision)
        .compat_exec(compat)
        .build()
        .unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(s.step().unwrap().loss);
    }
    let bytes = s.params().unwrap().to_bytes().unwrap();
    (losses, bytes, s.resident_param_bytes())
}

#[test]
fn f32_precision_is_bit_identical_to_default() {
    let rt = runtime();
    let explicit = run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                              Precision::F32, false, 5);
    let mut default_s = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(77)
        .build()
        .unwrap();
    let default_losses: Vec<f64> =
        (0..5).map(|_| default_s.step().unwrap().loss).collect();
    assert_eq!(explicit.0, default_losses,
               "F32 precision must not change the trajectory");
    assert_eq!(explicit.1,
               default_s.params().unwrap().to_bytes().unwrap());
}

#[test]
fn f16_session_tracks_f32_golden_trajectory() {
    let rt = runtime();
    let steps = 6;
    let (golden, _, bytes_f32) =
        run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                   Precision::F32, false, steps);
    let (half, _, bytes_f16) =
        run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                   Precision::F16, false, steps);
    for (i, (g, h)) in golden.iter().zip(&half).enumerate() {
        assert!(h.is_finite(), "step {i}: fp16 loss not finite");
        assert!((g - h).abs() < F16_LOSS_TOL,
                "step {i}: fp16 loss {h} drifted from f32 golden {g}");
    }
    // the acceptance pin: resident parameter bytes exactly halve
    assert_eq!(bytes_f16 * 2, bytes_f32,
               "fp16 residency must be exactly half of f32");
}

#[test]
fn f16_adam_session_tracks_f32_and_descends() {
    let rt = runtime();
    let steps = 8;
    let (golden, _, _) =
        run_losses(&rt, "pocket-tiny-fast", OptimizerKind::Adam,
                   Precision::F32, false, steps);
    let (half, _, _) =
        run_losses(&rt, "pocket-tiny-fast", OptimizerKind::Adam,
                   Precision::F16, false, steps);
    for (i, (g, h)) in golden.iter().zip(&half).enumerate() {
        assert!((g - h).abs() < F16_LOSS_TOL,
                "step {i}: adam fp16 {h} vs f32 {g}");
    }
    assert!(half.last().unwrap() < &half[0],
            "fp16 adam must still descend: {half:?}");
}

#[test]
fn in_place_and_bridge_paths_agree_at_every_precision() {
    // the donation path and the literal run() bridge share the same
    // dequantize/requantize functions, so they must stay bit-identical
    // at EVERY precision, not just f32
    let rt = runtime();
    for precision in Precision::ALL {
        let a = run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                           precision, false, 4);
        let b = run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                           precision, true, 4);
        assert_eq!(a.0, b.0,
                   "{precision}: loss trajectories must match");
        assert_eq!(a.1, b.1,
                   "{precision}: parameter bytes must match");
    }
}

#[test]
fn int8_session_runs_end_to_end() {
    // int8 is lossy (per-step scale recompute) but must stay finite
    // and keep the smallest residency
    let rt = runtime();
    let (losses, _, bytes_i8) =
        run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                   Precision::Int8, false, 4);
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    let (_, _, bytes_f32) =
        run_losses(&rt, "pocket-tiny", OptimizerKind::MeZo,
                   Precision::F32, false, 1);
    assert!(bytes_i8 < bytes_f32 / 3,
            "int8 {bytes_i8} vs f32 {bytes_f32}");
}

#[test]
fn f16_checkpoint_restore_is_bit_exact() {
    // f16 decode is exact and re-encodes to identical bits, so a
    // checkpoint written by an fp16 session restores losslessly and
    // replays the identical tail
    let rt = runtime();
    let dir = std::env::temp_dir().join("pocketllm_f16_ckpt.plsi");
    let _ = std::fs::remove_file(&dir);

    let build = || {
        SessionBuilder::new(&rt, "pocket-tiny")
            .optimizer(OptimizerKind::MeZo)
            .seed(91)
            .precision(Precision::F16)
            .build()
            .unwrap()
    };
    let mut a = build();
    let mut ref_losses = Vec::new();
    for _ in 0..6 {
        ref_losses.push(a.step().unwrap().loss);
    }

    let mut b = build();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(b.step().unwrap().loss);
    }
    let img = b.snapshot_image(*got.last().unwrap()).unwrap();
    let expected_resident = b.resident_param_bytes();
    pocketllm::tuner::checkpoint::Checkpoint::save(&dir, img).unwrap();
    drop(b);

    let ck =
        pocketllm::tuner::checkpoint::Checkpoint::open(&dir).unwrap();
    // the image records the precision AND stores f16 bytes on disk
    assert_eq!(ck.precision, Precision::F16);
    assert_eq!(ck.image().unwrap().param_bytes(), expected_resident,
               "on-disk param payload must equal the f16 residency");
    let mut c = build();
    c.restore(&ck).unwrap();
    assert_eq!(c.resident_param_bytes(), expected_resident,
               "restored session must keep f16 residency (the \
                silently-widens-to-f32 satellite bug)");
    for _ in 0..3 {
        got.push(c.step().unwrap().loss);
    }
    assert_eq!(got, ref_losses,
               "fp16 resume must replay the identical loss sequence");
}

#[test]
fn f16_device_ledger_charges_half_the_parameter_bytes() {
    use pocketllm::device::{Category, Device};
    let rt = runtime();
    let charged = |p: Precision| -> u64 {
        let s = SessionBuilder::new(&rt, "pocket-tiny")
            .device(Device::preset("oppo-reno6").unwrap())
            .precision(p)
            .build()
            .unwrap();
        s.device
            .as_ref()
            .unwrap()
            .ledger
            .category(Category::Parameters)
    };
    let f32b = charged(Precision::F32);
    let f16b = charged(Precision::F16);
    let i8b = charged(Precision::Int8);
    assert_eq!(f16b * 2, f32b,
               "simulated ledger must charge the storage byte-width");
    assert_eq!(i8b * 4, f32b);
}
