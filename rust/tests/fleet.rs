//! Fleet determinism contract: `FleetScheduler` results must be
//! bit-identical for any worker count and identical to the sequential
//! `Coordinator::run_queue` oracle — losses, events, job statuses,
//! metrics.  Thread timing may reorder *work*, never *results*.

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, Event,
                             FleetConfig, FleetScheduler, JobSpec,
                             JobStatus};
use pocketllm::data::task::TaskKind;
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::scheduler::Policy;

fn runtime() -> Runtime {
    let m = Manifest::load_or_builtin("artifacts/manifest.json")
        .expect("manifest");
    Runtime::new(m).expect("native runtime")
}

fn mixed_jobs() -> Vec<JobSpec> {
    vec![
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(6)
            .seed(11),
        JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                     OptimizerKind::Adam)
            .steps(4)
            .seed(12),
        JobSpec::new("pocket-tiny", TaskKind::Rte, OptimizerKind::MeZo)
            .steps(8)
            .seed(13),
    ]
}

/// A worker-count-independent fingerprint of everything a fleet run
/// produces.  Debug formatting of f64 is shortest-roundtrip, so equal
/// strings mean bit-equal floats.
fn fingerprint(
    outcomes: &[pocketllm::coordinator::JobOutcome],
    events: &[Event],
    csv: &str,
) -> String {
    format!("{outcomes:?}\n===\n{events:?}\n===\n{csv}")
}

#[test]
fn fleet_matches_sequential_oracle_for_any_worker_count() {
    let rt = runtime();
    // overnight policy + 30-min ticks: the trace denies plenty of
    // daytime windows, so interleaving covers the deny path too
    let cfg = CoordinatorConfig {
        policy: Policy::overnight(),
        steps_per_window: 4,
        trace_step_minutes: 30.0,
        max_windows: 500,
        trace_seed: 3,
        ..Default::default()
    };
    let jobs = mixed_jobs();

    // the oracle: one job at a time, in order
    let mut oracle = Coordinator::new(&rt, cfg.clone());
    let oracle_outcomes = oracle.run_queue(&jobs).unwrap();
    let want = fingerprint(&oracle_outcomes, &oracle.events,
                           &oracle.metrics.to_csv());
    assert!(
        oracle_outcomes.iter().all(|o| o.status == JobStatus::Completed),
        "oracle jobs must complete: {oracle_outcomes:?}"
    );
    assert!(
        oracle_outcomes.iter().any(|o| o.windows_denied > 0),
        "trace must exercise denied windows"
    );

    for workers in [1usize, 2, 4] {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig { coord: cfg.clone(), workers,
                          ..FleetConfig::default() },
        );
        let report = fleet.run(&jobs).unwrap();
        let got = fingerprint(&report.outcomes, &report.events,
                              &report.metrics.to_csv());
        assert_eq!(got, want,
                   "fleet with {workers} workers diverged from the \
                    sequential oracle");
        // telemetry is derived from the same streams, so it is equally
        // pinned
        assert_eq!(report.telemetry.jobs, jobs.len());
        assert_eq!(report.telemetry.completed, jobs.len());
        assert_eq!(report.telemetry.completion_rate, 1.0);
        assert_eq!(
            report.telemetry.windows_denied,
            oracle_outcomes.iter().map(|o| o.windows_denied).sum::<usize>()
        );
        assert!(report.telemetry.sim_step_seconds > 0.0);
        let histogram_total: usize =
            report.telemetry.denied_by_reason.values().sum();
        assert_eq!(histogram_total, report.telemetry.windows_denied);
    }
}

#[test]
fn fleet_oom_fallback_fires_via_typed_downcast() {
    let rt = runtime();
    // an Adam job that must OOM on a 3 GB handset and fall back to
    // MeZO — the paper's headline event, at fleet scale and behind a
    // context()-wrapped error chain
    let cfg = CoordinatorConfig {
        device_preset: "budget-phone-3gb".into(),
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 50,
        ..Default::default()
    };
    let jobs = vec![
        JobSpec::new("pocket-roberta", TaskKind::Sst2,
                     OptimizerKind::Adam)
            .batch(64)
            .steps(4)
            .seed(21),
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(22),
    ];

    let mut oracle = Coordinator::new(&rt, cfg.clone());
    let oracle_outcomes = oracle.run_queue(&jobs).unwrap();
    assert_eq!(oracle_outcomes[0].optimizer, OptimizerKind::MeZo,
               "oracle must fall back from adam");

    let mut fingerprints = Vec::new();
    for workers in [1usize, 2] {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig { coord: cfg.clone(), workers,
                          ..FleetConfig::default() },
        );
        let report = fleet.run(&jobs).unwrap();
        assert_eq!(report.outcomes[0].optimizer, OptimizerKind::MeZo,
                   "fleet job 0 should have fallen back to \
                    derivative-free");
        assert_eq!(report.outcomes[0].status, JobStatus::Completed);
        assert_eq!(report.telemetry.oom_fallbacks, 1);
        assert!(report
            .events
            .iter()
            .any(|e| matches!(e, Event::OomFallback { job: 0, .. })));
        fingerprints.push(fingerprint(&report.outcomes, &report.events,
                                      &report.metrics.to_csv()));
    }
    assert_eq!(fingerprints[0], fingerprints[1]);
    assert_eq!(
        fingerprints[0],
        fingerprint(&oracle_outcomes, &oracle.events,
                    &oracle.metrics.to_csv())
    );
}

#[test]
fn fleet_metrics_are_per_job_series_in_job_order() {
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 20,
        ..Default::default()
    };
    let jobs: Vec<JobSpec> = (0..3)
        .map(|i| {
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(4)
                .seed(30 + i)
        })
        .collect();
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 3,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs).unwrap();
    for i in 0..3 {
        let s = report
            .metrics
            .get(&format!("job{i}.loss"))
            .unwrap_or_else(|| panic!("missing job{i}.loss series"));
        // 4 steps at 2 per window = 2 recorded points, steps 2 and 4
        assert_eq!(s.points.len(), 2);
        assert_eq!(s.points[0].0, 2);
        assert_eq!(s.points[1].0, 4);
        assert!(s.points.iter().all(|&(_, v)| v.is_finite()));
    }
    // and the CSV renders one row per distinct step across the fleet
    let csv = report.metrics.to_csv();
    assert_eq!(csv.lines().next().unwrap(),
               "step,job0.loss,job1.loss,job2.loss");
    assert_eq!(csv.lines().count(), 1 + 2);
}

#[test]
fn fleet_with_more_workers_than_jobs_is_fine() {
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 4,
        max_windows: 10,
        ..Default::default()
    };
    let jobs = vec![JobSpec::new("pocket-tiny", TaskKind::Sst2,
                                 OptimizerKind::MeZo)
        .steps(4)
        .seed(5)];
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 8,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs).unwrap();
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(report.outcomes[0].status, JobStatus::Completed);
    assert_eq!(report.telemetry.completion_rate, 1.0);
}

#[test]
fn budget_forced_hibernation_matches_unbounded_oracle() {
    // THE acceptance pin of the store subsystem: a fleet run whose
    // resident budget forces every queued job to hibernate (budget 0)
    // must produce byte-for-byte the oracle's outcomes/events/metrics
    // — for workers {1, 2, 4}, at f32, f16, AND int8, with an Adam
    // job in the mix so moments ride through the images too.
    use pocketllm::runtime::Precision;
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 100,
        ..Default::default()
    };
    for precision in [Precision::F32, Precision::F16, Precision::Int8]
    {
        let jobs: Vec<JobSpec> = vec![
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(6)
                .seed(61)
                .precision(precision)
                .deadline(600.0),
            JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                         OptimizerKind::Adam)
                .steps(4)
                .seed(62)
                .precision(precision),
            JobSpec::new("pocket-tiny", TaskKind::Rte,
                         OptimizerKind::MeZo)
                .steps(6)
                .seed(63)
                .precision(precision)
                .deadline(30.0),
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(4)
                .seed(64)
                .precision(precision),
        ];

        let mut oracle = Coordinator::new(&rt, cfg.clone());
        let oracle_outcomes = oracle.run_queue(&jobs).unwrap();
        let want = fingerprint(&oracle_outcomes, &oracle.events,
                               &oracle.metrics.to_csv());

        for workers in [1usize, 2, 4] {
            let fleet = FleetScheduler::new(
                &rt,
                FleetConfig {
                    coord: cfg.clone(),
                    workers,
                    // budget 0: every requeued job must hibernate
                    resident_budget_bytes: Some(0),
                    ..FleetConfig::default()
                },
            );
            let report = fleet.run(&jobs).unwrap();
            let got = fingerprint(&report.outcomes, &report.events,
                                  &report.metrics.to_csv());
            assert_eq!(got, want,
                       "{precision}, {workers} workers: hibernating \
                        fleet diverged from the resident oracle");
            assert!(report.telemetry.hibernations > 0,
                    "budget 0 must force hibernation");
            assert_eq!(report.telemetry.rehydrations,
                       report.telemetry.hibernations,
                       "every hibernated job must rehydrate");
            assert!(report.telemetry.store_bytes_spilled > 0,
                    "write-through store must hit disk");
        }
    }
}

#[test]
fn edf_queue_dispatches_earliest_deadline_first() {
    // one worker = deterministic dispatch order: deadlines 30 < 60 <
    // best-effort, regardless of queue position
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 4,
        max_windows: 20,
        ..Default::default()
    };
    let jobs = vec![
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(71), // best-effort, queued first
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(72)
            .deadline(60.0),
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(73)
            .deadline(30.0),
    ];
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 1,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs).unwrap();
    assert_eq!(report.first_dispatch, vec![2, 1, 0],
               "EDF must dispatch deadline 30, then 60, then \
                best-effort");
    // dispatch order is scheduling only — every job still completes
    assert_eq!(report.telemetry.completed, 3);
    // 4 steps in one always-admitted window at minute 10 < deadlines
    assert_eq!(report.telemetry.deadline_misses, 0);
}

#[test]
fn blown_deadlines_are_reported_not_fatal() {
    // overnight policy + daytime queue time: the first admitted
    // window is hours away, so a 30-minute deadline must be missed —
    // and identically in the oracle and the fleet
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::overnight(),
        steps_per_window: 4,
        trace_step_minutes: 30.0,
        max_windows: 500,
        trace_seed: 3,
        ..Default::default()
    };
    let jobs = vec![
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(81)
            .deadline(30.0), // hopeless under the overnight policy
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(4)
            .seed(82), // best-effort never "misses"
    ];
    let mut oracle = Coordinator::new(&rt, cfg.clone());
    let oracle_outcomes = oracle.run_queue(&jobs).unwrap();
    assert!(oracle_outcomes[0].deadline_missed,
            "30 simulated minutes cannot cover an overnight wait");
    assert!(!oracle_outcomes[1].deadline_missed);
    assert_eq!(oracle_outcomes[0].status, JobStatus::Completed,
               "a miss is telemetry, not failure");

    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 2,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs).unwrap();
    assert_eq!(
        fingerprint(&report.outcomes, &report.events,
                    &report.metrics.to_csv()),
        fingerprint(&oracle_outcomes, &oracle.events,
                    &oracle.metrics.to_csv())
    );
    assert_eq!(report.telemetry.deadline_misses, 1);
}

#[test]
fn fleet_stalled_jobs_are_counted_not_dropped() {
    let rt = runtime();
    // a policy no daytime trace can satisfy quickly + a 2-window cap:
    // the job must stall, and the fleet must report it
    let cfg = CoordinatorConfig {
        policy: Policy::overnight(),
        steps_per_window: 4,
        trace_step_minutes: 10.0,
        max_windows: 2,
        ..Default::default()
    };
    let jobs = vec![JobSpec::new("pocket-tiny", TaskKind::Sst2,
                                 OptimizerKind::MeZo)
        .steps(4)
        .seed(7)];
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 2,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs).unwrap();
    assert_eq!(report.outcomes[0].status, JobStatus::Stalled);
    assert_eq!(report.telemetry.stalled, 1);
    assert_eq!(report.telemetry.completed, 0);
    assert_eq!(report.telemetry.completion_rate, 0.0);
    assert_eq!(report.outcomes[0].windows_denied, 2,
               "both 09:00 daytime windows must be denied");
}

#[test]
fn trace_spans_are_bit_identical_for_any_worker_count() {
    // the tentpole pin of the tracing subsystem: a 16-job fleet's
    // span stream (and the histograms derived from it) must be
    // bit-identical for any worker count and identical to the
    // sequential oracle's — only the segregated `host_us` sidecars
    // (excluded from the fingerprint) may vary
    use pocketllm::telemetry::trace;
    let rt = runtime();
    let cfg = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 50,
        ..Default::default()
    };
    let jobs: Vec<JobSpec> = (0..16)
        .map(|i| {
            if i % 4 == 3 {
                JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                             OptimizerKind::Adam)
                    .steps(2)
                    .seed(42 + i as u64)
            } else {
                JobSpec::new("pocket-tiny", TaskKind::Sst2,
                             OptimizerKind::MeZo)
                    .steps(2)
                    .seed(42 + i as u64)
            }
        })
        .collect();

    let mut oracle = Coordinator::new(&rt, cfg.clone());
    oracle.run_queue(&jobs).unwrap();
    let want = trace::fingerprint(&oracle.spans);
    assert!(!want.is_empty(), "oracle must emit spans");

    let mut first_hists = None;
    for workers in [1usize, 2, 4] {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig { coord: cfg.clone(), workers,
                          ..FleetConfig::default() },
        );
        let report = fleet.run(&jobs).unwrap();
        assert_eq!(trace::fingerprint(&report.spans), want,
                   "{workers} workers: span stream diverged from \
                    the oracle");
        let t = &report.telemetry;
        assert_eq!(t.dispatch_latency_us.count(), 16,
                   "one dispatch span per job");
        assert!(!t.window_latency_us.is_empty(),
                "admitted windows must record latency");
        let hists = (
            t.dispatch_latency_us.clone(),
            t.window_latency_us.clone(),
            t.link_transfer_bytes.clone(),
        );
        match &first_hists {
            None => first_hists = Some(hists),
            Some(h) => assert_eq!(
                h, &hists,
                "{workers} workers: histograms diverged"
            ),
        }
    }
}
