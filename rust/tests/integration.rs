//! Integration tests: the full three-layer stack over the execution
//! backend.  These run hermetically on the default native backend —
//! `Manifest::builtin()` when no artifact directory exists, the real
//! AOT manifest when one does — so `cargo test` is self-contained.
//!
//! These are the tests that prove the layers *compose*: manifest →
//! backend compile → rust session loop → losses that behave like
//! Fig. 1 says they should.

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, Event, JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::device::{Device, ModelDims};
use pocketllm::optim::{OptimizerKind, Schedule};
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::scheduler::Policy;
use pocketllm::tuner::checkpoint::Checkpoint;
use pocketllm::tuner::session::SessionBuilder;

fn runtime() -> Runtime {
    let m = Manifest::load_or_builtin("artifacts/manifest.json")
        .expect("manifest");
    Runtime::new(m).expect("native runtime")
}

// ---------------------------------------------------------------------
// manifest / cross-language consistency
// ---------------------------------------------------------------------

#[test]
fn manifest_has_all_default_programs() {
    let rt = runtime();
    for cfg in ["pocket-tiny", "pocket-tiny-fast", "pocket-roberta",
                "pocket-opt"] {
        assert!(rt.manifest.configs.contains_key(cfg), "missing {cfg}");
        assert!(
            !rt.manifest.batches_for(cfg, "mezo_step").is_empty(),
            "no mezo_step for {cfg}"
        );
    }
    // the kernel-path config must NOT have an adam program (MeZO needs no
    // AD — that asymmetry is by design)
    assert!(rt.manifest.batches_for("pocket-tiny", "adam_step").is_empty());
}

#[test]
fn rust_param_formula_matches_python_manifest() {
    // ModelDims::n_params (used by the device model at 355M/1.3B scale)
    // must agree with the param_specs layout behind the manifest, for
    // every config we can cross-check.
    let rt = runtime();
    for (name, info) in &rt.manifest.configs {
        let dims = ModelDims {
            name: name.clone(),
            vocab: info.vocab,
            d_model: info.d_model,
            n_layers: info.n_layers,
            n_heads: info.n_heads,
            d_ff: info.d_ff,
            max_seq: info.max_seq,
            decoder: info.is_decoder(),
            param_bytes: 4,
        };
        assert_eq!(
            dims.n_params(),
            info.n_params as u64,
            "param-count formula diverged for {name}"
        );
    }
}

#[test]
fn init_params_load_and_match_manifest_shapes() {
    let rt = runtime();
    let raw = rt.manifest.load_init_params("pocket-tiny").unwrap();
    let cfg = rt.manifest.config("pocket-tiny").unwrap();
    assert_eq!(raw.len(), cfg.params.len());
    let total: usize = raw.iter().map(|t| t.len()).sum();
    assert_eq!(total, cfg.n_params);
}

// ---------------------------------------------------------------------
// program execution
// ---------------------------------------------------------------------

#[test]
fn eval_program_produces_logits() {
    let rt = runtime();
    let session = SessionBuilder::new(&rt, "pocket-tiny").build().unwrap();
    let acc = session.eval_accuracy().unwrap();
    assert!((0.0..=1.0).contains(&acc), "{acc}");
    let loss = session.eval_loss().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn mezo_is_deterministic_across_sessions() {
    let rt = runtime();
    let run = || {
        let mut s = SessionBuilder::new(&rt, "pocket-tiny")
            .optimizer(OptimizerKind::MeZo)
            .seed(99)
            .build()
            .unwrap();
        let stats = s.run_steps(3).unwrap();
        (stats.first_loss, stats.last_loss)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must give bit-identical trajectories");
}

#[test]
fn pallas_and_fast_paths_agree() {
    // pocket-tiny is the kernel-path config; pocket-tiny-fast the
    // XLA-native-op twin.  Same dims, same init, same seed — the
    // first-step loss must agree to fp32 tolerance on any backend.
    let rt = runtime();
    let loss_of = |config: &str| {
        let mut s = SessionBuilder::new(&rt, config)
            .optimizer(OptimizerKind::MeZo)
            .seed(5)
            .build()
            .unwrap();
        s.run_steps(1).unwrap().first_loss
    };
    let a = loss_of("pocket-tiny");
    let b = loss_of("pocket-tiny-fast");
    assert!((a - b).abs() < 5e-3, "pallas {a} vs fast {b}");
}

#[test]
fn adam_descends_fast_mezo_descends_slow() {
    // Fig. 1's qualitative claim on the real stack.
    let rt = runtime();
    let mut adam = SessionBuilder::new(&rt, "pocket-tiny-fast")
        .optimizer(OptimizerKind::Adam)
        .lr(Schedule::Constant(2e-3))
        .seed(7)
        .build()
        .unwrap();
    let a = adam.run_steps(30).unwrap();
    assert!(
        a.last_loss < a.first_loss * 0.9,
        "adam should descend: {} -> {}",
        a.first_loss,
        a.last_loss
    );

    let mut mezo = SessionBuilder::new(&rt, "pocket-tiny-fast")
        .optimizer(OptimizerKind::MeZo)
        .lr(Schedule::Constant(1e-3))
        .seed(7)
        .build()
        .unwrap();
    let m = mezo.run_steps(60).unwrap();
    // slow but directionally down over enough steps
    let head = mezo.metrics.get("loss").unwrap().head_mean(10);
    let tail = mezo.metrics.get("loss").unwrap().tail_mean(10);
    assert!(tail < head + 0.02, "mezo drifting up: {head} -> {tail}");
    let _ = m;
}

#[test]
fn decoder_lm_session_runs() {
    let rt = runtime();
    let mut s = SessionBuilder::new(&rt, "pocket-opt")
        .optimizer(OptimizerKind::MeZo)
        .seed(3)
        .build()
        .unwrap();
    assert_eq!(s.task, TaskKind::ChatLm, "decoders self-supervise");
    let stats = s.run_steps(2).unwrap();
    assert!(stats.last_loss.is_finite());
    // near ln(vocab) at init
    let chance = (s.cfg.vocab as f64).ln();
    assert!((stats.first_loss - chance).abs() < 0.3 * chance,
            "{} vs ln(V)={}", stats.first_loss, chance);
}

// ---------------------------------------------------------------------
// checkpoint / resume
// ---------------------------------------------------------------------

#[test]
fn checkpoint_resume_is_exact() {
    let rt = runtime();
    let path = std::env::temp_dir().join("pocketllm_it_ckpt.plsi");
    let _ = std::fs::remove_file(&path);

    // run 4 steps, checkpoint (single-file session image), run 2 more
    let mut a = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(11)
        .build()
        .unwrap();
    a.run_steps(4).unwrap();
    let a_params = a.params().unwrap();
    Checkpoint::save(&path, a.snapshot_image(0.0).unwrap()).unwrap();
    assert!(path.is_file(), "canonical checkpoints are ONE file");
    let params_at_4 = a_params.to_bytes().unwrap();
    let a6 = a.run_steps(2).unwrap().last_loss;

    // restore the checkpoint into a fresh session and run the same 2
    // steps — Session::restore fast-forwards the optimizer clock via
    // the deterministic (master_seed, step) schedule
    let ck = Checkpoint::open(&path).unwrap();
    assert_eq!(ck.master_seed, 11);
    let mut b = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(11)
        .build()
        .unwrap();
    b.restore(&ck).unwrap();
    assert_eq!(b.step, 4);
    let b6 = b.run_steps(2).unwrap().last_loss;
    assert_eq!(a6, b6, "resumed tail must be bit-identical");

    // and the checkpointed params themselves round-trip bit-exactly
    let ck2 = Checkpoint::open(&path).unwrap();
    let pb = ck2
        .load_params(rt.manifest.config("pocket-tiny").unwrap())
        .unwrap();
    assert_eq!(pb.to_bytes().unwrap(), params_at_4,
               "checkpoint params must round-trip bit-exactly");
}

#[test]
fn resume_reproduces_seed_and_loss_sequence_with_huge_master_seed() {
    // the satellite-bug regression: master seeds above 2^53 must survive
    // checkpoint JSON (string-serialized u64) AND the resumed session
    // must replay the identical seed/loss sequence
    let rt = runtime();
    let path = std::env::temp_dir().join("pocketllm_it_bigseed.plsi");
    let _ = std::fs::remove_file(&path);
    let big_seed = u64::MAX - 1;

    // uninterrupted reference run: 6 steps of losses
    let mut a = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(big_seed)
        .build()
        .unwrap();
    let mut ref_losses = Vec::new();
    for _ in 0..6 {
        ref_losses.push(a.step().unwrap().loss);
    }

    // interrupted run: 3 steps, checkpoint, restore, 3 more
    let mut b = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(big_seed)
        .build()
        .unwrap();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(b.step().unwrap().loss);
    }
    let img = b.snapshot_image(*got.last().unwrap()).unwrap();
    assert_eq!(img.master_seed, big_seed);
    Checkpoint::save(&path, img).unwrap();
    drop(b);

    let ck = Checkpoint::open(&path).unwrap();
    assert_eq!(ck.master_seed, big_seed,
               "seed must survive the image bytes");
    assert_eq!(ck.step, 3);
    let mut c = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(ck.master_seed)
        .build()
        .unwrap();
    c.restore(&ck).unwrap();
    for _ in 3..6 {
        got.push(c.step().unwrap().loss);
    }
    assert_eq!(got, ref_losses,
               "resumed run must replay the identical loss sequence");
}

// ---------------------------------------------------------------------
// device envelope + coordinator
// ---------------------------------------------------------------------

#[test]
fn session_charges_and_releases_device_memory() {
    let rt = runtime();
    let device = Device::preset("oppo-reno6").unwrap();
    let mut s = SessionBuilder::new(&rt, "pocket-tiny")
        .device(device)
        .build()
        .unwrap();
    let in_use = s.device.as_ref().unwrap().ledger.in_use();
    assert!(in_use > 0);
    s.run_steps(2).unwrap();
    s.close();
    assert_eq!(s.device.as_ref().unwrap().ledger.in_use(), 0);
}

#[test]
fn adam_ooms_on_budget_phone_and_coordinator_falls_back() {
    let rt = runtime();
    // direct admission: Adam on a 3 GB handset must OOM at this batch
    let device = Device::preset("budget-phone-3gb").unwrap();
    let err = SessionBuilder::new(&rt, "pocket-roberta")
        .optimizer(OptimizerKind::Adam)
        .batch_size(64)
        .device(device)
        .build();
    assert!(err.is_err(), "expected OOM admission failure");
    assert!(format!("{:#}", err.err().unwrap()).contains("OOM"));

    // the coordinator handles the same event by falling back to MeZO
    let cfg = CoordinatorConfig {
        device_preset: "budget-phone-3gb".into(),
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 50,
        ..Default::default()
    };
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new("pocket-roberta", TaskKind::Sst2,
                           OptimizerKind::Adam)
        .batch(64)
        .steps(4);
    let outcome = coord.run_job(0, &job).unwrap();
    assert_eq!(outcome.optimizer, OptimizerKind::MeZo,
               "coordinator should have fallen back to derivative-free");
    assert!(coord
        .events
        .iter()
        .any(|e| matches!(e, Event::OomFallback { .. })));
    assert_eq!(outcome.steps_done, 4);
}

#[test]
fn overnight_policy_gates_execution() {
    let rt = runtime();
    let cfg = CoordinatorConfig {
        device_preset: "oppo-reno6".into(),
        policy: Policy::overnight(),
        steps_per_window: 4,
        trace_step_minutes: 30.0,
        max_windows: 500,
        trace_seed: 3,
        ..Default::default()
    };
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                           OptimizerKind::MeZo)
        .steps(12);
    let outcome = coord.run_job(0, &job).unwrap();
    assert_eq!(outcome.steps_done, 12);
    assert!(outcome.windows_denied > 0,
            "a day trace must contain denied windows (screen-on daytime)");
}

// ---------------------------------------------------------------------
// literal plumbing against a real program
// ---------------------------------------------------------------------

#[test]
fn loss_eval_program_io_contract() {
    let rt = runtime();
    let prog = rt.program("pocket-tiny", "loss_eval", 4).unwrap();
    assert_eq!(prog.spec.outputs.len(), 1);
    let n_inputs = prog.spec.inputs.len();
    let cfg = rt.manifest.config("pocket-tiny").unwrap();
    assert_eq!(n_inputs, cfg.params.len() + 3); // params + ids/mask/labels

    // wrong arity must error, not crash
    let raw = rt.manifest.load_init_params("pocket-tiny").unwrap();
    let st = pocketllm::runtime::ModelState::from_raw(cfg, &raw).unwrap();
    let refs = st.refs();
    assert!(prog.execute(&refs).is_err());
}

#[test]
fn compiled_programs_are_cached() {
    let rt = runtime();
    let a = rt.program("pocket-tiny", "eval", 4).unwrap();
    let n = rt.compiled_count();
    let b = rt.program("pocket-tiny", "eval", 4).unwrap();
    assert_eq!(rt.compiled_count(), n);
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    let l = a.spec.outputs[0].elements();
    assert!(l > 0);
}

#[test]
fn model_state_roundtrip_through_real_config() {
    let rt = runtime();
    let cfg = rt.manifest.config("pocket-roberta").unwrap();
    let raw = rt.manifest.load_init_params("pocket-roberta").unwrap();
    let st = pocketllm::runtime::ModelState::from_raw(cfg, &raw).unwrap();
    let bytes = st.to_bytes().unwrap();
    assert_eq!(bytes.len(), cfg.n_params * 4);
    let st2 = pocketllm::runtime::ModelState::from_bytes(cfg, &bytes).unwrap();
    assert_eq!(st.tensors[0].f32_vec().unwrap(),
               st2.tensors[0].f32_vec().unwrap());
}

// ---------------------------------------------------------------------
// buffer-donation (run_in_place) vs literal (run) execution paths
// ---------------------------------------------------------------------

#[test]
fn in_place_and_run_paths_are_bit_identical_mezo() {
    // the donation path must change WHERE tensors live, never what the
    // step computes: identical loss sequences and identical final
    // parameter bytes
    let rt = runtime();
    let run_with = |compat: bool| {
        let mut s = SessionBuilder::new(&rt, "pocket-tiny")
            .optimizer(OptimizerKind::MeZo)
            .seed(21)
            .compat_exec(compat)
            .build()
            .unwrap();
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(s.step().unwrap().loss);
        }
        (losses, s.params().unwrap().to_bytes().unwrap())
    };
    let (l_inplace, p_inplace) = run_with(false);
    let (l_run, p_run) = run_with(true);
    assert_eq!(l_inplace, l_run, "loss trajectories must match");
    assert_eq!(p_inplace, p_run, "parameter bytes must match");
}

#[test]
fn in_place_and_run_paths_are_bit_identical_adam() {
    let rt = runtime();
    let run_with = |compat: bool| {
        let mut s = SessionBuilder::new(&rt, "pocket-tiny-fast")
            .optimizer(OptimizerKind::Adam)
            .seed(23)
            .compat_exec(compat)
            .build()
            .unwrap();
        let mut losses = Vec::new();
        for _ in 0..3 {
            losses.push(s.step().unwrap().loss);
        }
        let (m, v) = s.adam_state().unwrap();
        (
            losses,
            s.params().unwrap().to_bytes().unwrap(),
            m.to_bytes().unwrap(),
            v.to_bytes().unwrap(),
        )
    };
    let a = run_with(false);
    let b = run_with(true);
    assert_eq!(a.0, b.0, "loss trajectories must match");
    assert_eq!(a.1, b.1, "parameter bytes must match");
    assert_eq!(a.2, b.2, "adam m bytes must match");
    assert_eq!(a.3, b.3, "adam v bytes must match");
}

#[test]
fn in_place_path_matches_run_path_across_checkpoint_restore() {
    // reference: the literal run() path, 6 uninterrupted steps; the
    // donation path must reproduce it bit-exactly even when split by a
    // checkpoint save + restore into a fresh session
    let rt = runtime();
    let path =
        std::env::temp_dir().join("pocketllm_it_inplace_ck.plsi");
    let _ = std::fs::remove_file(&path);

    let mut r = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(31)
        .compat_exec(true)
        .build()
        .unwrap();
    let mut ref_losses = Vec::new();
    for _ in 0..6 {
        ref_losses.push(r.step().unwrap().loss);
    }
    let ref_params = r.params().unwrap().to_bytes().unwrap();

    let mut a = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(31)
        .build()
        .unwrap();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(a.step().unwrap().loss);
    }
    Checkpoint::save(&path,
                     a.snapshot_image(*got.last().unwrap()).unwrap())
        .unwrap();
    drop(a);

    let ck = Checkpoint::open(&path).unwrap();
    let mut b = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(31)
        .build()
        .unwrap();
    b.restore(&ck).unwrap();
    assert_eq!(b.step, 3);
    for _ in 0..3 {
        got.push(b.step().unwrap().loss);
    }
    assert_eq!(got, ref_losses,
               "restored in-place run must replay the run() trajectory");
    assert_eq!(b.params().unwrap().to_bytes().unwrap(), ref_params,
               "final parameters must be bit-identical");
}

#[test]
fn parallel_k_query_session_is_deterministic() {
    // mezo_step_q4 drives the threaded SPSA pool; two sessions must
    // still agree bit-for-bit (worker count never leaks into results)
    let rt = runtime();
    let run = || {
        let mut s = SessionBuilder::new(&rt, "pocket-roberta")
            .optimizer(OptimizerKind::MeZo)
            .queries(4)
            .seed(17)
            .build()
            .unwrap();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(s.step().unwrap().loss);
        }
        (losses, s.params().unwrap().to_bytes().unwrap())
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "k-query trajectories must be reproducible");
}

#[test]
fn pooled_spsa_shadows_are_standing_state_charged_once() {
    use pocketllm::runtime::Precision;
    let rt = runtime();
    // f32: after the first q-step the session keeps the pooled worker
    // shadows resident, and their size is steady across later steps
    // (standing state metered once — not per-step growth)
    let mut s = SessionBuilder::new(&rt, "pocket-roberta")
        .optimizer(OptimizerKind::MeZo)
        .queries(4)
        .seed(23)
        .build()
        .unwrap();
    assert_eq!(s.resident_bytes(), s.resident_param_bytes(),
               "no shadows pooled before the first step");
    s.step().unwrap();
    let pool = s.resident_bytes() - s.resident_param_bytes();
    assert!(pool >= s.resident_param_bytes(),
            "a q-session pools at least one full f32 shadow");
    s.step().unwrap();
    assert_eq!(s.resident_bytes() - s.resident_param_bytes(), pool,
               "pool size is steady state, not per-step accumulation");

    // quantized residency: the pool is released with the transient
    // f32 working set, so between steps only quantized bytes remain
    let mut q = SessionBuilder::new(&rt, "pocket-roberta")
        .optimizer(OptimizerKind::MeZo)
        .queries(4)
        .precision(Precision::Int8)
        .seed(23)
        .build()
        .unwrap();
    q.step().unwrap();
    assert_eq!(q.resident_bytes(), q.resident_param_bytes(),
               "quantized sessions release pooled shadows at writeback");
}

// ---------------------------------------------------------------------
// hibernate / rehydrate (durable session images)
// ---------------------------------------------------------------------

#[test]
fn hibernate_rehydrate_resumes_bit_identically_at_every_precision() {
    // reference: 6 uninterrupted steps.  Test: 3 steps -> hibernate
    // (session image through a real SessionStore, LRU + disk) ->
    // rehydrate -> 3 more steps.  Losses and final parameter bytes
    // must match bit-for-bit — at f32, f16, AND int8 (the image
    // stores the resident storage verbatim, so int8 codes never
    // re-round).
    use pocketllm::runtime::Precision;
    use pocketllm::store::SessionStore;
    let rt = runtime();
    let store_dir =
        std::env::temp_dir().join("pocketllm_it_hibernate");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = SessionStore::with_mem_capacity(&store_dir, 0).unwrap();

    for (key, precision) in [("f32", Precision::F32),
                             ("f16", Precision::F16),
                             ("int8", Precision::Int8)]
    {
        let build = || {
            SessionBuilder::new(&rt, "pocket-tiny")
                .optimizer(OptimizerKind::MeZo)
                .seed(47)
                .precision(precision)
                .build()
                .unwrap()
        };
        let mut reference = build();
        let mut ref_losses = Vec::new();
        for _ in 0..6 {
            ref_losses.push(reference.step().unwrap().loss);
        }
        let ref_params =
            reference.params().unwrap().to_bytes().unwrap();

        let mut live = build();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(live.step().unwrap().loss);
        }
        let resident_before = live.resident_param_bytes();
        let (image, remnant) = live.hibernate().unwrap();
        assert_eq!(image.precision, precision);
        assert_eq!(image.step, 3);
        assert_eq!(image.param_bytes(), resident_before,
                   "image payload = resident storage, no f32 blowup");
        store.put(key, &image).unwrap();
        // ... the job is now O(100)-bytes-of-counters on the host ...
        let image_back = store.take(key).unwrap();
        let mut resumed = remnant.rehydrate(image_back).unwrap();
        assert_eq!(resumed.step, 3);
        assert_eq!(resumed.resident_param_bytes(), resident_before,
                   "rehydrated residency must keep its precision");
        for _ in 0..3 {
            got.push(resumed.step().unwrap().loss);
        }
        assert_eq!(got, ref_losses,
                   "{precision}: hibernated run diverged");
        assert_eq!(resumed.params().unwrap().to_bytes().unwrap(),
                   ref_params,
                   "{precision}: final parameter bytes diverged");
    }
    let _ = std::fs::remove_dir_all(&store_dir);
}

#[test]
fn adam_session_hibernates_with_moments_mezo_without() {
    let rt = runtime();
    // Adam: moments must survive the image round trip bit-exactly
    let mut adam = SessionBuilder::new(&rt, "pocket-tiny-fast")
        .optimizer(OptimizerKind::Adam)
        .seed(53)
        .build()
        .unwrap();
    let mut ref_adam = SessionBuilder::new(&rt, "pocket-tiny-fast")
        .optimizer(OptimizerKind::Adam)
        .seed(53)
        .build()
        .unwrap();
    let mut ref_losses = Vec::new();
    for _ in 0..4 {
        ref_losses.push(ref_adam.step().unwrap().loss);
    }
    let mut got = Vec::new();
    for _ in 0..2 {
        got.push(adam.step().unwrap().loss);
    }
    let (image, remnant) = adam.hibernate().unwrap();
    assert!(!image.adam_m.is_empty(),
            "adam image must carry its moments");
    assert!(image.moment_bytes() > 0);
    let mut resumed = remnant.rehydrate(image).unwrap();
    for _ in 0..2 {
        got.push(resumed.step().unwrap().loss);
    }
    assert_eq!(got, ref_losses, "adam hibernate diverged");

    // MeZO: the image is params + O(100) B of metadata — the paper's
    // Table-1 asymmetry made durable (no moment payload, ~9 B/tensor
    // directory + fixed header)
    let mezo = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .seed(53)
        .build()
        .unwrap();
    let n_tensors = mezo.cfg.params.len() as u64;
    let (image, _remnant) = mezo.hibernate().unwrap();
    assert!(image.adam_m.is_empty() && image.adam_v.is_empty());
    assert_eq!(image.moment_bytes(), 0);
    let encoded = image.encode().len() as u64;
    assert_eq!(encoded, image.param_bytes() + image.metadata_bytes());
    assert!(image.metadata_bytes() <= 100 + 9 * n_tensors,
            "MeZO image metadata is {} B for {} tensors",
            image.metadata_bytes(), n_tensors);
}

// ---------------------------------------------------------------------
// capped batch window (recompute-on-miss)
// ---------------------------------------------------------------------

#[test]
fn capped_batch_window_replays_the_same_stream() {
    // a tiny window forces eviction + deterministic regeneration; the
    // trajectory must match an uncapped session exactly, and the
    // resident cache must stay bounded
    let rt = runtime();
    let losses_with_window = |w: usize| {
        let mut s = SessionBuilder::new(&rt, "pocket-tiny")
            .optimizer(OptimizerKind::MeZo)
            .seed(37)
            .batch_window(w)
            .build()
            .unwrap();
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(s.step().unwrap().loss);
        }
        losses
    };
    let capped = losses_with_window(2);
    let wide = losses_with_window(1024);
    assert_eq!(capped, wide,
               "window size must never change the batch stream");
}
