//! Crash-safe fleet recovery: a durable run killed mid-flight and
//! resumed with `FleetScheduler::recover` must land on OUTCOMES
//! bit-identical to the uninterrupted sequential oracle — for every
//! worker count, every parameter precision, and both store engines.
//!
//! The whole contract survives the crash: outcomes come from the
//! session images, and the pre-crash event/metric/span streams are
//! replayed from the durable journal (`store::journal`) — a recovered
//! job's stream is the uninterrupted prefix followed by a `Recovered`
//! marker.  Outcomes are fingerprinted via Debug formatting
//! (shortest-roundtrip f64, so equal strings mean bit-equal floats);
//! streams are diffed against the sequential oracle directly.

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, Event,
                             FleetConfig, FleetScheduler, JobOutcome,
                             JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Precision, Runtime};
use pocketllm::scheduler::Policy;
use pocketllm::store::{EngineKind, PagedEngine, PAGED_FILE_NAME};

fn runtime() -> Runtime {
    let m = Manifest::load_or_builtin("artifacts/manifest.json")
        .expect("manifest");
    Runtime::new(m).expect("native runtime")
}

fn outcome_fingerprint(outcomes: &[JobOutcome]) -> String {
    format!("{outcomes:?}")
}

fn coord_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 100,
        ..Default::default()
    }
}

/// A mixed workload: MeZO and Adam (so optimizer moments ride through
/// recovery images), deadlines and best-effort (so the rebuilt EDF
/// queue is exercised), multi-window jobs (so the crash interrupts
/// real progress).
fn jobs_for(precision: Precision) -> Vec<JobSpec> {
    vec![
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(6)
            .seed(41)
            .precision(precision)
            .deadline(600.0),
        JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                     OptimizerKind::Adam)
            .steps(4)
            .seed(42)
            .precision(precision),
        JobSpec::new("pocket-tiny", TaskKind::Rte, OptimizerKind::MeZo)
            .steps(6)
            .seed(43)
            .precision(precision)
            .deadline(30.0),
    ]
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("pocketllm_recovery_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn killed_fleet_recovers_bit_identically_to_the_oracle() {
    // THE acceptance pin of the recovery subsystem.  budget 0 forces
    // a hibernation image after every committed window, so the store
    // holds a live image for each in-flight job at the crash;
    // halt_at_window(3) is the in-process stand-in for kill-at-window
    // (same store state, no process abort — the CLI smoke drill
    // exercises the real abort).
    let rt = runtime();
    let cfg = coord_cfg();
    for (pi, precision) in
        [Precision::F32, Precision::F16, Precision::Int8]
            .into_iter()
            .enumerate()
    {
        let jobs = jobs_for(precision);
        let mut oracle = Coordinator::new(&rt, cfg.clone());
        let want =
            outcome_fingerprint(&oracle.run_queue(&jobs).unwrap());
        let want_events = oracle.events.clone();
        let want_csv = oracle.metrics.to_csv();

        for (wi, workers) in [1usize, 2, 4].into_iter().enumerate() {
            // alternate backends across the matrix so both engines
            // see every worker count somewhere
            let engine = if (pi + wi) % 2 == 0 {
                EngineKind::Dir
            } else {
                EngineKind::Paged
            };
            let dir = tmp(&format!("{precision}_{workers}"));
            let crashing = FleetScheduler::new(
                &rt,
                FleetConfig {
                    coord: cfg.clone(),
                    workers,
                    resident_budget_bytes: Some(0),
                    store_dir: Some(dir.clone()),
                    store_engine: engine,
                    halt_at_window: Some(3),
                    ..FleetConfig::default()
                },
            );
            let err = crashing.run(&jobs).expect_err(
                "halt_at_window must abort the run with an error",
            );
            assert!(format!("{err:#}").contains("halted"), "{err:#}");

            if engine == EngineKind::Paged {
                // the crashed store must already be consistent — and
                // stay consistent under a simulated torn write (bytes
                // past the committed root are a warning, not
                // corruption)
                let file = dir.join(PAGED_FILE_NAME);
                let report = PagedEngine::fsck(&file).unwrap();
                assert!(report.is_clean(),
                        "crashed paged store must fsck clean:\n\
                         {report}");
                use std::io::Write;
                let mut f = std::fs::OpenOptions::new()
                    .append(true)
                    .open(&file)
                    .unwrap();
                f.write_all(&[0xAB; 37]).unwrap();
                drop(f);
                let report = PagedEngine::fsck(&file).unwrap();
                assert!(report.is_clean(),
                        "torn tail must be a warning, not an error:\n\
                         {report}");
                assert!(!report.warnings.is_empty(),
                        "the torn tail should be reported");
            }

            let recovering = FleetScheduler::new(
                &rt,
                FleetConfig {
                    workers,
                    resident_budget_bytes: Some(0),
                    ..FleetConfig::default()
                },
            );
            let report = recovering.recover(&dir).unwrap();
            assert_eq!(
                outcome_fingerprint(&report.outcomes), want,
                "{precision}, {workers} workers, {} engine: recovered \
                 outcomes diverged from the uninterrupted oracle",
                engine.label()
            );
            assert_eq!(report.telemetry.jobs, jobs.len());
            // the journal retires the old event gap: minus the
            // Recovered markers, the recovered stream IS the oracle's
            // (replayed prefix + post-crash re-run, per job in order)
            let replayed: Vec<Event> = report
                .events
                .iter()
                .filter(|e| !matches!(e, Event::Recovered { .. }))
                .cloned()
                .collect();
            assert_eq!(
                replayed, want_events,
                "{precision}, {workers} workers, {} engine: \
                 recovered event stream diverged from the oracle",
                engine.label()
            );
            assert_eq!(
                report.metrics.to_csv(), want_csv,
                "{precision}, {workers} workers, {} engine: \
                 recovered metrics diverged from the oracle",
                engine.label()
            );
            if workers == 1 {
                // the window that ticked the halt clock hibernated
                // its job (budget 0) before the tick, and a single
                // worker can never dispatch it again afterwards — so
                // at least one job must resume from a live image
                assert!(report.telemetry.recovered_jobs >= 1,
                        "single-worker crash at window 3 must leave \
                         a live image behind");
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn completed_run_recovers_from_terminal_images_without_rerunning() {
    // after a durable run completes, every job has a terminal image:
    // recover() must reconstruct the same outcomes from the store
    // alone — no window re-runs, no recovered (live) jobs, no
    // dispatches
    let rt = runtime();
    let cfg = coord_cfg();
    let jobs = jobs_for(Precision::F32);
    let dir = tmp("terminal");
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig {
            coord: cfg.clone(),
            workers: 2,
            store_dir: Some(dir.clone()),
            store_engine: EngineKind::Paged,
            ..FleetConfig::default()
        },
    );
    let first = fleet.run(&jobs).unwrap();
    assert!(first.telemetry.windows_used > 0);
    let want = outcome_fingerprint(&first.outcomes);

    let report = fleet.recover(&dir).unwrap();
    assert_eq!(outcome_fingerprint(&report.outcomes), want);
    assert_eq!(report.telemetry.recovered_jobs, 0,
               "terminal images short-circuit, they do not resume");
    assert!(report.first_dispatch.is_empty(),
            "nothing should have been dispatched");
    // terminal jobs replay their full streams from the journal —
    // byte-for-byte what the uninterrupted run reported
    assert_eq!(report.events, first.events,
               "journal replay must reproduce the finished run's \
                event stream");
    assert_eq!(report.metrics.to_csv(), first.metrics.to_csv());
    assert_eq!(
        pocketllm::telemetry::trace::fingerprint(&report.spans),
        pocketllm::telemetry::trace::fingerprint(&first.spans),
        "journal replay must reproduce the finished run's spans"
    );

    // compaction preserves every byte that matters: fsck stays clean
    // and a post-compaction recovery still reconstructs the run
    let file = dir.join(PAGED_FILE_NAME);
    PagedEngine::open(&file).unwrap().compact().unwrap();
    let fsck = PagedEngine::fsck(&file).unwrap();
    assert!(fsck.is_clean(), "compacted store must fsck clean:\n{fsck}");
    let again = fleet.recover(&dir).unwrap();
    assert_eq!(outcome_fingerprint(&again.outcomes), want);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recover_needs_a_manifest() {
    let rt = runtime();
    let dir = tmp("no_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    let fleet =
        FleetScheduler::new(&rt, FleetConfig::default());
    let err = fleet.recover(&dir).expect_err(
        "an empty directory is not a durable fleet store",
    );
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}
