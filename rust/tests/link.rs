//! Server-assisted split tuning over the simulated link: the
//! subsystem's acceptance pins.
//!
//! The determinism contract extends to every link profile and mode
//! directive: fleet outcomes in `--mode auto` must be bit-identical to
//! the sequential oracle for workers {1, 2, 4} across {wifi, metered,
//! offline} — including a run that is killed mid-flight and resumed
//! with `FleetScheduler::recover` (the `RecoveryRecord` carries the
//! link-trace position and per-mode counters, so a recovered job picks
//! up the exact link weather it would have seen).  The `flaky` profile
//! is the fault-injection drill: mid-transfer drops must re-plan the
//! window as local MeZO deterministically.

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, Event,
                             FleetConfig, FleetScheduler, JobOutcome,
                             JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::link::LinkSpec;
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::scheduler::{ModePolicy, Policy};
use pocketllm::store::EngineKind;

fn runtime() -> Runtime {
    let m = Manifest::load_or_builtin("artifacts/manifest.json")
        .expect("manifest");
    Runtime::new(m).expect("native runtime")
}

fn outcome_fingerprint(outcomes: &[JobOutcome]) -> String {
    format!("{outcomes:?}")
}

fn coord_cfg(link: LinkSpec, mode: ModePolicy) -> CoordinatorConfig {
    CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 2,
        max_windows: 300,
        link,
        mode,
        ..Default::default()
    }
}

/// Multi-window MeZO jobs on split-capable encoder configs, so every
/// mode the policy can pick actually gets exercised.
fn jobs() -> Vec<JobSpec> {
    vec![
        JobSpec::new("pocket-tiny", TaskKind::Sst2, OptimizerKind::MeZo)
            .steps(8)
            .seed(21),
        JobSpec::new("pocket-tiny", TaskKind::Rte, OptimizerKind::MeZo)
            .steps(6)
            .seed(22),
        JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                     OptimizerKind::MeZo)
            .steps(8)
            .seed(23),
    ]
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("pocketllm_link_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn auto_mode_fleet_matches_oracle_across_links_and_workers() {
    // THE acceptance pin of the split-tuning subsystem: for each link
    // profile, the auto-mode fleet must reproduce the sequential
    // oracle bit-for-bit at every worker count, and a killed +
    // recovered run must land on the same outcomes again.
    let rt = runtime();
    let jobs = jobs();
    for (li, link) in [
        LinkSpec::wifi(),
        LinkSpec::metered(),
        LinkSpec::offline(),
    ]
    .into_iter()
    .enumerate()
    {
        let cfg = coord_cfg(link.clone(), ModePolicy::Auto);
        let mut oracle = Coordinator::new(&rt, cfg.clone());
        let outcomes = oracle.run_queue(&jobs).unwrap();
        let want = outcome_fingerprint(&outcomes);
        assert!(
            outcomes.iter().all(|o| o.steps_done > 0),
            "{}: oracle jobs must make progress",
            link.name
        );
        if link.name == "offline" {
            // no link, no traffic — in any mode
            assert!(outcomes
                .iter()
                .all(|o| o.windows_split == 0 && o.link_bytes == 0));
        }

        for (wi, workers) in [1usize, 2, 4].into_iter().enumerate() {
            let fleet = FleetScheduler::new(
                &rt,
                FleetConfig {
                    coord: cfg.clone(),
                    workers,
                    ..FleetConfig::default()
                },
            );
            let report = fleet.run(&jobs).unwrap();
            assert_eq!(
                outcome_fingerprint(&report.outcomes),
                want,
                "{} link, {workers} workers: fleet diverged from the \
                 sequential oracle",
                link.name
            );

            // kill-and-recover: same matrix, crash after window 3,
            // resume from the durable store, same outcomes again
            let engine = if (li + wi) % 2 == 0 {
                EngineKind::Dir
            } else {
                EngineKind::Paged
            };
            let dir = tmp(&format!("auto_{}_{workers}", link.name));
            let crashing = FleetScheduler::new(
                &rt,
                FleetConfig {
                    coord: cfg.clone(),
                    workers,
                    resident_budget_bytes: Some(0),
                    store_dir: Some(dir.clone()),
                    store_engine: engine,
                    halt_at_window: Some(3),
                    ..FleetConfig::default()
                },
            );
            let err = crashing.run(&jobs).expect_err(
                "halt_at_window must abort the run with an error",
            );
            assert!(format!("{err:#}").contains("halted"), "{err:#}");
            let recovering = FleetScheduler::new(
                &rt,
                FleetConfig {
                    workers,
                    resident_budget_bytes: Some(0),
                    ..FleetConfig::default()
                },
            );
            let report = recovering.recover(&dir).unwrap();
            assert_eq!(
                outcome_fingerprint(&report.outcomes),
                want,
                "{} link, {workers} workers: recovered outcomes \
                 diverged from the uninterrupted oracle",
                link.name
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn force_split_ships_bytes_and_completes() {
    // ForceSplit on home wifi: essentially every admitted window runs
    // split, so the outcome must carry split counters, link traffic,
    // and radio energy — and the event stream must say so.
    let rt = runtime();
    let cfg = coord_cfg(LinkSpec::wifi(), ModePolicy::ForceSplit);
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                           OptimizerKind::MeZo)
        .steps(8)
        .seed(31);
    let o = coord.run_job(0, &job).unwrap();
    assert_eq!(o.steps_done, 8);
    assert!(o.windows_split > 0, "ForceSplit never split: {o:?}");
    assert!(o.link_bytes > 0 && o.link_wh > 0.0,
            "split windows must bill the link: {o:?}");
    assert!(o.final_loss.is_finite());
    let split_events = coord
        .events
        .iter()
        .filter(|e| matches!(e, Event::SplitDone { .. }))
        .count();
    assert_eq!(split_events, o.windows_split,
               "one SplitDone event per split window");

    // an Adam job has no split program: ForceSplit degrades to local
    // and ships nothing
    let adam = JobSpec::new("pocket-tiny-fast", TaskKind::Sst2,
                            OptimizerKind::Adam)
        .steps(4)
        .seed(32);
    let oa = coord.run_job(1, &adam).unwrap();
    assert_eq!(oa.steps_done, 4);
    assert_eq!(oa.windows_split, 0);
    assert_eq!(oa.link_bytes, 0);
}

#[test]
fn offline_force_split_defers_and_stalls_deterministically() {
    // ForceSplit with no connectivity: every admitted window defers —
    // the window is consumed, no steps run, and the job stalls at
    // max_windows.  Entirely trace-free (offline is never up), so
    // every assertion here is exact, not probabilistic.
    let rt = runtime();
    let mut cfg = coord_cfg(LinkSpec::offline(), ModePolicy::ForceSplit);
    cfg.max_windows = 12;
    let mut coord = Coordinator::new(&rt, cfg.clone());
    let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                           OptimizerKind::MeZo)
        .steps(8)
        .seed(41);
    let o = coord.run_job(0, &job).unwrap();
    assert_eq!(o.steps_done, 0);
    assert_eq!(o.windows_used, 0);
    assert_eq!(o.windows_deferred, 12,
               "every window must defer on a dead link: {o:?}");
    assert!(coord
        .events
        .iter()
        .any(|e| matches!(e, Event::Deferred { .. })));

    // the fleet's deferral histogram attributes the starvation per job
    let fleet = FleetScheduler::new(
        &rt,
        FleetConfig { coord: cfg, workers: 2,
                      ..FleetConfig::default() },
    );
    let report = fleet.run(&jobs()).unwrap();
    assert_eq!(report.telemetry.deferred_by_job.len(), 3);
    assert!(report
        .telemetry
        .deferred_by_job
        .iter()
        .all(|&d| d > 0),
        "offline ForceSplit must starve every job: {:?}",
        report.telemetry.deferred_by_job);
    assert_eq!(
        report.telemetry.windows_deferred,
        report.telemetry.deferred_by_job.iter().sum::<usize>()
    );
}

#[test]
fn flaky_link_drops_replan_as_local_and_stay_deterministic() {
    // Satellite fault-injection drill: the flaky profile tears ~35% of
    // transfers mid-flight.  Every drop must (a) bill the partial
    // transfer, (b) emit LinkDropped, (c) fall back to a local MeZO
    // window — and the whole dance must replay bit-identically in the
    // fleet at workers {1, 2} and through a kill + recover.
    // every job consumes the SAME link-weather stream (one trace_seed
    // per coordinator), so drop coverage comes from the longest job's
    // window stream, not from the job count: 30 up-windows at
    // drop_prob 0.35 make a zero-drop run astronomically unlikely —
    // and once a seed pins drops, they are pinned forever
    let flaky_jobs = || {
        vec![
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(60)
                .seed(61),
            JobSpec::new("pocket-tiny", TaskKind::Rte,
                         OptimizerKind::MeZo)
                .steps(40)
                .seed(62),
        ]
    };
    let rt = runtime();
    let cfg = coord_cfg(LinkSpec::flaky(), ModePolicy::ForceSplit);
    let mut oracle = Coordinator::new(&rt, cfg.clone());
    let outcomes = oracle.run_queue(&flaky_jobs()).unwrap();
    let want = outcome_fingerprint(&outcomes);
    let drops: usize = outcomes.iter().map(|o| o.link_drops).sum();
    assert!(drops > 0,
            "flaky link produced no drops — the drill is vacuous");
    let dropped_events = oracle
        .events
        .iter()
        .filter(|e| matches!(e, Event::LinkDropped { .. }))
        .count();
    assert_eq!(dropped_events, drops,
               "one LinkDropped event per counted drop");
    // a dropped window still makes progress (local fallback ran), so
    // every job completes despite the weather
    assert!(outcomes.iter().all(|o| o.steps_done > 0));

    for workers in [1usize, 2] {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig {
                coord: cfg.clone(),
                workers,
                ..FleetConfig::default()
            },
        );
        let report = fleet.run(&flaky_jobs()).unwrap();
        assert_eq!(
            outcome_fingerprint(&report.outcomes),
            want,
            "flaky link, {workers} workers: fleet diverged"
        );
        assert_eq!(
            report.telemetry.link_drops, drops,
            "drop count must not depend on the worker count"
        );

        let dir = tmp(&format!("flaky_{workers}"));
        let crashing = FleetScheduler::new(
            &rt,
            FleetConfig {
                coord: cfg.clone(),
                workers,
                resident_budget_bytes: Some(0),
                store_dir: Some(dir.clone()),
                store_engine: EngineKind::Paged,
                halt_at_window: Some(3),
                ..FleetConfig::default()
            },
        );
        let err = crashing.run(&flaky_jobs()).expect_err(
            "halt_at_window must abort the run with an error",
        );
        assert!(format!("{err:#}").contains("halted"), "{err:#}");
        let recovered = FleetScheduler::new(
            &rt,
            FleetConfig {
                workers,
                resident_budget_bytes: Some(0),
                ..FleetConfig::default()
            },
        )
        .recover(&dir)
        .unwrap();
        assert_eq!(
            outcome_fingerprint(&recovered.outcomes),
            want,
            "flaky link, {workers} workers: recovery diverged"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn energy_cap_denies_windows_with_the_energy_reason() {
    // Satellite: Policy::max_energy_per_window end-to-end.  A cap
    // below one step's Wh denies every window with the Energy reason;
    // the job stalls without running a single step.
    let rt = runtime();
    let mut cfg = coord_cfg(LinkSpec::wifi(), ModePolicy::ForceLocal);
    cfg.policy.max_energy_per_window = Some(1e-12);
    cfg.max_windows = 10;
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                           OptimizerKind::MeZo)
        .steps(4)
        .seed(51);
    let o = coord.run_job(0, &job).unwrap();
    assert_eq!(o.steps_done, 0);
    assert_eq!(o.windows_used, 0);
    assert_eq!(o.windows_denied, 10, "{o:?}");
    assert!(coord.events.iter().all(|e| !matches!(
        e,
        Event::StepsDone { .. } | Event::SplitDone { .. }
    )));
    assert!(coord
        .events
        .iter()
        .any(|e| matches!(e, Event::Denied { reason, .. }
                          if *reason == "energy budget")));
}
