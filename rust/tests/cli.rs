//! CLI integration tests: drive the `pocketllm` binary end to end the way
//! a user would.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let bin = env!("CARGO_BIN_EXE_pocketllm");
    let out = Command::new(bin).args(args).output().expect("spawn");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["finetune", "report", "daemon", "fleet", "devices"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
    for flag in ["--queries", "--batch-window", "--workers"] {
        assert!(text.contains(flag), "missing {flag} in help");
    }
}

#[test]
fn unknown_subcommand_fails_loudly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn devices_table_renders() {
    let (ok, text) = run(&["devices"]);
    assert!(ok, "{text}");
    assert!(text.contains("oppo-reno6"));
    assert!(text.contains("rtx3090-server"));
}

#[test]
fn report_tables_match_paper_shape() {
    let (ok, text) = run(&["report", "table1"]);
    assert!(ok, "{text}");
    assert!(text.contains("OOM"));
    let (ok, text) = run(&["report", "table2"]);
    assert!(ok, "{text}");
    assert!(text.contains("97"));
    let (ok, text) = run(&["report", "energy"]);
    assert!(ok, "{text}");
    assert!(text.contains("battery"));
    let (ok, _) = run(&["report", "nonsense"]);
    assert!(!ok);
}

#[test]
fn finetune_smoke_with_device_and_csv() {
    let csv = std::env::temp_dir().join("pocketllm_cli_metrics.csv");
    let csv_s = csv.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--optimizer", "mezo",
        "--steps", "4", "--device", "oppo-reno6", "--csv", csv_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final loss"));
    assert!(text.contains("simulated peak memory"));
    let data = std::fs::read_to_string(&csv).unwrap();
    assert!(data.starts_with("step,"));
    assert!(data.lines().count() >= 5, "{data}");
}

#[test]
fn finetune_checkpoint_then_eval_and_inspect() {
    let path = std::env::temp_dir().join("pocketllm_cli_ckpt.plsi");
    let _ = std::fs::remove_file(&path);
    let path_s = path.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--steps", "3",
        "--checkpoint", path_s,
    ]);
    assert!(ok, "{text}");
    assert!(path.is_file(), "checkpoint must be ONE file");
    let (ok, text) = run(&[
        "eval", "--model", "pocket-tiny", "--checkpoint", path_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("eval loss"));
    assert!(text.contains("accuracy"));
    // and the image is inspectable: header + size breakdown
    let (ok, text) = run(&["store", "inspect", path_s]);
    assert!(ok, "{text}");
    assert!(text.contains("session image"), "{text}");
    assert!(text.contains("CRC verified"), "{text}");
    assert!(text.contains("config: pocket-tiny"), "{text}");
    assert!(text.contains("precision: f32"), "{text}");
    assert!(text.contains("params"), "{text}");
    assert!(text.contains("(master_seed, step)"),
            "MeZO images must advertise their 16-byte optimizer \
             state: {text}");
}

#[test]
fn adam_checkpoint_carries_moments_and_f16_keeps_its_precision() {
    // Adam checkpoints are now a single image with the m/v payload —
    // `store inspect` surfaces the Table-1 size asymmetry
    let adam = std::env::temp_dir().join("pocketllm_cli_adam.plsi");
    let _ = std::fs::remove_file(&adam);
    let adam_s = adam.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny-fast", "--optimizer",
        "adam", "--steps", "2", "--checkpoint", adam_s,
    ]);
    assert!(ok, "{text}");
    let (ok, text) = run(&["store", "inspect", adam_s]);
    assert!(ok, "{text}");
    assert!(text.contains("optimizer: adam"), "{text}");
    assert!(!text.contains("(master_seed, step)"), "{text}");

    // an f16 checkpoint records its precision, and eval honours it
    let f16 = std::env::temp_dir().join("pocketllm_cli_f16.plsi");
    let _ = std::fs::remove_file(&f16);
    let f16_s = f16.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--precision", "f16",
        "--steps", "2", "--checkpoint", f16_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("f16 storage"), "{text}");
    let (ok, text) = run(&["store", "inspect", f16_s]);
    assert!(ok, "{text}");
    assert!(text.contains("precision: f16 (2 B/param on disk)"),
            "{text}");
    let (ok, text) = run(&[
        "eval", "--model", "pocket-tiny", "--checkpoint", f16_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("f16 storage"),
            "eval must restore the checkpoint's precision: {text}");
}

#[test]
fn store_inspect_rejects_garbage_and_missing_files() {
    let bad = std::env::temp_dir().join("pocketllm_cli_garbage.plsi");
    std::fs::write(&bad, b"not an image at all").unwrap();
    let (ok, text) = run(&["store", "inspect", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("magic") || text.contains("truncated"),
            "{text}");
    let (ok, _) = run(&["store", "inspect", "/tmp/definitely_missing_x"]);
    assert!(!ok);
    let (ok, text) = run(&["store"]);
    assert!(!ok);
    assert!(text.contains("usage"), "{text}");
}

#[test]
fn finetune_queries_and_batch_window_reach_the_session() {
    // PR-2 regression: the parallel k-query SPSA path existed but the
    // binary had no --queries flag.  pocket-roberta ships a
    // mezo_step_q4 artifact at bs 8 in the builtin manifest.
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-roberta", "--queries", "4",
        "--batch", "8", "--batch-window", "4", "--steps", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final loss"));

    // a k with no artifact must fail loudly, proving the flag reached
    // SessionBuilder::queries (mezo_step_q3 is not in the manifest)
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-roberta", "--queries", "3",
        "--steps", "1",
    ]);
    assert!(!ok);
    assert!(text.contains("mezo_step_q3"), "{text}");

    let (ok, text) = run(&["finetune", "--queries", "0"]);
    assert!(!ok);
    assert!(text.contains("--queries"), "{text}");
}

#[test]
fn finetune_precision_f16_end_to_end() {
    // the precision API's CLI acceptance pin: an fp16 session runs end
    // to end, reports its storage, and prints BOTH the host-resident
    // and simulated parameter bytes (the footer bugfix)
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--precision", "f16",
        "--steps", "3", "--device", "oppo-reno6",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("f16 storage"), "{text}");
    assert!(text.contains("final loss"), "{text}");
    assert!(text.contains("params resident on host"), "{text}");
    assert!(text.contains("simulated ledger parameters"), "{text}");

    // int8 runs too; a bad precision fails loudly
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--precision", "int8",
        "--steps", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("int8 storage"), "{text}");
    let (ok, text) = run(&["finetune", "--precision", "fp64"]);
    assert!(!ok);
    assert!(text.contains("--precision"), "{text}");
}

#[test]
fn fleet_smoke_and_worker_count_determinism() {
    // the CLI-level determinism contract: identical output (minus the
    // host-wall line) for any --workers
    let fleet_out = |workers: &str| {
        let (ok, text) = run(&[
            "fleet", "--jobs", "2", "--workers", workers, "--steps",
            "4", "--policy", "always", "--model", "pocket-tiny",
        ]);
        assert!(ok, "{text}");
        // `host wall` and `fleet store` carry worker-timing detail
        // (wall-clock, hibernation counts, high-water) by design
        text.lines()
            .filter(|l| {
                !l.starts_with("host wall")
                    && !l.starts_with("fleet store")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    let w1 = fleet_out("1");
    let w2 = fleet_out("2");
    assert_eq!(w1, w2, "fleet output must not depend on --workers");
    assert!(w1.contains("fleet outcomes: 2/2 completed"), "{w1}");
    assert!(w1.contains("Completed"), "{w1}");
    assert!(w1.contains("fleet simulated step-seconds"), "{w1}");
    // 2 distinct (task, seed) jobs -> 2 artifact builds, 0 hits, for
    // any worker count (builds are serialized under the cache lock)
    assert!(w1.contains("fleet tokenizer cache: 2 builds, 0 hits"),
            "{w1}");
}

#[test]
fn fleet_with_resident_budget_is_worker_count_invariant() {
    // hibernation under a 0-byte budget must not change a single
    // deterministic output line — and it must actually hibernate
    let store_dir =
        std::env::temp_dir().join("pocketllm_cli_fleet_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let fleet_out = |workers: &str| {
        let (ok, text) = run(&[
            "fleet", "--jobs", "3", "--workers", workers, "--steps",
            "4", "--policy", "always", "--model", "pocket-tiny",
            "--resident-budget", "0", "--deadline", "60",
        ]);
        assert!(ok, "{text}");
        assert!(
            text.lines().any(|l| l.starts_with("fleet store")
                && !l.contains("0 hibernations")),
            "budget 0 must force hibernation: {text}"
        );
        text.lines()
            .filter(|l| {
                !l.starts_with("host wall")
                    && !l.starts_with("fleet store")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    let w1 = fleet_out("1");
    let w2 = fleet_out("2");
    assert_eq!(w1, w2,
               "hibernating fleet output must not depend on --workers");
    assert!(w1.contains("fleet outcomes: 3/3 completed"), "{w1}");
    assert!(w1.contains("fleet resident budget: 0 B"), "{w1}");
    assert!(w1.contains("fleet deadline misses: 0"), "{w1}");
}

#[test]
fn fleet_rejects_bad_policy() {
    let (ok, text) = run(&["fleet", "--policy", "sometimes"]);
    assert!(!ok);
    assert!(text.contains("overnight|always"), "{text}");
}

#[test]
fn artifacts_listing_shows_programs() {
    let (ok, text) = run(&["artifacts"]);
    assert!(ok, "{text}");
    assert!(text.contains("mezo_step"));
    assert!(text.contains("pocket-roberta"));
    assert!(text.contains("platform: cpu"));
}

#[test]
fn missing_artifacts_dir_explains_make() {
    let (ok, text) = run(&["artifacts", "--artifacts", "/nonexistent"]);
    assert!(!ok);
    assert!(text.contains("make artifacts"), "{text}");
}
