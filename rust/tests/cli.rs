//! CLI integration tests: drive the `pocketllm` binary end to end the way
//! a user would.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let bin = env!("CARGO_BIN_EXE_pocketllm");
    let out = Command::new(bin).args(args).output().expect("spawn");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["finetune", "report", "daemon", "devices"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_subcommand_fails_loudly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn devices_table_renders() {
    let (ok, text) = run(&["devices"]);
    assert!(ok, "{text}");
    assert!(text.contains("oppo-reno6"));
    assert!(text.contains("rtx3090-server"));
}

#[test]
fn report_tables_match_paper_shape() {
    let (ok, text) = run(&["report", "table1"]);
    assert!(ok, "{text}");
    assert!(text.contains("OOM"));
    let (ok, text) = run(&["report", "table2"]);
    assert!(ok, "{text}");
    assert!(text.contains("97"));
    let (ok, text) = run(&["report", "energy"]);
    assert!(ok, "{text}");
    assert!(text.contains("battery"));
    let (ok, _) = run(&["report", "nonsense"]);
    assert!(!ok);
}

#[test]
fn finetune_smoke_with_device_and_csv() {
    let csv = std::env::temp_dir().join("pocketllm_cli_metrics.csv");
    let csv_s = csv.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--optimizer", "mezo",
        "--steps", "4", "--device", "oppo-reno6", "--csv", csv_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final loss"));
    assert!(text.contains("simulated peak memory"));
    let data = std::fs::read_to_string(&csv).unwrap();
    assert!(data.starts_with("step,"));
    assert!(data.lines().count() >= 5, "{data}");
}

#[test]
fn finetune_checkpoint_then_eval() {
    let dir = std::env::temp_dir().join("pocketllm_cli_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().unwrap();
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny", "--steps", "3",
        "--checkpoint", dir_s,
    ]);
    assert!(ok, "{text}");
    let (ok, text) = run(&[
        "eval", "--model", "pocket-tiny", "--checkpoint", dir_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("eval loss"));
    assert!(text.contains("accuracy"));
}

#[test]
fn adam_checkpoint_is_refused_with_explanation() {
    let (ok, text) = run(&[
        "finetune", "--model", "pocket-tiny-fast", "--optimizer", "adam",
        "--steps", "1", "--checkpoint", "/tmp/should_not_exist_ck",
    ]);
    assert!(!ok);
    assert!(text.contains("3x params"), "{text}");
}

#[test]
fn artifacts_listing_shows_programs() {
    let (ok, text) = run(&["artifacts"]);
    assert!(ok, "{text}");
    assert!(text.contains("mezo_step"));
    assert!(text.contains("pocket-roberta"));
    assert!(text.contains("platform: cpu"));
}

#[test]
fn missing_artifacts_dir_explains_make() {
    let (ok, text) = run(&["artifacts", "--artifacts", "/nonexistent"]);
    assert!(!ok);
    assert!(text.contains("make artifacts"), "{text}");
}
