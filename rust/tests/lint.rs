//! `pallas-lint` end-to-end: every rule fires on its seeded fixture,
//! every pragma suppresses, the lexer survives its trap file — and
//! the repo's own `src/` tree is lint-clean, which makes the
//! determinism/memory contracts part of tier-1 CI.

use std::path::Path;
use std::process::Command;

use pocketllm::lint::{lint_source, lint_tree, RULE_IDS};
use pocketllm::util::json;

fn rules_of(findings: &[pocketllm::lint::Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

const D001: &str =
    include_str!("lint_fixtures/src/runtime/d001_hashmap.rs");
const D002: &str =
    include_str!("lint_fixtures/src/device/d002_wallclock.rs");
const D002_TELEMETRY: &str = include_str!(
    "lint_fixtures/src/telemetry/d002_not_the_capture_point.rs"
);
const D003: &str =
    include_str!("lint_fixtures/src/runtime/d003_unsafe.rs");
const D004: &str =
    include_str!("lint_fixtures/src/optim/d004_unwrap.rs");
const D005: &str =
    include_str!("lint_fixtures/src/coordinator/d005_spawn.rs");
const P000: &str =
    include_str!("lint_fixtures/src/store/p000_unjustified.rs");
const ALLOWED: &str =
    include_str!("lint_fixtures/src/data/allowed.rs");
const TRAPS: &str =
    include_str!("lint_fixtures/src/runtime/lexer_traps.rs");

#[test]
fn every_rule_fires_on_its_fixture() {
    let r = lint_source("src/runtime/d001_hashmap.rs", D001);
    assert_eq!(rules_of(&r.findings), ["D001", "D001"], "{:?}", r.findings);

    let r = lint_source("src/device/d002_wallclock.rs", D002);
    assert_eq!(rules_of(&r.findings), ["D002"], "{:?}", r.findings);

    // the D002 allowlist names trace.rs, not all of telemetry — a
    // clock read elsewhere in the tree still fires
    let r = lint_source(
        "src/telemetry/d002_not_the_capture_point.rs",
        D002_TELEMETRY,
    );
    assert_eq!(rules_of(&r.findings), ["D002"], "{:?}", r.findings);

    let r = lint_source("src/runtime/d003_unsafe.rs", D003);
    assert_eq!(rules_of(&r.findings), ["D003"], "{:?}", r.findings);

    let r = lint_source("src/optim/d004_unwrap.rs", D004);
    assert_eq!(rules_of(&r.findings), ["D004", "D004", "D004"],
               "lock().unwrap(), unwrap_or and test code must not \
                fire: {:?}", r.findings);

    let r = lint_source("src/coordinator/d005_spawn.rs", D005);
    assert_eq!(rules_of(&r.findings), ["D005"], "{:?}", r.findings);

    let r = lint_source("src/store/p000_unjustified.rs", P000);
    let mut rules = rules_of(&r.findings);
    rules.sort_unstable();
    assert_eq!(rules, ["D001", "P000"],
               "an unjustified pragma is a finding AND fails to \
                suppress: {:?}", r.findings);
}

#[test]
fn justified_pragmas_suppress_everything() {
    let r = lint_source("src/data/allowed.rs", ALLOWED);
    assert!(r.clean(), "expected clean, got {:?}", r.findings);
    assert_eq!(r.allows.len(), 5);
    assert_eq!(r.suppressed, 6,
               "file-scope D001 covers both HashMap mentions");
}

#[test]
fn lexer_traps_produce_no_findings() {
    let r = lint_source("src/runtime/lexer_traps.rs", TRAPS);
    assert!(r.clean(), "false positive: {:?}", r.findings);
    // and the '"' char literal did not swallow the rest of the file
    let toks = pocketllm::lint::lexer::lex(TRAPS);
    assert!(toks.iter().any(|t| t.is_ident("lifetime_soup")),
            "char-literal quote swallowed the token stream");
}

fn manifest_path(rel: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn fixture_tree_violates_every_rule() {
    let report = lint_tree(&manifest_path("tests/lint_fixtures/src"))
        .expect("fixture tree scans");
    let by_rule = report.violations_by_rule();
    for id in RULE_IDS {
        assert!(by_rule.get(*id).copied().unwrap_or(0) > 0,
                "no fixture violation for {id}: {by_rule:?}");
    }
}

#[test]
fn repo_src_tree_is_lint_clean() {
    let report =
        lint_tree(&manifest_path("src")).expect("src tree scans");
    assert!(report.files_scanned > 40,
            "suspiciously few files scanned: {}",
            report.files_scanned);
    assert!(report.clean(),
            "the shipped tree violates its own contracts:\n{}",
            report.render_human());
}

#[test]
fn cli_flags_violations_and_passes_clean_tree() {
    let bin = env!("CARGO_BIN_EXE_pallas-lint");
    let fixtures = manifest_path("tests/lint_fixtures/src");

    // seeded violations: exit 1, JSON report names every rule
    let out = Command::new(bin)
        .arg("--json")
        .arg(&fixtures)
        .output()
        .expect("pallas-lint runs");
    assert_eq!(out.status.code(), Some(1),
               "violations must exit nonzero");
    let doc = json::parse(&String::from_utf8_lossy(&out.stdout))
        .expect("--json output parses");
    let by_rule = doc.get("violations_by_rule");
    for id in RULE_IDS {
        assert!(by_rule.get(*id).as_u64().unwrap_or(0) > 0,
                "{id} missing from JSON report");
    }

    // the repo tree: exit 0, --stats renders the per-rule table
    let out = Command::new(bin)
        .arg("--stats")
        .arg(manifest_path("src"))
        .output()
        .expect("pallas-lint runs");
    assert_eq!(out.status.code(), Some(0),
               "repo tree must be clean:\n{}",
               String::from_utf8_lossy(&out.stdout));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("files scanned:"), "{text}");
    assert!(text.contains("D001"), "{text}");
}
