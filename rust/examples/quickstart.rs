//! Quickstart: fine-tune a pocket model with MeZO in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart   # hermetic (native backend)
//! # or: make artifacts first to run over the AOT manifest
//! ```
//!
//! Fine-tunes `pocket-tiny` on synthetic SST-2 with derivative-free
//! optimization and reports accuracy before and after.  Note what is
//! *absent*: no Python, no gradients, no optimizer state — the entire
//! optimizer state is a seed and a step counter.

use pocketllm::prelude::*;
use pocketllm::optim::Schedule;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load_or_builtin("artifacts/manifest.json")?;
    let rt = Runtime::new(manifest)?;
    println!("execution backend: {}", rt.platform());

    let mut session = SessionBuilder::new(&rt, "pocket-tiny")
        .optimizer(OptimizerKind::MeZo)
        .task(TaskKind::Sst2)
        .lr(Schedule::Constant(1e-4))
        .seed(42)
        .build()?;

    let acc_before = session.eval_accuracy()?;
    println!("accuracy before fine-tuning: {:.3}", acc_before);

    let stats = session.run_steps(40)?;
    println!(
        "ran {} MeZO steps: loss {:.4} -> {:.4} ({:.0} ms/step on host)",
        stats.steps,
        stats.first_loss,
        stats.last_loss,
        stats.mean_host_step_s * 1e3
    );

    let acc_after = session.eval_accuracy()?;
    println!("accuracy after fine-tuning:  {:.3}", acc_after);
    println!(
        "optimizer state carried between steps: 16 bytes (seed + counter)"
    );
    Ok(())
}
