//! Memory sweep: Table 1 generalized — footprint vs batch size for both
//! optimizer families, on the analytic device model AND measured on this
//! host at pocket scale.
//!
//! ```bash
//! cargo run --release --example memory_sweep
//! ```
//!
//! The analytic half sweeps RoBERTa-large on the simulated Reno 6 (the
//! paper's Table 1 plus the in-between batch sizes the paper skipped).
//! The measured half runs real pocket-roberta sessions at bs 8 and 64
//! and reports this process's RSS growth — demonstrating on real
//! hardware that Adam's footprint grows with batch while MeZO's doesn't.

use pocketllm::optim::OptimizerKind;
use pocketllm::prelude::*;
use pocketllm::report;
use pocketllm::telemetry::bench::current_rss_bytes;
use pocketllm::telemetry::Table;
use pocketllm::util::bytes::fmt_human;

fn measure_rss(rt: &Runtime, kind: OptimizerKind, batch: usize)
    -> anyhow::Result<u64>
{
    let before = current_rss_bytes().unwrap_or(0);
    let mut s = SessionBuilder::new(rt, "pocket-roberta")
        .optimizer(kind)
        .batch_size(batch)
        .seed(1)
        .build()?;
    s.run_steps(3)?; // allocate activations/state for real
    let after = current_rss_bytes().unwrap_or(0);
    Ok(after.saturating_sub(before))
}

fn main() -> anyhow::Result<()> {
    // analytic sweep (the paper's device)
    println!("{}",
             report::memory_sweep(&[1, 2, 4, 8, 16, 32, 64, 128]).render());
    println!("{}", report::oom_frontier().render());

    // measured at pocket scale on this host
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let mut t = Table::new(
        "Measured host RSS growth per session (pocket-roberta, 3 steps)",
    )
    .header(&["optimizer", "batch", "RSS delta"]);
    for (kind, batch) in [
        (OptimizerKind::MeZo, 8),
        (OptimizerKind::MeZo, 64),
        (OptimizerKind::Adam, 8),
        (OptimizerKind::Adam, 64),
    ] {
        let delta = measure_rss(&rt, kind, batch)?;
        t.row(&[
            kind.label().to_string(),
            batch.to_string(),
            fmt_human(delta),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: rust runtime overhead is ~{} — versus the ~2.6 GB \
         Termux+PyTorch stack the paper carried (see ablation report)",
        fmt_human(current_rss_bytes().unwrap_or(0))
    );
    Ok(())
}
