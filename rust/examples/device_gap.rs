//! Device gap: the paper's §4.4 observation quantified across hardware.
//!
//! ```bash
//! cargo run --release --example device_gap
//! ```
//!
//! Projects per-step fine-tuning time for the paper's two models across
//! every device preset (phone, low-end phone, Raspberry Pi, GPU server),
//! shows the ~1000x phone-vs-GPU gap, and demonstrates the thermal
//! throttling trajectory of a long session on the Reno 6 — the §6.3
//! limitation made concrete.

use pocketllm::device::{spec::preset, spec::preset_names, ComputeModel,
                        ModelDims, OptimizerFamily};
use pocketllm::report;
use pocketllm::telemetry::Table;

fn main() {
    // per-device projection table
    for (dims, batch, seq) in [
        (ModelDims::roberta_large(), 8, report::SST2_SEQ),
        (ModelDims::opt_1_3b(), report::OPT_BATCH, report::OPT_SEQ),
    ] {
        let mut t = Table::new(&format!(
            "MeZO s/step — {} (batch {batch}, seq {seq})", dims.name
        ))
        .header(&["device", "s/step", "vs reno6"]);
        let reno = ComputeModel::new(preset("oppo-reno6").unwrap())
            .step_time(&dims, OptimizerFamily::DerivativeFree, batch, seq)
            .total_s();
        for name in preset_names() {
            let s = ComputeModel::new(preset(name).unwrap())
                .step_time(&dims, OptimizerFamily::DerivativeFree, batch,
                           seq)
                .total_s();
            t.row(&[
                name.to_string(),
                format!("{:.2}", s),
                format!("{:.1}x", reno / s),
            ]);
        }
        println!("{}", t.render());
    }

    // the paper's §4.3/4.4 summary
    println!("{}", report::opt13b().render());

    // thermal throttling trajectory on a long session
    let mut cm = ComputeModel::new(preset("oppo-reno6").unwrap());
    let dims = ModelDims::roberta_large();
    let mut t = Table::new(
        "Thermal throttling — RoBERTa-large MeZO steps back-to-back on \
         Reno 6",
    )
    .header(&["step", "elapsed min", "s/step", "throttle"]);
    let mut elapsed = 0.0;
    for step in 0..12 {
        let st = cm.step_time(&dims, OptimizerFamily::DerivativeFree, 8,
                              report::SST2_SEQ);
        let factor = cm.spec().thermal.factor(cm.sustained_s());
        if step % 2 == 0 {
            t.row(&[
                step.to_string(),
                format!("{:.0}", elapsed / 60.0),
                format!("{:.0}", st.total_s()),
                format!("{:.0}%", factor * 100.0),
            ]);
        }
        cm.advance(st.total_s());
        elapsed += st.total_s();
    }
    println!("{}", t.render());
    println!("cooling down resets the clock (the scheduler exploits this \
              between policy windows)");
}
