//! Personalization: the paper's motivating scenario end-to-end.
//!
//! ```bash
//! cargo run --release --example personalization
//! ```
//!
//! A user's (synthetic) message history personalizes the pocket-opt
//! causal LM with MeZO, orchestrated by the *coordinator* under the
//! overnight policy — fine-tuning runs only in admitted windows
//! (charging, screen off, cool, memory-rich), exactly how a phone would
//! deploy this.  Reports held-out perplexity before/after and the
//! policy-denial breakdown over the simulated day.

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, Event, JobSpec};
use pocketllm::optim::OptimizerKind;
use pocketllm::prelude::*;
use pocketllm::scheduler::Policy;
use pocketllm::tuner::eval::perplexity;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;

    // baseline perplexity on the user's held-out messages
    let base = SessionBuilder::new(&rt, "pocket-opt")
        .task(TaskKind::ChatLm)
        .seed(777)
        .build()?;
    let loss_before = base.eval_loss()?;
    println!(
        "perplexity on user's messages before personalization: {:.1}",
        perplexity(loss_before)
    );
    drop(base);

    // the coordinator personalizes overnight
    let cfg = CoordinatorConfig {
        device_preset: "oppo-reno6".into(),
        policy: Policy::overnight(),
        steps_per_window: 8,
        trace_step_minutes: 20.0,
        max_windows: 400,
        trace_seed: 11,
        ..Default::default()
    };
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new("pocket-opt", TaskKind::ChatLm,
                           OptimizerKind::MeZo)
        .steps(64)
        .seed(777);
    println!("queueing personalization job (64 MeZO steps, overnight \
              policy)...");
    let outcome = coord.run_job(0, &job)?;
    println!(
        "job {:?}: {} steps over {} admitted windows ({} denied)",
        outcome.status, outcome.steps_done, outcome.windows_used,
        outcome.windows_denied
    );
    let mut denials = std::collections::BTreeMap::new();
    for e in &coord.events {
        if let Event::Denied { reason, .. } = e {
            *denials.entry(*reason).or_insert(0usize) += 1;
        }
    }
    for (reason, n) in &denials {
        println!("  window denied {n:>3}x: {reason}");
    }

    // final perplexity: re-train an identical session to get the
    // personalized params (the coordinator's job was policy-driven; this
    // mirrors it deterministically)
    let mut tuned = SessionBuilder::new(&rt, "pocket-opt")
        .task(TaskKind::ChatLm)
        .optimizer(OptimizerKind::MeZo)
        .seed(777)
        .build()?;
    tuned.run_steps(outcome.steps_done)?;
    let loss_after = tuned.eval_loss()?;
    println!(
        "perplexity after personalization: {:.1} (was {:.1})",
        perplexity(loss_after),
        perplexity(loss_before)
    );
    anyhow::ensure!(
        loss_after < loss_before,
        "personalization should reduce held-out loss"
    );
    println!("personalization OK — all data stayed on device");
    Ok(())
}
