//! End-to-end driver: the full system on a real (synthetic-data) training
//! workload — the run recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! # knobs: E2E_STEPS_MEZO (default 300), E2E_STEPS_ADAM (default 150)
//! ```
//!
//! Trains the pocket-roberta classifier (5.8M params) on synthetic SST-2
//! with BOTH optimizers through the whole stack — Pallas/JAX-lowered HLO
//! driven by the Rust session loop under a simulated OPPO Reno 6
//! envelope — and writes the Fig.-1-style loss curves to
//! `e2e_loss_curves.csv`.  Exit code is non-zero if either optimizer
//! fails to learn (so this doubles as a long-running CI check).

use pocketllm::device::Device;
use pocketllm::optim::{OptimizerKind, Schedule};
use pocketllm::prelude::*;
use pocketllm::report;
use pocketllm::telemetry::bench::env_u64;
use pocketllm::telemetry::MetricLog;

fn main() -> anyhow::Result<()> {
    let mezo_steps = env_u64("E2E_STEPS_MEZO", 300);
    let adam_steps = env_u64("E2E_STEPS_ADAM", 150);
    let manifest = Manifest::load_or_builtin("artifacts/manifest.json")?;
    let rt = Runtime::new(manifest)?;
    let mut log = MetricLog::new();
    let mut summary = Vec::new();

    for (kind, steps, lr) in [
        (OptimizerKind::MeZo, mezo_steps, 1e-4),
        (OptimizerKind::Adam, adam_steps, 1e-3),
    ] {
        let label = kind.label();
        println!("=== {label}: {steps} steps on pocket-roberta/sst2 ===");
        let mut session = SessionBuilder::new(&rt, "pocket-roberta")
            .optimizer(kind)
            .task(TaskKind::Sst2)
            .lr(Schedule::Constant(lr))
            .seed(2024)
            .device(Device::preset("oppo-reno6").unwrap())
            .dataset_size(1024, 256)
            .build()?;

        let acc0 = session.eval_accuracy()?;
        let t0 = std::time::Instant::now();
        let mut chunk = 0;
        while chunk < steps {
            let n = 25.min(steps - chunk);
            let stats = session.run_steps(n)?;
            chunk += n;
            println!(
                "  step {:>4}  loss {:.4}  {:.0} ms/step (host)  \
                 {:.1} s/step (reno6 sim)",
                session.step, stats.last_loss,
                stats.mean_host_step_s * 1e3, stats.mean_sim_step_s
            );
        }
        let wall = t0.elapsed().as_secs_f64();
        let acc1 = session.eval_accuracy()?;
        let curve = session.metrics.get("loss").unwrap().clone();
        for &(s, v) in &curve.points {
            log.record(&format!("{label}.loss"), s, v);
        }

        let head = curve.head_mean(20);
        let tail = curve.tail_mean(20);
        println!(
            "{label}: loss head {head:.4} -> tail {tail:.4}; accuracy \
             {acc0:.3} -> {acc1:.3}; {wall:.0}s wall"
        );
        println!("  {}", report::sparkline(&curve.points, 70));
        let peak = session.device.as_ref().unwrap().ledger.peak();
        println!(
            "  simulated reno6 peak memory: {}",
            pocketllm::util::bytes::fmt_gb(peak)
        );
        summary.push((label, head, tail, acc0, acc1));
    }

    log.save_csv(std::path::Path::new("e2e_loss_curves.csv"))?;
    println!("\nloss curves -> e2e_loss_curves.csv");

    // Fig. 1 shape assertions: both descend; Adam descends further in
    // half the steps ("not as rapidly as with Adam" for MeZO).
    let (_, mh, mt, _, macc) = summary[0];
    let (_, ah, at, _, aacc) = summary[1];
    anyhow::ensure!(mt < mh, "MeZO failed to descend: {mh} -> {mt}");
    anyhow::ensure!(at < ah, "Adam failed to descend: {ah} -> {at}");
    anyhow::ensure!(
        (ah - at) > (mh - mt),
        "expected Adam to descend faster (adam {ah}->{at}, mezo {mh}->{mt})"
    );
    // NB: MeZO needs orders of magnitude more steps to move *accuracy*
    // (the MeZO paper trains 10k-100k steps); a few hundred steps moves
    // the loss visibly (the paper's Fig. 1 shows exactly this) while
    // accuracy is still near chance.  Gate on sanity, not convergence.
    anyhow::ensure!(macc > 0.40, "MeZO accuracy collapsed: {macc}");
    anyhow::ensure!(aacc > 0.8, "Adam accuracy too low: {aacc}");
    println!("\nE2E OK: Fig. 1 shape reproduced on the full stack");
    Ok(())
}
