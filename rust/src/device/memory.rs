//! Memory accounting: the allocation ledger and the analytical fine-tuning
//! footprint model — the mechanism behind the paper's Table 1.
//!
//! ## The footprint model
//!
//! Fine-tuning memory decomposes into (Ren et al. 2021, "model states +
//! residual states"):
//!
//! | category        | derivative-based (Adam)          | derivative-free (MeZO) |
//! |-----------------|----------------------------------|------------------------|
//! | parameters      | P·dtype                          | P·dtype                |
//! | gradients       | P·4 (fp32)                       | **0** (scalar g_proj)  |
//! | optimizer state | 2·P·4 (m, v)                     | **0** (u32 seed)       |
//! | activations     | ~per-layer inputs, ∝ batch·seq   | one live layer, tiny   |
//! | runtime         | framework fixed cost             | framework fixed cost   |
//!
//! The parameters row charges `ModelDims::param_bytes` — the *storage*
//! byte-width of the session's [`Precision`](crate::runtime::Precision)
//! (4 f32, 2 f16, 1 int8), threaded from
//! `ConfigInfo::model_dims_at`.  Gradients and Adam moments stay
//! fp32 regardless (mixed-precision practice), which is why an fp16
//! Adam job saves only one of its four parameter-scale tensors while
//! fp16 MeZO halves its entire model-state footprint — the asymmetry
//! behind the paper's OPT-1.3B-in-6.5-GB figure.
//!
//! MeZO's column is the paper's contribution: regenerating z from a seed
//! erases the three parameter-scale tensors, and forward-without-autograd
//! erases the batch-proportional activation term — which is why Table 1
//! shows MeZO flat in batch size while Adam OOMs.
//!
//! Split tuning (`OptimizerFamily::SplitForward`) goes one step further:
//! the frozen backbone runs a single forward on the device and only the
//! pooled activations cross the link, so the trainable side module and
//! its optimizer state drop off the device entirely — the parameter row
//! sheds the head bytes ([`split_side_params`]) and everything else
//! matches MeZO's forward-only live set.

use std::collections::BTreeMap;
use std::fmt;

use super::spec::ModelDims;
use super::OptimizerFamily;
use crate::util::bytes::fmt_human;

/// What an allocation is for.  Mirrors the footprint model's rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    Parameters,
    Gradients,
    OptimizerState,
    Activations,
    Workspace,
    Runtime,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Parameters,
        Category::Gradients,
        Category::OptimizerState,
        Category::Activations,
        Category::Workspace,
        Category::Runtime,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Category::Parameters => "parameters",
            Category::Gradients => "gradients",
            Category::OptimizerState => "optimizer state",
            Category::Activations => "activations",
            Category::Workspace => "workspace",
            Category::Runtime => "runtime",
        }
    }
}

/// Out-of-memory: the job asked for more than the device budget allows.
/// This is the event the paper reports as "OOM" in Tables 1 and 2.
#[derive(Debug, Clone)]
pub struct OomError {
    pub requested: u64,
    pub available: u64,
    pub budget: u64,
    pub category: Category,
}

impl fmt::Display for OomError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "OOM: {} allocation of {} exceeds available {} (budget {})",
            self.category.label(),
            fmt_human(self.requested),
            fmt_human(self.available),
            fmt_human(self.budget),
        )
    }
}

impl std::error::Error for OomError {}

/// Per-category byte ledger with a hard budget and peak tracking.
///
/// Invariants (property-tested in `rust/tests/proptests.rs`):
/// * `in_use == sum(per-category)` at all times,
/// * a successful `alloc` never pushes `in_use` past `budget`,
/// * `free` never underflows (over-free is clamped and counted),
/// * `peak >= in_use` and `peak` is monotone non-decreasing.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    budget: u64,
    by_category: BTreeMap<Category, u64>,
    in_use: u64,
    peak: u64,
    oom_events: u64,
    overfree_events: u64,
}

impl MemoryLedger {
    pub fn new(budget: u64) -> Self {
        MemoryLedger {
            budget,
            by_category: BTreeMap::new(),
            in_use: 0,
            peak: 0,
            oom_events: 0,
            overfree_events: 0,
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn available(&self) -> u64 {
        self.budget.saturating_sub(self.in_use)
    }

    pub fn oom_events(&self) -> u64 {
        self.oom_events
    }

    pub fn overfree_events(&self) -> u64 {
        self.overfree_events
    }

    pub fn category(&self, c: Category) -> u64 {
        self.by_category.get(&c).copied().unwrap_or(0)
    }

    /// Attempt an allocation; fails with [`OomError`] past the budget.
    pub fn alloc(&mut self, c: Category, bytes: u64) -> Result<(), OomError> {
        if bytes > self.available() {
            self.oom_events += 1;
            return Err(OomError {
                requested: bytes,
                available: self.available(),
                budget: self.budget,
                category: c,
            });
        }
        *self.by_category.entry(c).or_insert(0) += bytes;
        self.in_use += bytes;
        self.peak = self.peak.max(self.in_use);
        Ok(())
    }

    /// Free bytes from a category; clamps at zero (never underflows).
    pub fn free(&mut self, c: Category, bytes: u64) {
        let e = self.by_category.entry(c).or_insert(0);
        let f = bytes.min(*e);
        if f < bytes {
            self.overfree_events += 1;
        }
        *e -= f;
        self.in_use -= f;
    }

    /// Charge a whole footprint atomically: all categories or nothing.
    pub fn charge_footprint(
        &mut self,
        fp: &FootprintBreakdown,
    ) -> Result<(), OomError> {
        if fp.total() > self.available() {
            self.oom_events += 1;
            // report the category that pushes past the line
            let mut acc = self.available();
            let mut blame = Category::Parameters;
            for (c, b) in fp.rows() {
                if b > acc {
                    blame = c;
                    break;
                }
                acc -= b;
            }
            return Err(OomError {
                requested: fp.total(),
                available: self.available(),
                budget: self.budget,
                category: blame,
            });
        }
        for (c, b) in fp.rows() {
            // lint:allow(D004): the budget check above covers the sum
            self.alloc(c, b).expect("pre-checked");
        }
        Ok(())
    }

    pub fn release_footprint(&mut self, fp: &FootprintBreakdown) {
        for (c, b) in fp.rows() {
            self.free(c, b);
        }
    }
}

/// The analytical footprint of one fine-tuning job.
#[derive(Debug, Clone, PartialEq)]
pub struct FootprintBreakdown {
    pub parameters: u64,
    pub gradients: u64,
    pub optimizer_state: u64,
    pub activations: u64,
    pub runtime: u64,
}

impl FootprintBreakdown {
    pub fn total(&self) -> u64 {
        self.parameters
            + self.gradients
            + self.optimizer_state
            + self.activations
            + self.runtime
    }

    pub fn rows(&self) -> [(Category, u64); 5] {
        [
            (Category::Runtime, self.runtime),
            (Category::Parameters, self.parameters),
            (Category::Gradients, self.gradients),
            (Category::OptimizerState, self.optimizer_state),
            (Category::Activations, self.activations),
        ]
    }
}

/// Parameters of the trainable side module that split tuning keeps
/// server-side: the classification head `[d_model, n_classes]` plus its
/// bias.  The paper's personalization tasks are binary classification,
/// so the analytic model fixes `n_classes = 2`; sessions that know the
/// real head shape account exact bytes via the runtime state instead.
pub fn split_side_params(dims: &ModelDims) -> u64 {
    dims.d_model as u64 * 2 + 2
}

/// Analytical footprint for fine-tuning `dims` with `family` at
/// (batch, seq).  `runtime` uses the Termux+PyTorch figure baked into the
/// Reno 6 preset via [`finetune_footprint_with_runtime`]'s caller; this
/// helper uses the paper's stack (2.6 GB) to stay comparable to Table 1.
pub fn finetune_footprint(
    dims: &ModelDims,
    family: OptimizerFamily,
    batch: usize,
    seq: usize,
) -> FootprintBreakdown {
    finetune_footprint_with_runtime(dims, family, batch, seq,
                                    (2.6 * 1e9) as u64)
}

/// Footprint with an explicit runtime-overhead charge (the fixed cost of
/// the framework stack: 2.6 GB for Termux+PyTorch, ~0.1 GB for this
/// crate's rust+PJRT runtime — the ablation bench contrasts the two).
pub fn finetune_footprint_with_runtime(
    dims: &ModelDims,
    family: OptimizerFamily,
    batch: usize,
    seq: usize,
    runtime_bytes: u64,
) -> FootprintBreakdown {
    let p = dims.n_params();
    let d = dims.d_model as u64;
    let ff = dims.d_ff as u64;
    let b = batch as u64;
    let s = seq as u64;
    let parameters = p * dims.param_bytes;

    match family {
        OptimizerFamily::DerivativeFree => {
            // No autograd graph: XLA/torch-no-grad frees each layer's
            // activations as soon as the next consumes them.  Peak live
            // set ~= widest pair of adjacent buffers (the d->ff GEMM) +
            // attention scores for one layer, in compute precision.
            let live = b * s * (2 * d + ff) * 4
                + b * (dims.n_heads as u64) * s * s * 4;
            FootprintBreakdown {
                parameters,
                gradients: 0,
                optimizer_state: 0,
                activations: live,
                runtime: runtime_bytes,
            }
        }
        OptimizerFamily::SplitForward => {
            // Same single-forward live set as MeZO (frozen pass, no
            // autograd), but the trainable side module and its
            // optimizer state live server-side: the parameter row
            // sheds the head bytes.  The link staging buffer (pooled
            // activations up, refreshed head down) is a sub-slice of
            // buffers already counted in `live`, so it adds nothing
            // at peak.
            let live = b * s * (2 * d + ff) * 4
                + b * (dims.n_heads as u64) * s * s * 4;
            let side = split_side_params(dims);
            FootprintBreakdown {
                parameters: p.saturating_sub(side) * dims.param_bytes,
                gradients: 0,
                optimizer_state: 0,
                activations: live,
                runtime: runtime_bytes,
            }
        }
        OptimizerFamily::DerivativeBased => {
            // Backprop retains per-layer GEMM inputs + attention
            // probabilities across ALL layers: the batch-proportional
            // term that blows up Table 1's bs=64 column.
            let l = dims.n_layers as u64;
            let per_layer = b * s * (6 * d + 2 * ff) * 4
                + b * (dims.n_heads as u64) * s * s * 4;
            FootprintBreakdown {
                parameters,
                gradients: p * 4,
                optimizer_state: 2 * p * 4,
                activations: l * per_layer,
                runtime: runtime_bytes,
            }
        }
    }
}

/// Footprint for derivative-based fine-tuning with gradient accumulation:
/// the standard counter-argument to the paper's OOM result (activations
/// scale with the *micro*-batch).  Gradients + Adam state stay fully
/// resident, so MeZO still wins by ~3 parameter sets — the ablation
/// report quantifies exactly how much of the gap survives.
pub fn finetune_footprint_grad_accum(
    dims: &ModelDims,
    batch: usize,
    seq: usize,
    microbatch: usize,
) -> FootprintBreakdown {
    finetune_footprint_grad_accum_with_runtime(dims, batch, seq,
                                               microbatch,
                                               (2.6 * 1e9) as u64)
}

/// Gradient-accumulation footprint with an explicit runtime-overhead
/// charge, mirroring [`finetune_footprint_with_runtime`]'s signature so
/// the ablation can model stacks other than Termux+PyTorch (e.g. this
/// crate's ~0.3 GB rust runtime).
pub fn finetune_footprint_grad_accum_with_runtime(
    dims: &ModelDims,
    batch: usize,
    seq: usize,
    microbatch: usize,
    runtime_bytes: u64,
) -> FootprintBreakdown {
    let micro = microbatch.min(batch).max(1);
    let full = finetune_footprint_with_runtime(
        dims, OptimizerFamily::DerivativeBased, micro, seq,
        runtime_bytes);
    // accumulation buffer == gradient tensor (already charged); only the
    // activation term shrinks to the microbatch
    full
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GB;

    fn rl() -> ModelDims {
        ModelDims::roberta_large()
    }

    #[test]
    fn ledger_alloc_free_roundtrip() {
        let mut l = MemoryLedger::new(1000);
        l.alloc(Category::Parameters, 400).unwrap();
        l.alloc(Category::Activations, 500).unwrap();
        assert_eq!(l.in_use(), 900);
        assert_eq!(l.available(), 100);
        assert!(l.alloc(Category::Workspace, 200).is_err());
        assert_eq!(l.oom_events(), 1);
        l.free(Category::Activations, 500);
        l.alloc(Category::Workspace, 200).unwrap();
        assert_eq!(l.peak(), 900.max(l.in_use()));
    }

    #[test]
    fn overfree_is_clamped() {
        let mut l = MemoryLedger::new(100);
        l.alloc(Category::Workspace, 10).unwrap();
        l.free(Category::Workspace, 50);
        assert_eq!(l.in_use(), 0);
        assert_eq!(l.overfree_events(), 1);
    }

    #[test]
    fn table1_shape_mezo_flat_adam_grows() {
        // Table 1's qualitative content, from the analytic model alone.
        let m8 = finetune_footprint(&rl(), OptimizerFamily::DerivativeFree, 8, 32);
        let m64 = finetune_footprint(&rl(), OptimizerFamily::DerivativeFree, 64, 32);
        let a8 = finetune_footprint(&rl(), OptimizerFamily::DerivativeBased, 8, 32);
        let a64 = finetune_footprint(&rl(), OptimizerFamily::DerivativeBased, 64, 32);
        // MeZO ~flat: growing batch 8x adds < 15% memory
        assert!(m64.total() < m8.total() * 115 / 100);
        // Adam at bs8 already far above MeZO
        assert!(a8.total() > m8.total() * 14 / 10);
        // Adam grows materially with batch
        assert!(a64.total() > a8.total() * 12 / 10);
    }

    #[test]
    fn table1_absolute_bands() {
        // Paper: MeZO ~4.0-4.8 GB, Adam ~6.5-6.7 GB @ bs8 (seq ~32-128
        // for SST-2), RoBERTa-large, on the Reno 6 stack.
        let m = finetune_footprint(&rl(), OptimizerFamily::DerivativeFree, 8, 128);
        assert!((3_600_000_000..5_200_000_000u64).contains(&m.total()),
                "mezo bs8: {}", m.total());
        let a = finetune_footprint(&rl(), OptimizerFamily::DerivativeBased, 8, 32);
        assert!((6_000_000_000..9_500_000_000u64).contains(&a.total()),
                "adam bs8: {}", a.total());
    }

    #[test]
    fn opt13b_fits_in_reno6() {
        // Paper §4.3: OPT-1.3B fine-tunes under MeZO in ~6.5 GB (fp16).
        let m = finetune_footprint(&ModelDims::opt_1_3b(),
                                   OptimizerFamily::DerivativeFree, 16, 128);
        assert!(m.total() < 8 * GB, "{}", m.total());
        assert!(m.total() > 4 * GB, "{}", m.total());
    }

    #[test]
    fn param_row_charges_storage_byte_width() {
        // fp16 storage halves ONLY the parameter row; grads + moments
        // stay fp32 — the simulated ledger now matches what the host
        // keeps resident per precision
        let mut half = rl();
        half.param_bytes = 2;
        let f32_fp = finetune_footprint(
            &rl(), OptimizerFamily::DerivativeBased, 8, 32);
        let f16_fp = finetune_footprint(
            &half, OptimizerFamily::DerivativeBased, 8, 32);
        assert_eq!(f16_fp.parameters * 2, f32_fp.parameters);
        assert_eq!(f16_fp.gradients, f32_fp.gradients);
        assert_eq!(f16_fp.optimizer_state, f32_fp.optimizer_state);
        assert_eq!(f16_fp.activations, f32_fp.activations);
        // MeZO at fp16 halves its whole model-state footprint
        let m32 = finetune_footprint(
            &rl(), OptimizerFamily::DerivativeFree, 8, 32);
        let m16 = finetune_footprint(
            &half, OptimizerFamily::DerivativeFree, 8, 32);
        assert_eq!(
            m32.parameters - m16.parameters,
            rl().n_params() * 2,
            "fp16 MeZO saves 2 bytes/param of resident storage"
        );
    }

    #[test]
    fn mezo_has_zero_optimizer_rows() {
        let m = finetune_footprint(&rl(), OptimizerFamily::DerivativeFree, 8, 64);
        assert_eq!(m.gradients, 0);
        assert_eq!(m.optimizer_state, 0);
        let a = finetune_footprint(&rl(), OptimizerFamily::DerivativeBased, 8, 64);
        assert_eq!(a.gradients, rl().n_params() * 4);
        assert_eq!(a.optimizer_state, 2 * rl().n_params() * 4);
    }

    #[test]
    fn split_sheds_the_side_module() {
        let m = finetune_footprint(&rl(), OptimizerFamily::DerivativeFree, 8, 64);
        let s = finetune_footprint(&rl(), OptimizerFamily::SplitForward, 8, 64);
        assert_eq!(s.gradients, 0);
        assert_eq!(s.optimizer_state, 0);
        assert_eq!(s.activations, m.activations,
                   "split runs the same single-forward live set");
        let side = split_side_params(&rl());
        assert_eq!(m.parameters - s.parameters, side * rl().param_bytes);
        assert!(s.total() < m.total());
        // int8 storage keeps the ordering the link bench pins
        let mut q = rl();
        q.param_bytes = 1;
        let mq = finetune_footprint(&q, OptimizerFamily::DerivativeFree, 8, 64);
        let sq = finetune_footprint(&q, OptimizerFamily::SplitForward, 8, 64);
        assert!(sq.total() < mq.total());
        assert_eq!(mq.parameters - sq.parameters, side);
    }

    #[test]
    fn grad_accum_shrinks_activations_but_not_states() {
        let dims = rl();
        let full = finetune_footprint(&dims,
                                      OptimizerFamily::DerivativeBased,
                                      64, 32);
        let accum = finetune_footprint_grad_accum(&dims, 64, 32, 8);
        let mezo = finetune_footprint(&dims,
                                      OptimizerFamily::DerivativeFree,
                                      64, 32);
        // accumulation rescues Adam from the bs-64 OOM...
        assert!(accum.total() < full.total());
        assert!(accum.activations < full.activations / 4);
        // ...but the 3 parameter-sized states remain: MeZO still wins
        assert_eq!(accum.gradients, dims.n_params() * 4);
        assert!(accum.total() > mezo.total() + 3 * dims.n_params() * 4);

        // the runtime charge is a parameter, not a Termux constant:
        // the rust-runtime stack shaves exactly the runtime delta
        let termux = (2.6 * 1e9) as u64;
        let rust_rt = (0.3 * 1e9) as u64;
        let lean = finetune_footprint_grad_accum_with_runtime(
            &dims, 64, 32, 8, rust_rt);
        assert_eq!(lean.runtime, rust_rt);
        assert_eq!(accum.runtime, termux);
        assert_eq!(accum.total() - lean.total(), termux - rust_rt);
        assert_eq!(lean.activations, accum.activations);
        assert_eq!(lean.gradients, accum.gradients);
    }

    #[test]
    fn footprint_charge_is_atomic() {
        let fp = finetune_footprint(&rl(), OptimizerFamily::DerivativeBased, 64, 32);
        let mut l = MemoryLedger::new(5 * GB);
        assert!(l.charge_footprint(&fp).is_err());
        assert_eq!(l.in_use(), 0, "failed charge must not leak partial allocs");
    }
}
