//! Device hardware envelopes and model dimension records.
//!
//! A [`DeviceSpec`] captures exactly the hardware facts the paper's
//! numbers depend on: total RAM, how much of it the OS keeps, sustained
//! (not peak) FLOP throughput for forward- and backward-shaped work,
//! memory bandwidth, and a thermal throttle curve.
//!
//! Calibration (see DESIGN.md §2 and EXPERIMENTS.md):
//! * `oppo-reno6` — Dimensity 900 (2×A78 + 6×A55), 12 GB LPDDR4X.
//!   Sustained f32 GEMM throughput under Termux/PyTorch is far below
//!   peak; fitted to the paper's Table 2 wall-clocks.
//! * `rtx3090-server` — fitted to the paper's §4.4 "1.99 s/step for
//!   OPT-1.3B", i.e. ~30% of the card's 35.6 TFLOPs peak.

use crate::util::bytes::GB;

/// Thermal throttling: sustained load reduces effective throughput.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Seconds of sustained load before throttling begins.
    pub onset_s: f64,
    /// Steady-state throughput multiplier once fully throttled.
    pub floor: f64,
    /// Seconds over which throughput decays from 1.0 to `floor`.
    pub decay_s: f64,
}

impl ThermalModel {
    pub fn none() -> Self {
        ThermalModel { onset_s: f64::INFINITY, floor: 1.0, decay_s: 1.0 }
    }

    /// Effective throughput multiplier after `t` seconds of sustained load.
    pub fn factor(&self, t: f64) -> f64 {
        if t <= self.onset_s {
            return 1.0;
        }
        let progress = ((t - self.onset_s) / self.decay_s).min(1.0);
        1.0 - progress * (1.0 - self.floor)
    }
}

/// Hardware envelope of one device.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    /// Total physical RAM.
    pub ram_bytes: u64,
    /// RAM the OS + resident apps keep for themselves; the fine-tuning
    /// process can never have it.  (Android keeps several GB on a 12 GB
    /// phone; the paper's OOMs happen against this reduced budget.)
    pub os_reserved_bytes: u64,
    /// Fixed per-process runtime overhead charged to any fine-tuning job:
    /// interpreter + framework + loaded libraries.  The paper's Termux +
    /// PyTorch stack measures ~2.6 GB before any tensor is allocated; our
    /// rust+PJRT stack is far leaner, but the simulated phone charges the
    /// paper's stack because that is the system being modelled.
    pub runtime_overhead_bytes: u64,
    /// Peak sustained throughput for inference-shaped (forward-only) work,
    /// in GFLOP/s, at full utilization.  MeZO steps are two forwards.
    pub fwd_gflops: f64,
    /// Peak sustained throughput for training-shaped (fwd+bwd) work,
    /// GFLOP/s.  Backprop is GEMM-richer and utilizes wider units.
    pub bwd_gflops: f64,
    /// Utilization half-saturation batch size: effective throughput is
    /// `peak * b / (b + sat_half_batch)`.  Phones saturate slowly (small
    /// GEMMs parallelize poorly across big.LITTLE NEON units) — this is
    /// exactly why the paper's Table 2 shows only 97→123 s when batch
    /// grows 8 -> 64.  GPUs saturate almost immediately at LLM sizes.
    pub sat_half_batch: f64,
    /// Memory bandwidth, GB/s (used for the bandwidth-bound term).
    pub mem_bw_gbps: f64,
    pub thermal: ThermalModel,
}

impl DeviceSpec {
    /// Memory available to one fine-tuning process.
    pub fn app_memory_budget(&self) -> u64 {
        self.ram_bytes - self.os_reserved_bytes
    }
}

/// The model dimensions the analytic memory/time models need.  Mirrors
/// `python/compile/model.py::ModelConfig`; constructors for the paper's
/// two subjects are kept in sync with the manifest (tested in
/// `rust/tests/integration.rs`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub decoder: bool,
    /// Bytes per parameter as deployed (4 = fp32; 2 = fp16).  The paper
    /// runs RoBERTa-large in fp32 and OPT-1.3B in half precision (the
    /// MeZO reference setup) — this is what makes OPT-1.3B's measured
    /// 6.5 GB possible at all: 1.32B fp32 params alone would be 5.3 GB.
    pub param_bytes: u64,
}

impl ModelDims {
    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff as u64;
        let v = self.vocab as u64;
        let s = self.max_seq as u64;
        let per_layer = 4 * (d * d + d) // qkv+o
            + d * ff + ff + ff * d + d  // ffn
            + 4 * d; // 2 layernorms
        let head = if self.decoder { 0 } else { d * 2 + 2 };
        v * d + s * d + self.n_layers as u64 * per_layer + 2 * d + head
    }

    /// FLOPs for ONE forward pass over `batch*seq` tokens.  The standard
    /// 2·P·T estimate plus the attention quadratic term.
    pub fn forward_flops(&self, batch: usize, seq: usize) -> f64 {
        let tokens = (batch * seq) as f64;
        let dense = 2.0 * self.n_params() as f64 * tokens;
        let attn = 4.0
            * self.n_layers as f64
            * (batch as f64)
            * (seq as f64)
            * (seq as f64)
            * self.d_model as f64;
        dense + attn
    }

    /// RoBERTa-large (355M, fp32): the paper's Table 1/2 subject.
    pub fn roberta_large() -> Self {
        ModelDims {
            name: "roberta-large".into(),
            vocab: 50265,
            d_model: 1024,
            n_layers: 24,
            n_heads: 16,
            d_ff: 4096,
            max_seq: 512,
            decoder: false,
            param_bytes: 4,
        }
    }

    /// OPT-1.3B (fp16, the MeZO reference setup): the §4.3/4.4 subject.
    pub fn opt_1_3b() -> Self {
        ModelDims {
            name: "opt-1.3b".into(),
            vocab: 50272,
            d_model: 2048,
            n_layers: 24,
            n_heads: 32,
            d_ff: 8192,
            max_seq: 2048,
            decoder: true,
            param_bytes: 2,
        }
    }
}

/// Built-in device presets.
pub fn preset(name: &str) -> Option<DeviceSpec> {
    let spec = match name {
        // The paper's testbed.  12 GB phone; Android + resident apps keep
        // ~2 GB under memory pressure; Termux+PyTorch runtime ~2.6 GB.
        // fwd/bwd peaks + sat_half fitted to Table 2: with SST-2-length
        // sequences (~32 tokens) and u(b)=b/(b+200), the model reproduces
        // MeZO 97 s @bs8 -> 125 s @bs64 and Adam 75 s @bs8.  Peak ~96
        // GFLOP/s f32 is consistent with 2xA78 + 6xA55 NEON.
        "oppo-reno6" => DeviceSpec {
            name: "oppo-reno6".into(),
            ram_bytes: 12 * GB,
            os_reserved_bytes: 2 * GB,
            runtime_overhead_bytes: (2.6 * GB as f64) as u64,
            fwd_gflops: 96.0,
            bwd_gflops: 192.0,
            sat_half_batch: 200.0,
            mem_bw_gbps: 17.0, // LPDDR4X-4266 x2ch effective
            thermal: ThermalModel { onset_s: 120.0, floor: 0.65, decay_s: 180.0 },
        },
        // The paper's GPU comparator (§4.4): RTX 3090 server.  ~30% of
        // the card's 35.6 TFLOPs f32 peak sustained, saturating at tiny
        // batch for billion-parameter models — fits "1.99 s/step".
        "rtx3090-server" => DeviceSpec {
            name: "rtx3090-server".into(),
            ram_bytes: 256 * GB,
            os_reserved_bytes: 8 * GB,
            runtime_overhead_bytes: (2.0 * GB as f64) as u64,
            fwd_gflops: 11_000.0,
            bwd_gflops: 14_000.0,
            sat_half_batch: 1.0,
            mem_bw_gbps: 936.0,
            thermal: ThermalModel::none(),
        },
        // A smaller phone: the 1 GB-per-app regime §6.1 worries about.
        "pixel-4a" => DeviceSpec {
            name: "pixel-4a".into(),
            ram_bytes: 6 * GB,
            os_reserved_bytes: (1.8 * GB as f64) as u64,
            runtime_overhead_bytes: (2.2 * GB as f64) as u64,
            fwd_gflops: 54.0,
            bwd_gflops: 108.0,
            sat_half_batch: 240.0,
            mem_bw_gbps: 13.0,
            thermal: ThermalModel { onset_s: 90.0, floor: 0.55, decay_s: 150.0 },
        },
        // The edge device prior work (PockEngine et al.) targets.
        "raspberry-pi4" => DeviceSpec {
            name: "raspberry-pi4".into(),
            ram_bytes: 8 * GB,
            os_reserved_bytes: 1 * GB,
            runtime_overhead_bytes: (1.8 * GB as f64) as u64,
            fwd_gflops: 24.0,
            bwd_gflops: 48.0,
            sat_half_batch: 100.0,
            mem_bw_gbps: 4.0,
            thermal: ThermalModel { onset_s: 60.0, floor: 0.7, decay_s: 120.0 },
        },
        // A low-end 3 GB handset: with the Termux+PyTorch stack charged,
        // only derivative-free fine-tuning fits at all.  Used by the
        // coordinator's OOM-fallback tests and the frontier report.
        "budget-phone-3gb" => DeviceSpec {
            name: "budget-phone-3gb".into(),
            ram_bytes: 3 * GB,
            os_reserved_bytes: (0.25 * GB as f64) as u64,
            runtime_overhead_bytes: (2.6 * GB as f64) as u64,
            fwd_gflops: 30.0,
            bwd_gflops: 60.0,
            sat_half_batch: 300.0,
            mem_bw_gbps: 8.0,
            thermal: ThermalModel { onset_s: 60.0, floor: 0.5, decay_s: 120.0 },
        },
        // This machine (for relating measured pocket-scale numbers to the
        // simulated devices).  Throughput is calibrated at runtime by the
        // bench harness, so these are placeholders.
        "host" => DeviceSpec {
            name: "host".into(),
            ram_bytes: 64 * GB,
            os_reserved_bytes: 4 * GB,
            runtime_overhead_bytes: (0.3 * GB as f64) as u64,
            fwd_gflops: 80.0,
            bwd_gflops: 120.0,
            sat_half_batch: 8.0,
            mem_bw_gbps: 25.0,
            thermal: ThermalModel::none(),
        },
        _ => return None,
    };
    Some(spec)
}

pub fn preset_names() -> &'static [&'static str] {
    &["oppo-reno6", "rtx3090-server", "pixel-4a", "raspberry-pi4",
      "budget-phone-3gb", "host"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_param_counts() {
        // must mirror python's model.num_params (tested cross-language in
        // the integration suite via manifest.json)
        let rl = ModelDims::roberta_large().n_params();
        assert!((330_000_000..380_000_000).contains(&rl), "{rl}");
        let opt = ModelDims::opt_1_3b().n_params();
        assert!((1_250_000_000..1_400_000_000).contains(&opt), "{opt}");
    }

    #[test]
    fn all_presets_resolve() {
        for name in preset_names() {
            let s = preset(name).unwrap();
            assert!(s.app_memory_budget() > 0);
            assert!(s.fwd_gflops > 0.0);
        }
    }

    #[test]
    fn thermal_factor_monotone() {
        let t = ThermalModel { onset_s: 10.0, floor: 0.5, decay_s: 10.0 };
        assert_eq!(t.factor(0.0), 1.0);
        assert_eq!(t.factor(10.0), 1.0);
        assert!((t.factor(15.0) - 0.75).abs() < 1e-9);
        assert_eq!(t.factor(1000.0), 0.5);
        assert!(ThermalModel::none().factor(1e9) == 1.0);
    }

    #[test]
    fn forward_flops_scale_with_batch() {
        let d = ModelDims::roberta_large();
        let f8 = d.forward_flops(8, 128);
        let f64_ = d.forward_flops(64, 128);
        assert!((f64_ / f8 - 8.0).abs() < 0.01);
    }
}
