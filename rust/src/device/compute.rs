//! Step-time model — the mechanism behind the paper's Table 2 and the
//! §4.4 phone-vs-GPU gap.
//!
//! Per-step wall-clock is modelled as
//!
//! ```text
//!   t = flops / (peak * u(batch) * thermal) + bytes / bandwidth
//! ```
//!
//! where `u(b) = b / (b + sat_half)` is the utilization saturation curve.
//! That curve is the key observation the paper's own numbers force: on
//! the Reno 6 an 8x batch increase costs only 97 s -> 123 s, i.e. small
//! batches leave the NEON units mostly idle.  GPUs have `sat_half ~= 1`
//! (saturated immediately at LLM widths), which is also why the 3090 is
//! ~1000x faster on OPT-1.3B (§4.4) while its peak-FLOPs advantage is
//! only ~100x.
//!
//! MeZO steps are **two forwards** (the ±eps·z evaluations); Adam steps
//! are forward + backward, with backward ≈ 2 forwards of FLOPs running
//! at the (higher-utilization) training throughput.

use super::spec::{DeviceSpec, ModelDims};
use super::OptimizerFamily;

/// Component timings for one step (seconds).
#[derive(Debug, Clone)]
pub struct StepTimeBreakdown {
    pub compute_s: f64,
    pub memory_s: f64,
    /// FLOPs executed in this step.
    pub flops: f64,
    /// Effective throughput achieved (GFLOP/s).
    pub effective_gflops: f64,
}

impl StepTimeBreakdown {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.memory_s
    }
}

/// Compute model bound to one device spec.
pub struct ComputeModel {
    spec: DeviceSpec,
    /// Seconds of sustained load so far (drives the thermal model).
    sustained_s: f64,
}

impl ComputeModel {
    pub fn new(spec: DeviceSpec) -> Self {
        ComputeModel { spec, sustained_s: 0.0 }
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// Utilization at a given batch size.
    pub fn utilization(&self, batch: usize) -> f64 {
        let b = batch as f64;
        b / (b + self.spec.sat_half_batch)
    }

    /// Step FLOPs for an optimizer family.
    pub fn step_flops(
        &self,
        dims: &ModelDims,
        family: OptimizerFamily,
        batch: usize,
        seq: usize,
    ) -> f64 {
        let fwd = dims.forward_flops(batch, seq);
        match family {
            // two perturbed forward evaluations
            OptimizerFamily::DerivativeFree => 2.0 * fwd,
            // forward + backward (~2x forward)
            OptimizerFamily::DerivativeBased => 3.0 * fwd,
            // one frozen-backbone forward; the side module trains
            // server-side and its FLOPs are not the device's
            OptimizerFamily::SplitForward => fwd,
        }
    }

    /// Predicted step time at the current thermal state.
    pub fn step_time(
        &self,
        dims: &ModelDims,
        family: OptimizerFamily,
        batch: usize,
        seq: usize,
    ) -> StepTimeBreakdown {
        let flops = self.step_flops(dims, family, batch, seq);
        let peak = match family {
            OptimizerFamily::DerivativeFree
            | OptimizerFamily::SplitForward => self.spec.fwd_gflops,
            OptimizerFamily::DerivativeBased => self.spec.bwd_gflops,
        } * 1e9;
        let thermal = self.spec.thermal.factor(self.sustained_s);
        let eff = peak * self.utilization(batch) * thermal;
        let compute_s = flops / eff;

        // streaming term: parameters are swept once per pass (plus state
        // updates for Adam); activations traffic is folded into `eff`.
        let passes = match family {
            OptimizerFamily::DerivativeFree => 2.0,
            OptimizerFamily::DerivativeBased => 6.0, // fwd+bwd+g+m+v+p
            OptimizerFamily::SplitForward => 1.0,    // single forward
        };
        let bytes = dims.n_params() as f64 * dims.param_bytes as f64 * passes;
        let memory_s = bytes / (self.spec.mem_bw_gbps * 1e9);

        StepTimeBreakdown {
            compute_s,
            memory_s,
            flops,
            effective_gflops: eff / 1e9,
        }
    }

    /// Seconds of accumulated load one idle second removes: phones shed
    /// heat slower than they build it under sustained load, so idle
    /// recovery is deliberately not 1:1.
    pub const COOL_RATE: f64 = 0.5;

    /// Advance the thermal clock by `dt` seconds of sustained load.
    pub fn advance(&mut self, dt: f64) {
        self.sustained_s += dt;
    }

    /// Partial idle recovery: `dt_s` seconds of idle time walk the
    /// thermal clock back by `dt_s * COOL_RATE`, clamped at fully
    /// cool.  This is what a denied scheduler window credits — a
    /// single idle 10-minute tick must NOT reset a device that has
    /// been throttling for an hour (that was the old `cool_down()`
    /// bug; pinned in `cool_for_is_partial_recovery`).
    pub fn cool_for(&mut self, dt_s: f64) {
        self.sustained_s =
            (self.sustained_s - dt_s * Self::COOL_RATE).max(0.0);
    }

    /// Full cool-down (long idle / session teardown): thermal clock
    /// resets to ambient.
    pub fn cool_down(&mut self) {
        self.sustained_s = 0.0;
    }

    pub fn sustained_s(&self) -> f64 {
        self.sustained_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::preset;

    const SST2_SEQ: usize = 32; // SST-2 sentences are short

    fn reno6() -> ComputeModel {
        ComputeModel::new(preset("oppo-reno6").unwrap())
    }

    #[test]
    fn table2_mezo_bs8_about_97s() {
        let t = reno6().step_time(&ModelDims::roberta_large(),
                                  OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        assert!((70.0..130.0).contains(&t.total_s()), "{}", t.total_s());
    }

    #[test]
    fn table2_mezo_bs64_sublinear() {
        // paper: 97 s -> ~123 s for 8x the batch
        let m = reno6();
        let t8 = m.step_time(&ModelDims::roberta_large(),
                             OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        let t64 = m.step_time(&ModelDims::roberta_large(),
                              OptimizerFamily::DerivativeFree, 64, SST2_SEQ);
        let ratio = t64.total_s() / t8.total_s();
        assert!((1.05..2.0).contains(&ratio), "ratio {}", ratio);
    }

    #[test]
    fn table2_adam_bs8_about_74s() {
        let t = reno6().step_time(&ModelDims::roberta_large(),
                                  OptimizerFamily::DerivativeBased, 8, SST2_SEQ);
        assert!((55.0..100.0).contains(&t.total_s()), "{}", t.total_s());
    }

    #[test]
    fn sec44_opt13b_phone_vs_gpu_gap() {
        // paper: ~1800 s/step on the phone vs 1.99 s on the 3090 (~1000x)
        let phone = reno6().step_time(&ModelDims::opt_1_3b(),
                                      OptimizerFamily::DerivativeFree, 16, 128);
        let gpu = ComputeModel::new(preset("rtx3090-server").unwrap())
            .step_time(&ModelDims::opt_1_3b(),
                       OptimizerFamily::DerivativeFree, 16, 128);
        assert!((900.0..3500.0).contains(&phone.total_s()),
                "phone {}", phone.total_s());
        assert!((0.5..5.0).contains(&gpu.total_s()), "gpu {}", gpu.total_s());
        let gap = phone.total_s() / gpu.total_s();
        assert!((300.0..3000.0).contains(&gap), "gap {}", gap);
    }

    #[test]
    fn thermal_throttling_slows_steps() {
        let mut m = reno6();
        let cold = m.step_time(&ModelDims::roberta_large(),
                               OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        m.advance(600.0);
        let hot = m.step_time(&ModelDims::roberta_large(),
                              OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        assert!(hot.total_s() > cold.total_s() * 1.2);
        m.cool_down();
        let cooled = m.step_time(&ModelDims::roberta_large(),
                                 OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        assert!((cooled.total_s() - cold.total_s()).abs() < 1e-9);
    }

    #[test]
    fn cool_for_is_partial_recovery() {
        let mut m = reno6();
        m.advance(1800.0);
        // two adjacent denied 10-minute windows: each credits
        // 600 s * COOL_RATE = 300 s of load-clock
        m.cool_for(600.0);
        assert!((m.sustained_s() - 1500.0).abs() < 1e-9,
                "{}", m.sustained_s());
        m.cool_for(600.0);
        assert!((m.sustained_s() - 1200.0).abs() < 1e-9,
                "{}", m.sustained_s());
        assert!(m.sustained_s() > 0.0,
                "two denied ticks must not fully reset the thermal clock");
        // a long idle stretch clamps at fully cool
        m.cool_for(1e9);
        assert_eq!(m.sustained_s(), 0.0);
    }

    #[test]
    fn cool_for_keeps_hot_device_throttled() {
        // behavioural version: after an hour of load, one idle tick
        // must leave step times slower than cold
        let mut m = reno6();
        let cold = m.step_time(&ModelDims::roberta_large(),
                               OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        m.advance(3600.0);
        m.cool_for(600.0);
        let warm = m.step_time(&ModelDims::roberta_large(),
                               OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        assert!(warm.total_s() > cold.total_s() * 1.1,
                "one denied tick fully cooled the device: {} vs {}",
                warm.total_s(), cold.total_s());
    }

    #[test]
    fn split_forward_halves_the_mezo_step() {
        // one frozen forward vs MeZO's two perturbed forwards, and one
        // parameter sweep vs two: split device time is half a MeZO step
        let m = reno6();
        let split = m.step_time(&ModelDims::roberta_large(),
                                OptimizerFamily::SplitForward, 8, SST2_SEQ);
        let mezo = m.step_time(&ModelDims::roberta_large(),
                               OptimizerFamily::DerivativeFree, 8, SST2_SEQ);
        assert!((split.total_s() * 2.0 - mezo.total_s()).abs() < 1e-9,
                "split {} vs mezo {}", split.total_s(), mezo.total_s());
    }

    #[test]
    fn utilization_saturates() {
        let m = reno6();
        assert!(m.utilization(8) < m.utilization(64));
        assert!(m.utilization(100_000) > 0.99);
    }
}
