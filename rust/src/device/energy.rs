//! Energy model: what fine-tuning costs in battery — the constraint the
//! paper's deployment story lives under (its overnight/charging policy
//! exists exactly because of this).
//!
//! Simple but calibrated: sustained full-tilt compute on a Dimensity-900
//! class SoC draws ~4 W package power; a Reno 6 battery holds 4300 mAh
//! @3.85 V ≈ 16.6 Wh.  Energy per step = watts × step seconds, so a
//! single RoBERTa-large MeZO step (~97 s) costs ~0.11 Wh ≈ 0.65% of the
//! battery — i.e. an *unplugged* phone affords ~150 steps.  This is why
//! the scheduler requires the charger, and it is an honest extension of
//! the paper's analysis (the paper never quantifies energy).

use super::spec::DeviceSpec;

/// Per-device energy envelope.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Package power under sustained fine-tuning load (W).
    pub active_watts: f64,
    /// Idle draw while the job is paused (W).
    pub idle_watts: f64,
    /// Battery capacity (Wh); `f64::INFINITY` for mains-powered devices.
    pub battery_wh: f64,
}

impl EnergyModel {
    /// Calibrated envelope for a device preset.
    pub fn for_spec(spec: &DeviceSpec) -> EnergyModel {
        match spec.name.as_str() {
            "oppo-reno6" => EnergyModel {
                active_watts: 4.0,
                idle_watts: 0.15,
                battery_wh: 16.6, // 4300 mAh @ 3.85 V
            },
            "pixel-4a" => EnergyModel {
                active_watts: 3.2,
                idle_watts: 0.12,
                battery_wh: 12.0,
            },
            "budget-phone-3gb" => EnergyModel {
                active_watts: 2.5,
                idle_watts: 0.10,
                battery_wh: 11.5,
            },
            "raspberry-pi4" => EnergyModel {
                active_watts: 6.5,
                idle_watts: 2.5,
                battery_wh: f64::INFINITY, // mains
            },
            "rtx3090-server" => EnergyModel {
                active_watts: 420.0,
                idle_watts: 60.0,
                battery_wh: f64::INFINITY,
            },
            _ => EnergyModel {
                active_watts: 65.0,
                idle_watts: 10.0,
                battery_wh: f64::INFINITY,
            },
        }
    }

    /// Energy for `seconds` of sustained fine-tuning (Wh).
    pub fn active_wh(&self, seconds: f64) -> f64 {
        self.active_watts * seconds / 3600.0
    }

    /// Battery fraction consumed by `seconds` of load (0..=1; 0 for
    /// mains-powered devices).
    pub fn battery_fraction(&self, seconds: f64) -> f64 {
        if self.battery_wh.is_infinite() {
            0.0
        } else {
            (self.active_wh(seconds) / self.battery_wh).min(1.0)
        }
    }

    /// How many steps of `step_seconds` each fit in `budget_frac` of the
    /// battery (the scheduler's unplugged allowance).
    pub fn steps_within_budget(&self, step_seconds: f64,
                               budget_frac: f64) -> u64 {
        if self.battery_wh.is_infinite() {
            return u64::MAX;
        }
        let budget_wh = self.battery_wh * budget_frac;
        (budget_wh / self.active_wh(step_seconds).max(1e-12)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::preset;

    #[test]
    fn reno6_step_costs_fraction_of_battery() {
        let e = EnergyModel::for_spec(&preset("oppo-reno6").unwrap());
        // one ~97 s RoBERTa-large MeZO step
        let frac = e.battery_fraction(97.0);
        assert!((0.002..0.02).contains(&frac), "{frac}");
        // an unplugged phone affords O(100) steps on 80% of the battery
        let steps = e.steps_within_budget(97.0, 0.8);
        assert!((50..500).contains(&(steps as i64)), "{steps}");
    }

    #[test]
    fn mains_devices_are_unconstrained() {
        let e = EnergyModel::for_spec(&preset("rtx3090-server").unwrap());
        assert_eq!(e.battery_fraction(1e6), 0.0);
        assert_eq!(e.steps_within_budget(10.0, 0.5), u64::MAX);
    }

    #[test]
    fn energy_scales_linearly() {
        let e = EnergyModel::for_spec(&preset("pixel-4a").unwrap());
        assert!((e.active_wh(7200.0) - 2.0 * e.active_wh(3600.0)).abs()
                < 1e-12);
        assert!(e.active_wh(3600.0) > 0.0);
    }

    #[test]
    fn every_preset_has_an_envelope() {
        for name in crate::device::spec::preset_names() {
            let e = EnergyModel::for_spec(&preset(name).unwrap());
            assert!(e.active_watts > 0.0);
            assert!(e.idle_watts < e.active_watts);
        }
    }
}
