//! Mobile-device simulator — the substitution for the paper's OPPO Reno 6.
//!
//! The paper's evaluation is three measurements on a phone: a memory
//! footprint per (model, optimizer, batch) cell (Table 1), a per-step
//! wall-clock (Table 2), and OOM events when the footprint exceeds what
//! Android will give one app.  All three are *functions of the workload
//! shape*, which this module computes explicitly:
//!
//! * [`spec`]     — hardware envelopes ([`DeviceSpec`]) with calibrated
//!                  presets: `oppo-reno6`, `rtx3090-server`, `pixel-4a`,
//!                  `raspberry-pi4`, and `host` (this machine).
//! * [`memory`]   — an allocation ledger with category tagging and OOM
//!                  semantics, plus the analytical fine-tuning footprint
//!                  model (params / grads / optimizer state / activations).
//! * [`compute`]  — the step-time model (FLOPs / effective throughput,
//!                  plus bandwidth term and thermal throttling).
//!
//! Calibration constants come from the paper's own numbers; DESIGN.md §2
//! documents the fit and EXPERIMENTS.md compares model vs. paper for every
//! cell the paper reports.

pub mod compute;
pub mod energy;
pub mod memory;
pub mod spec;

pub use compute::{ComputeModel, StepTimeBreakdown};
pub use energy::EnergyModel;
pub use memory::{FootprintBreakdown, MemoryLedger, OomError, Category};
pub use spec::{DeviceSpec, ModelDims};

/// Which optimizer family a fine-tuning job uses — the axis the paper's
/// whole evaluation pivots on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptimizerFamily {
    /// Derivative-free (MeZO): no grads, no optimizer state, activations
    /// not retained (inference-style forward, twice).
    DerivativeFree,
    /// Derivative-based (Adam): grads + 2x optimizer state + full
    /// activation retention for backprop.
    DerivativeBased,
    /// Split tuning: the frozen backbone runs forward-only on the
    /// device; the trainable side module (and its optimizer state)
    /// lives server-side, so the device keeps no grads, no optimizer
    /// state, and only one forward's live activations.
    SplitForward,
}

impl OptimizerFamily {
    pub fn label(&self) -> &'static str {
        match self {
            OptimizerFamily::DerivativeFree => "MeZo",
            OptimizerFamily::DerivativeBased => "Adam",
            OptimizerFamily::SplitForward => "Split",
        }
    }
}

/// A simulated device: spec + live memory ledger + compute model.
///
/// The tuner drives this alongside the real PJRT execution: every tensor
/// the runtime allocates is mirrored into the ledger scaled to the
/// *simulated* model dimensions, so a pocket-scale run on this host
/// faithfully reproduces the OOM behaviour the paper saw at 355M/1.3B
/// scale on the phone.
pub struct Device {
    pub spec: DeviceSpec,
    pub ledger: MemoryLedger,
    pub compute: ComputeModel,
}

impl Device {
    pub fn new(spec: DeviceSpec) -> Self {
        let budget = spec.app_memory_budget();
        Device {
            ledger: MemoryLedger::new(budget),
            compute: ComputeModel::new(spec.clone()),
            spec,
        }
    }

    pub fn preset(name: &str) -> Option<Self> {
        spec::preset(name).map(Device::new)
    }

    /// Admission check + ledger charge for a fine-tuning job.  Returns the
    /// footprint breakdown, or the OOM error the phone would raise.
    pub fn admit_finetune(
        &mut self,
        dims: &ModelDims,
        family: OptimizerFamily,
        batch: usize,
        seq: usize,
    ) -> Result<FootprintBreakdown, OomError> {
        let fp = memory::finetune_footprint(dims, family, batch, seq);
        self.ledger.charge_footprint(&fp)?;
        Ok(fp)
    }

    /// Release a previously admitted job's memory.
    pub fn release_finetune(
        &mut self,
        dims: &ModelDims,
        family: OptimizerFamily,
        batch: usize,
        seq: usize,
    ) {
        let fp = memory::finetune_footprint(dims, family, batch, seq);
        self.ledger.release_footprint(&fp);
    }

    /// Predicted per-step wall-clock for this device (seconds).
    pub fn step_time(
        &self,
        dims: &ModelDims,
        family: OptimizerFamily,
        batch: usize,
        seq: usize,
    ) -> StepTimeBreakdown {
        self.compute.step_time(dims, family, batch, seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reno6_runs_mezo_but_ooms_adam_bs64() {
        // The paper's headline OOM result, as an admission-control test.
        let dims = ModelDims::roberta_large();
        let mut dev = Device::preset("oppo-reno6").unwrap();
        assert!(dev
            .admit_finetune(&dims, OptimizerFamily::DerivativeFree, 64, 128)
            .is_ok());
        dev.release_finetune(&dims, OptimizerFamily::DerivativeFree, 64, 128);
        assert!(dev
            .admit_finetune(&dims, OptimizerFamily::DerivativeBased, 8, 128)
            .is_ok());
        dev.release_finetune(&dims, OptimizerFamily::DerivativeBased, 8, 128);
        let err = dev
            .admit_finetune(&dims, OptimizerFamily::DerivativeBased, 64, 128)
            .unwrap_err();
        assert!(err.requested > err.available);
    }

    #[test]
    fn release_restores_budget() {
        let dims = ModelDims::roberta_large();
        let mut dev = Device::preset("oppo-reno6").unwrap();
        let before = dev.ledger.in_use();
        dev.admit_finetune(&dims, OptimizerFamily::DerivativeFree, 8, 128)
            .unwrap();
        dev.release_finetune(&dims, OptimizerFamily::DerivativeFree, 8, 128);
        assert_eq!(dev.ledger.in_use(), before);
    }
}
