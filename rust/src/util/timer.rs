//! Timing helpers: stopwatch, exponential moving average, and a tiny
//! statistics accumulator used by the bench harness and telemetry.

use std::time::Instant;

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Exponential moving average (used for step-time smoothing in logs).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Streaming summary statistics (Welford) for benchmark measurements.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(0.0);
        assert!((v - 5.0).abs() < 1e-9);
    }

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - 1.29099).abs() < 1e-4);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }
}
