//! Dependency-free substrates: JSON codec, deterministic PRNG, byte/size
//! formatting, timing helpers, and a tiny CLI argument parser.
//!
//! The offline build environment provides no serde / rand / clap, so the
//! runtime carries its own minimal, well-tested implementations.

pub mod args;
pub mod bytes;
pub mod json;
pub mod rng;
pub mod timer;
