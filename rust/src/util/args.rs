//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports the subset the `pocketllm` launcher needs: a positional
//! subcommand, `--flag value`, `--flag=value`, boolean `--flag`, and
//! repeated flags.  Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw argv (without the program name).  `known` lists the flags
    /// that take a value; every other `--x` is treated as boolean.
    pub fn parse(
        argv: &[String],
        known_value_flags: &[&str],
    ) -> Result<Args, ArgError> {
        let mut out = Args {
            subcommand: None,
            positional: Vec::new(),
            flags: BTreeMap::new(),
        };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let takes_value = known_value_flags.contains(&name.as_str());
                let value = if let Some(v) = inline_val {
                    v
                } else if takes_value {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| {
                            ArgError(format!("--{name} expects a value"))
                        })?
                } else {
                    "true".to_string()
                };
                out.flags.entry(name).or_default().push(value);
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: bad integer '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: bad integer '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError(format!("--{name}: bad number '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(
            &argv(&["finetune", "--model", "pocket-tiny", "--steps=5",
                    "--verbose", "extra"]),
            &["model", "steps"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("finetune"));
        assert_eq!(a.flag("model"), Some("pocket-tiny"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 5);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["x", "--model"]), &["model"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_f64("lr", 1e-3).unwrap(), 1e-3);
    }

    #[test]
    fn repeated_flags() {
        let a = Args::parse(
            &argv(&["r", "--tag", "a", "--tag", "b"]),
            &["tag"],
        )
        .unwrap();
        assert_eq!(a.flag_all("tag"), vec!["a", "b"]);
        assert_eq!(a.flag("tag"), Some("b"));
    }
}
