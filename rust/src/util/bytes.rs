//! Byte-size arithmetic and human-readable formatting.
//!
//! The device memory model traffics in exact byte counts; reports print
//! them the way the paper does (decimal GB, one decimal place).

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;
pub const GB: u64 = 1_000_000_000;
pub const MB: u64 = 1_000_000;
pub const KB: u64 = 1_000;

/// Format as the paper's tables do: decimal GB with one decimal.
pub fn fmt_gb(bytes: u64) -> String {
    format!("{:.1} GB", bytes as f64 / GB as f64)
}

/// Adaptive human formatting (B / KiB / MiB / GiB).
pub fn fmt_human(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{} B", bytes)
    }
}

/// f32 tensor size in bytes for a shape.
pub fn f32_bytes(shape: &[usize]) -> u64 {
    4 * shape.iter().product::<usize>() as u64
}

/// Parse a human byte count, case-insensitively: plain digits, the
/// short binary suffixes `k`/`m`/`g` (`"64k"` = 65536), the explicit
/// binary forms `kib`/`mib`/`gib`, or the decimal forms
/// `kb`/`mb`/`gb` (`"12kb"` = 12000 — SI, matching the paper's
/// decimal-GB tables).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_lowercase();
    // longest suffix first, so "kib" is never misread as bare "k"
    // followed by trailing garbage
    const SUFFIXES: &[(&str, u64)] = &[
        ("kib", KIB),
        ("mib", MIB),
        ("gib", GIB),
        ("kb", KB),
        ("mb", MB),
        ("gb", GB),
        ("k", KIB),
        ("m", MIB),
        ("g", GIB),
    ];
    for (suffix, mult) in SUFFIXES {
        if let Some(digits) = t.strip_suffix(suffix) {
            return digits.trim().parse::<u64>().ok()?
                .checked_mul(*mult);
        }
    }
    t.parse::<u64>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_gb(6_500_000_000), "6.5 GB");
        assert_eq!(fmt_human(512), "512 B");
        assert_eq!(fmt_human(2 * MIB), "2.0 MiB");
        assert_eq!(fmt_human(3 * GIB), "3.00 GiB");
    }

    #[test]
    fn tensor_bytes() {
        assert_eq!(f32_bytes(&[2, 3]), 24);
        assert_eq!(f32_bytes(&[]), 4);
    }

    #[test]
    fn parses_suffixed_byte_counts() {
        assert_eq!(parse_bytes("123"), Some(123));
        assert_eq!(parse_bytes("64k"), Some(64 * 1024));
        assert_eq!(parse_bytes("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_bytes(" 1g "), Some(1024 * 1024 * 1024));
        assert_eq!(parse_bytes("0"), Some(0));
        assert_eq!(parse_bytes("x"), None);
        assert_eq!(parse_bytes(""), None);
    }

    #[test]
    fn parses_explicit_binary_and_decimal_suffixes() {
        // the old parser rejected "12kb" outright; both unit families
        // now work, with decimal kb/mb/gb matching the paper's SI
        // tables and kib/mib/gib staying binary
        assert_eq!(parse_bytes("12kb"), Some(12_000));
        assert_eq!(parse_bytes("12KB"), Some(12_000));
        assert_eq!(parse_bytes("3mb"), Some(3_000_000));
        assert_eq!(parse_bytes("2GB"), Some(2_000_000_000));
        assert_eq!(parse_bytes("12kib"), Some(12 * 1024));
        assert_eq!(parse_bytes("3MiB"), Some(3 * 1024 * 1024));
        assert_eq!(parse_bytes(" 1GiB "), Some(1024 * 1024 * 1024));
        // suffix must trail a number; lone or doubled units stay errors
        assert_eq!(parse_bytes("kb"), None);
        assert_eq!(parse_bytes("12kbb"), None);
        assert_eq!(parse_bytes("12 kb"), Some(12_000));
        // overflow is an error, not a wrap
        assert_eq!(parse_bytes("99999999999999999999g"), None);
        assert_eq!(parse_bytes(&format!("{}g", u64::MAX)), None);
    }
}
