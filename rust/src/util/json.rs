//! Minimal JSON codec (parse + emit), no external dependencies.
//!
//! The offline build environment ships no serde, so the runtime carries its
//! own small, total JSON implementation.  It is used for three things:
//! the AOT `manifest.json` (read), checkpoint metadata (read/write) and
//! metric/report dumps (write).  Supported: the full JSON value grammar
//! with f64 numbers, `\uXXXX` escapes, and nesting bounded only by stack.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept ordered (BTreeMap) so emitted
/// documents are deterministic — handy for golden tests and diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ----- typed accessors (None on shape mismatch) -----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` style access; returns Null for missing keys or
    /// non-objects so lookups chain without panics.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array (Null when out of range / not an array).
    pub fn at(&self, i: usize) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ----- constructors -----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- emit -----

    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Unpaired surrogates map to the replacement
                            // char rather than erroring.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    // lint:allow(D004): rest is non-empty (Some arm)
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(1).get("b").as_bool(), Some(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"arr":[1,2.5,null,true],"obj":{"k":"v \" w"},"s":"t"}"#;
        let v = parse(doc).unwrap();
        let v2 = parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }
}
