//! Deterministic PRNG utilities (SplitMix64 core).
//!
//! Two distinct random streams exist in the system and must not be
//! confused:
//!
//! 1. The **MeZO perturbation stream** lives *inside* the HLO artifacts
//!    (murmur3-fmix over uint32 counters, see `python/compile/kernels/
//!    rng.py`).  Rust only supplies the per-step `u32` seed.
//! 2. The **host stream** (this module): data generation, shuffling, and
//!    per-step seed derivation.  SplitMix64 — tiny, fast, and passes the
//!    statistical tests that matter at this scale.

/// SplitMix64 PRNG.  Deterministic across platforms; copy-free seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) via rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Derive an independent child stream (e.g. per-job, per-epoch).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

/// Per-step MeZO seed schedule: derives the u32 seed fed to the artifact
/// at step `t` from a master seed.  Stateless, so a resumed session
/// regenerates the identical seed sequence — checkpoints need only store
/// `(master_seed, step)`.
pub fn mezo_step_seed(master_seed: u64, step: u64) -> u32 {
    let mut r = Rng::new(master_seed ^ step.wrapping_mul(0xD6E8FEB86659FD93));
    r.next_u32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            let y = r.range(-5, 5);
            assert!((-5..=5).contains(&y));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn step_seed_schedule_stateless() {
        assert_eq!(mezo_step_seed(42, 10), mezo_step_seed(42, 10));
        assert_ne!(mezo_step_seed(42, 10), mezo_step_seed(42, 11));
        assert_ne!(mezo_step_seed(42, 10), mezo_step_seed(43, 10));
    }
}
