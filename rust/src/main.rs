//! `pocketllm` — the on-device fine-tuning launcher.
//!
//! Subcommands:
//! ```text
//!   finetune   run a fine-tuning session (the paper's core loop)
//!   eval       evaluate a model / checkpoint on a task's held-out split
//!   report     regenerate the paper's tables & figures (fig1, table1,
//!              table2, opt13b, ablation, sweep, frontier, all)
//!   daemon     run the policy-gated personalization coordinator over a
//!              simulated day of phone state
//!   fleet      multiplex N personalization jobs over a worker pool
//!              sharing one runtime (deterministic for any -W), with
//!              EDF deadlines and bounded-memory hibernation
//!   store      inspect durable session images / legacy checkpoints
//!   trace      replay a durable fleet's event journal: per-job
//!              timelines, kernel breakdowns, latency percentiles
//!   devices    list device presets
//!   artifacts  list AOT programs in the manifest
//! ```
//!
//! Python never runs here.  By default the binary is fully hermetic:
//! without an `artifacts/` directory it runs the builtin manifest on
//! the native interpreter backend.  With `make artifacts` (or an
//! explicit `--artifacts DIR`) it uses the AOT manifest instead — and
//! the same artifacts execute on PJRT when built with `--features
//! pjrt`.

use anyhow::{bail, Context, Result};

use pocketllm::coordinator::{Coordinator, CoordinatorConfig, FleetConfig,
                             FleetReport, FleetScheduler, JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::device::Device;
use pocketllm::link::LinkSpec;
use pocketllm::optim::{OptimizerKind, Schedule};
use pocketllm::report;
use pocketllm::runtime::{Manifest, Precision, Runtime};
use pocketllm::scheduler::{ModePolicy, Policy};
use pocketllm::store::{EngineKind, PagedEngine, PAGED_FILE_NAME};
use pocketllm::tuner::checkpoint::Checkpoint;
use pocketllm::tuner::session::SessionBuilder;
use pocketllm::util::args::Args;

const VALUE_FLAGS: &[&str] = &[
    "model", "task", "optimizer", "steps", "batch", "lr", "eps", "seed",
    "device", "artifacts", "csv", "checkpoint", "schedule", "windows",
    "report-steps", "trace-seed", "steps-per-window", "queries",
    "batch-window", "jobs", "workers", "policy", "precision",
    "resident-budget", "deadline", "store-dir", "store-engine",
    "kill-at-window", "link", "mode", "max-energy", "trace-out",
];

fn usage() -> &'static str {
    "pocketllm — on-device LLM fine-tuning via derivative-free optimization

USAGE: pocketllm <finetune|eval|report|daemon|fleet|store|trace|
                 devices|artifacts> [flags]

COMMON FLAGS
  --artifacts DIR    artifact directory (default: artifacts)
  --model NAME       model config (default: pocket-roberta)
  --task NAME        sst2 | boolq | rte | chatlm (default: sst2)
  --optimizer NAME   mezo | adam (default: mezo)
  --batch N          batch size (default: first available artifact)
  --steps N          optimization steps (default: 30)
  --lr F | --schedule S   learning rate (const:X, linear:A:B:N, cosine:..)
  --eps F            MeZO perturbation scale (default: 1e-3)
  --seed N           master seed (default: 42)
  --queries K        k-query SPSA: average K two-point estimates per
                     step (needs a mezo_step_q{K} artifact; default 1)
  --batch-window N   resident batch-cache window; older batches are
                     regenerated deterministically (default 512)
  --precision P      parameter storage: f32 | f16 | int8 | int8pc
                     (int8pc = per-channel scales; default f32).
                     Params stay at P between steps (compute is f32);
                     the simulated ledger charges the same byte-width.
                     For fleet runs, applies to every job
  --device NAME      simulate a device envelope (oppo-reno6, pixel-4a, ...)
  --csv PATH         dump step metrics as CSV
  --checkpoint PATH  save a single-file session image at the end (the
                     canonical durable form: params at their resident
                     precision + optimizer state, CRC-protected;
                     legacy checkpoint DIRECTORIES stay readable)

REPORT
  pocketllm report [fig1|table1|table2|opt13b|ablation|sweep|frontier|all]
                   [--report-steps N]

DAEMON
  pocketllm daemon [--steps N] [--windows N] [--steps-per-window N]
                   [--trace-seed N]

FLEET
  pocketllm fleet [--jobs N] [--workers W] [--steps N] [--model NAME]
                  [--policy overnight|always] [--windows N]
                  [--steps-per-window N] [--trace-seed N] [--queries K]
                  [--resident-budget B] [--deadline M] [--store-dir D]
                  [--store-engine dir|paged] [--recover]
                  [--kill-at-window K]
                  [--link wifi|lte|metered|offline]
                  [--mode auto|local|split] [--max-energy WH]
  Runs N independent personalization jobs (seeds 42, 43, ...) over a
  W-worker pool sharing one runtime.  Outcomes are bit-identical for
  any W and any budget (the determinism contract; see README).
  --resident-budget B   cap the summed resident parameter bytes of
                        queued jobs (suffixes k/m/g); jobs over the
                        cap hibernate to the session store and
                        rehydrate on dispatch — thousands of queued
                        jobs run in flat memory
  --deadline M          EDF deadlines: job i gets M*(jobs-i) simulated
                        minutes, so later-queued jobs are tighter and
                        dispatch first (earliest deadline first)
  --store-dir D         hibernation store location (default: a
                        per-run temp directory).  Giving an explicit
                        directory also makes the run DURABLE: the job
                        manifest, every hibernated image, and every
                        finished job's terminal image are committed
                        there, so a crashed run can be resumed
  --store-engine E      store backend: dir (one file per image) or
                        paged (one CRC-protected paged file; compact
                        with `store compact`) (default: dir)
  --recover             resume a crashed durable run from --store-dir
                        instead of starting fresh: finished jobs keep
                        their stored outcomes, interrupted jobs replay
                        from their last committed window, and the
                        recovered outcomes are bit-identical to an
                        uninterrupted run
  --kill-at-window K    abort the whole process (as a crash would)
                        right after the fleet completes its K-th
                        window — for exercising --recover
  --link P              simulated device<->server link profile used by
                        split tuning: wifi | lte | metered | offline
                        (default wifi).  Transfer time and radio Wh
                        are charged to the simulated device
  --mode M              how admitted windows are spent: local (all
                        MeZO on device; the default and the pre-split
                        behaviour), split (side-module tuning crosses
                        the link whenever it is up), or auto (per
                        window from memory headroom + link state;
                        metered links are never auto-selected)
  --max-energy WH       per-window energy ceiling over the estimated
                        compute + link Wh in the selected mode;
                        windows over the cap are denied with reason
                        `energy budget` (default: no cap)
  --trace-out FILE      write the run's deterministic span trace as
                        Chrome trace-event JSON (load in Perfetto or
                        chrome://tracing).  Every field except the
                        optional `host_dur_us` wall-clock annotation
                        is bit-identical for any --workers

TRACE
  pocketllm trace STORE_DIR [--trace-out FILE]
  Replay the event journal of a durable fleet run (one started with
  --store-dir): per-job window timelines, an aggregate kernel
  breakdown with simulated GFLOP/s, and latency percentiles — all
  reconstructed from the CRC-protected journal records, so it works
  on crashed runs too.  --trace-out re-exports the replayed spans as
  Chrome trace JSON

STORE
  pocketllm store inspect PATH
  Print a session image's header, tensor directory, and size
  breakdown (params vs optimizer state vs metadata) after verifying
  its CRC; also summarizes legacy checkpoint directories.

  pocketllm store fsck PATH
  Verify a paged store file (PATH may also be the directory holding
  one): root slots, ledger chain, page allocation, and every blob
  CRC.  Exits nonzero unless the report ends `status: clean`.

  pocketllm store compact PATH
  Rewrite a paged store file in place, dropping pages orphaned by
  superseded images, and report the bytes reclaimed.
"
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts");
    let path = format!("{dir}/manifest.json");
    if std::path::Path::new(&path).exists() {
        let manifest = Manifest::load(&path)
            .with_context(|| format!("loading {path}"))?;
        // with the pjrt feature, on-disk artifacts run on the PJRT/XLA
        // backend (the deployment path); otherwise native interprets
        // the same manifest
        #[cfg(feature = "pjrt")]
        return Runtime::pjrt(manifest);
        #[cfg(not(feature = "pjrt"))]
        return Runtime::new(manifest);
    }
    if args.has("artifacts") {
        // an explicit --artifacts dir that doesn't exist is an error,
        // not a silent fallback
        bail!("no manifest at {path} — did you run `make artifacts`?");
    }
    // hermetic default: builtin manifest + native interpreter backend
    Runtime::new(Manifest::builtin())
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, VALUE_FLAGS)?;
    match args.subcommand.as_deref() {
        Some("finetune") => cmd_finetune(&args),
        Some("eval") => cmd_eval(&args),
        Some("report") => cmd_report(&args),
        Some("daemon") => cmd_daemon(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("store") => cmd_store(&args),
        Some("trace") => cmd_trace(&args),
        Some("devices") => {
            println!("{}", report::devices().render());
            Ok(())
        }
        Some("artifacts") => cmd_artifacts(&args),
        Some("help") | None => {
            println!("{}", usage());
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'\n\n{}", usage()),
    }
}

fn parse_precision(args: &Args) -> Result<Precision> {
    Precision::parse(args.get_or("precision", "f32"))
        .context("bad --precision (f32|f16|int8|int8pc)")
}

fn parse_schedule(args: &Args) -> Result<Option<Schedule>> {
    if let Some(s) = args.flag("schedule") {
        return Ok(Some(
            Schedule::parse(s).context("bad --schedule (e.g. const:1e-3)")?,
        ));
    }
    if args.has("lr") {
        return Ok(Some(Schedule::Constant(args.get_f64("lr", 1e-3)?)));
    }
    Ok(None)
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "pocket-roberta");
    let optimizer = OptimizerKind::parse(args.get_or("optimizer", "mezo"))
        .context("bad --optimizer (mezo|adam)")?;
    let task = TaskKind::parse(args.get_or("task", "sst2"))
        .context("bad --task (sst2|boolq|rte|chatlm)")?;
    let steps = args.get_u64("steps", 30)?;

    let queries = args.get_usize("queries", 1)?;
    if queries == 0 {
        bail!("--queries must be >= 1");
    }
    let precision = parse_precision(args)?;
    let mut builder = SessionBuilder::new(&rt, model)
        .optimizer(optimizer)
        .task(task)
        .batch_size(args.get_usize("batch", 0)?)
        .eps(args.get_f64("eps", 1e-3)?)
        .seed(args.get_u64("seed", 42)?)
        .queries(queries)
        .precision(precision)
        .batch_window(args.get_usize(
            "batch-window",
            pocketllm::tuner::session::DEFAULT_BATCH_WINDOW,
        )?);
    if let Some(s) = parse_schedule(args)? {
        builder = builder.lr(s);
    }
    if let Some(dev) = args.flag("device") {
        let device =
            Device::preset(dev).context("unknown --device preset")?;
        println!(
            "device: {} (app budget {})",
            dev,
            pocketllm::util::bytes::fmt_gb(device.ledger.budget())
        );
        builder = builder.device(device);
    }

    let mut session = builder.build().map_err(|e| {
        anyhow::anyhow!("session admission failed: {e:#}")
    })?;
    println!(
        "fine-tuning {model} ({} params, {} storage) with {} on {}, \
         batch {}, {} steps",
        session.cfg.n_params,
        precision,
        optimizer.label(),
        task.label(),
        session.batch,
        steps
    );

    let t0 = std::time::Instant::now();
    let mut last = f64::NAN;
    for chunk_start in (0..steps).step_by(10) {
        let n = 10.min(steps - chunk_start);
        let stats = session.run_steps(n)?;
        last = stats.last_loss;
        println!(
            "step {:>5}  loss {:.4}  host {:.0} ms/step  sim {:.1} s/step",
            session.step,
            stats.last_loss,
            stats.mean_host_step_s * 1e3,
            stats.mean_sim_step_s
        );
    }
    println!("done in {:.1}s; final loss {:.4}", t0.elapsed().as_secs_f64(),
             last);
    if let Some(peak) = pocketllm::telemetry::bench::peak_rss_bytes() {
        // machine-readable for the table1 bench (subprocess isolation)
        println!("host peak RSS bytes: {peak}");
    }

    // step-log footer: the simulated ledger models the *paper's* phone
    // at paper scale, while the host keeps pocket-scale tensors
    // resident — print BOTH so the gap is visible for any precision
    // instead of implying they are the same number.
    println!(
        "params resident on host: {} ({} x {} storage)",
        pocketllm::util::bytes::fmt_human(session.resident_param_bytes()),
        session.cfg.n_params,
        session.precision()
    );
    if let Some(dev) = session.device.as_ref() {
        println!(
            "simulated ledger parameters: {} (model-scale, {} B/param)",
            pocketllm::util::bytes::fmt_human(
                dev.ledger.category(pocketllm::device::Category::Parameters)
            ),
            session.precision().param_bytes()
        );
    }

    if let Some(curve) = session.metrics.get("loss") {
        println!("loss  {}", report::sparkline(&curve.points, 60));
    }
    if let Some(dev) = session.device.as_ref() {
        println!(
            "simulated peak memory: {}",
            pocketllm::util::bytes::fmt_gb(dev.ledger.peak())
        );
    }
    if let Some(path) = args.flag("csv") {
        session.metrics.save_csv(std::path::Path::new(path))?;
        println!("metrics -> {path}");
    }
    if let Some(path) = args.flag("checkpoint") {
        // snapshot the resident ExecState AT ITS PRECISION — the
        // image stores f16/int8 bytes verbatim, never an f32
        // materialization; Adam sessions carry their moments
        let image = session.snapshot_image(last)?;
        let (param_b, moment_b) =
            (image.param_bytes(), image.moment_bytes());
        let ck = Checkpoint::save(path, image)?;
        println!(
            "checkpoint -> {path} ({}, {} storage: {} params + {} \
             optimizer state)",
            pocketllm::util::bytes::fmt_human(ck.size_bytes()?),
            session.precision(),
            pocketllm::util::bytes::fmt_human(param_b),
            pocketllm::util::bytes::fmt_human(moment_b),
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "pocket-roberta");
    let task = TaskKind::parse(args.get_or("task", "sst2"))
        .context("bad --task")?;
    // the checkpoint's recorded precision drives the session build,
    // so an f16/int8 checkpoint evaluates with f16/int8 resident
    // storage instead of silently widening to f32 (legacy
    // directories default to f32 — they always stored f32)
    let ck = args
        .flag("checkpoint")
        .map(Checkpoint::open)
        .transpose()?;
    let mut session = SessionBuilder::new(&rt, model)
        .task(task)
        .seed(args.get_u64("seed", 42)?)
        .precision(
            ck.as_ref().map(|c| c.precision).unwrap_or_default(),
        )
        .build()?;
    if let Some(ck) = &ck {
        let params = ck.load_params(&session.cfg)?;
        session.load_params(&params)?;
        println!("loaded checkpoint @ step {} ({} storage)", ck.step,
                 ck.precision);
    }
    let loss = session.eval_loss()?;
    println!("eval loss: {loss:.4}");
    if !session.cfg.is_decoder() {
        println!("eval accuracy: {:.3}", session.eval_accuracy()?);
    } else {
        println!("perplexity: {:.2}",
                 pocketllm::tuner::eval::perplexity(loss));
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let steps = args.get_u64("report-steps", 60)?;
    let wants = |k: &str| which == k || which == "all";
    let mut known = false;

    if wants("table1") {
        known = true;
        println!("{}", report::table1().render());
    }
    if wants("table2") {
        known = true;
        println!("{}", report::table2().render());
    }
    if wants("opt13b") {
        known = true;
        println!("{}", report::opt13b().render());
    }
    if wants("ablation") {
        known = true;
        println!("{}", report::ablation_memory().render());
    }
    if wants("sweep") {
        known = true;
        println!("{}",
                 report::memory_sweep(&[1, 2, 4, 8, 16, 32, 64, 128])
                     .render());
    }
    if wants("frontier") {
        known = true;
        println!("{}", report::oom_frontier().render());
    }
    if wants("energy") {
        known = true;
        println!("{}", report::energy_table().render());
    }
    if wants("fig1") {
        known = true;
        let rt = open_runtime(args)?;
        let model = args.get_or("model", "pocket-roberta");
        println!("running Fig. 1 ({steps} steps x 2 optimizers) ...");
        let (table, log) = report::fig1(&rt, model, steps, 1e-4, 1e-3)?;
        println!("{}", table.render());
        for name in ["mezo.loss", "adam.loss"] {
            if let Some(s) = log.get(name) {
                println!("{name:<10} {}", report::sparkline(&s.points, 60));
            }
        }
        if let Some(path) = args.flag("csv") {
            log.save_csv(std::path::Path::new(path))?;
            println!("fig1 series -> {path}");
        }
    }
    if !known {
        bail!("unknown report '{which}' (fig1|table1|table2|opt13b|\
               ablation|sweep|frontier|all)");
    }
    Ok(())
}

fn cmd_daemon(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "pocket-tiny");
    let steps = args.get_u64("steps", 24)?;
    let cfg = CoordinatorConfig {
        policy: Policy::overnight(),
        steps_per_window: args.get_u64("steps-per-window", 4)?,
        max_windows: args.get_usize("windows", 2000)?,
        trace_seed: args.get_u64("trace-seed", 7)?,
        ..Default::default()
    };
    let mut coord = Coordinator::new(&rt, cfg);
    let job = JobSpec::new(
        model,
        TaskKind::parse(args.get_or("task", "sst2")).context("bad task")?,
        OptimizerKind::parse(args.get_or("optimizer", "mezo"))
            .context("bad optimizer")?,
    )
    .steps(steps);
    println!("daemon: running {} for {} steps under overnight policy",
             model, steps);
    let outcome = coord.run_job(0, &job)?;
    println!(
        "outcome: {:?} with {} after {} steps (windows used {}, denied {})",
        outcome.status,
        outcome.optimizer.label(),
        outcome.steps_done,
        outcome.windows_used,
        outcome.windows_denied
    );
    println!("final loss: {:.4}", outcome.final_loss);
    let mut denies = std::collections::BTreeMap::new();
    for e in &coord.events {
        if let pocketllm::coordinator::Event::Denied { reason, .. } = e {
            *denies.entry(*reason).or_insert(0usize) += 1;
        }
    }
    for (reason, count) in denies {
        println!("  denied {count:>4}x: {reason}");
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "pocket-tiny");
    let n_jobs = args.get_usize("jobs", 4)?;
    let workers = args.get_usize("workers", 2)?;
    let steps = args.get_u64("steps", 8)?;
    let task = TaskKind::parse(args.get_or("task", "sst2"))
        .context("bad task")?;
    let optimizer = OptimizerKind::parse(args.get_or("optimizer", "mezo"))
        .context("bad optimizer")?;
    let policy_name = args.get_or("policy", "overnight");
    let mut policy = match policy_name {
        "overnight" => Policy::overnight(),
        "always" => Policy::always(),
        other => bail!("bad --policy '{other}' (overnight|always)"),
    };
    if let Some(s) = args.flag("max-energy") {
        policy.max_energy_per_window =
            Some(s.parse::<f64>().context("bad --max-energy (Wh)")?);
    }
    let link_name = args.get_or("link", "wifi");
    let link = LinkSpec::profile(link_name).with_context(|| {
        format!(
            "bad --link '{link_name}' ({})",
            pocketllm::link::PROFILE_NAMES.join("|")
        )
    })?;
    let mode_name = args.get_or("mode", "local");
    let mode = ModePolicy::parse(mode_name)
        .with_context(|| format!("bad --mode '{mode_name}' \
                                  (auto|local|split)"))?;
    let coord = CoordinatorConfig {
        device_preset: args.get_or("device", "oppo-reno6").into(),
        policy,
        steps_per_window: args.get_u64("steps-per-window", 4)?,
        max_windows: args.get_usize("windows", 2000)?,
        trace_seed: args.get_u64("trace-seed", 7)?,
        link,
        mode,
        ..Default::default()
    };
    let base_seed = args.get_u64("seed", 42)?;
    let batch = args.get_usize("batch", 0)?;
    let precision = parse_precision(args)?;
    let queries = args.get_usize("queries", 1)?;
    if queries == 0 {
        bail!("--queries must be >= 1");
    }
    // --deadline M: job i gets M*(jobs-i) simulated minutes, so
    // later-queued jobs have TIGHTER deadlines and the EDF queue
    // dispatches them first — outcomes stay identical (the contract),
    // only dispatch order and the deadline_missed flags react
    let deadline_base = match args.flag("deadline") {
        Some(s) => Some(
            s.parse::<f64>().context("bad --deadline (minutes)")?,
        ),
        None => None,
    };
    let resident_budget = match args.flag("resident-budget") {
        Some(s) => Some(pocketllm::util::bytes::parse_bytes(s).context(
            "bad --resident-budget (bytes, suffixes k/m/g)",
        )?),
        None => None,
    };
    let store_engine =
        EngineKind::parse(args.get_or("store-engine", "dir"))
            .context("bad --store-engine (dir|paged)")?;
    let kill_at_window = match args.flag("kill-at-window") {
        Some(s) => Some(
            s.parse::<u64>().context("bad --kill-at-window (windows)")?,
        ),
        None => None,
    };
    let store_dir = args
        .flag("store-dir")
        .map(std::path::PathBuf::from);
    let fleet_cfg = FleetConfig {
        coord,
        workers,
        resident_budget_bytes: resident_budget,
        store_dir: store_dir.clone(),
        store_engine,
        kill_at_window,
        ..FleetConfig::default()
    };

    if args.has("recover") {
        // resume a crashed durable run: the manifest in the store
        // supplies the job list and coordinator config; only the pool
        // knobs (--workers, --resident-budget) come from this
        // invocation
        let dir = store_dir.context(
            "--recover needs --store-dir (the durable store to resume)",
        )?;
        println!("fleet: recovering from {}", dir.display());
        let fleet = FleetScheduler::new(&rt, fleet_cfg);
        let t0 = std::time::Instant::now();
        let report = fleet.recover(&dir)?;
        write_trace_out(args, &report)?;
        print_fleet_report(&report, t0.elapsed().as_secs_f64(), workers);
        return Ok(());
    }
    let jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|i| {
            let mut j = JobSpec::new(model, task, optimizer)
                .batch(batch)
                .steps(steps)
                .seed(base_seed + i as u64)
                .precision(precision)
                .queries(queries);
            if let Some(m) = deadline_base {
                j = j.deadline(m * (n_jobs - i) as f64);
            }
            j
        })
        .collect();

    // NOTE: every line this command prints except `host wall: ...`
    // and `fleet store: ...` is deterministic for any --workers; CI
    // diffs the outputs of two worker counts, so keep
    // worker-dependent detail (wall-clock, hibernation counts,
    // high-water) on those two lines only.
    println!(
        "fleet: {n_jobs} jobs x {steps} steps on {model} ({}), \
         {policy_name} policy",
        optimizer.label()
    );
    println!("fleet link: {link_name}  mode: {mode_name}");
    if let Some(b) = resident_budget {
        println!(
            "fleet resident budget: {} (queued jobs hibernate to the \
             session store)",
            pocketllm::util::bytes::fmt_human(b)
        );
    }
    let fleet = FleetScheduler::new(&rt, fleet_cfg);
    let t0 = std::time::Instant::now();
    let report = fleet.run(&jobs)?;
    write_trace_out(args, &report)?;
    print_fleet_report(&report, t0.elapsed().as_secs_f64(), workers);
    Ok(())
}

/// `--trace-out FILE`: dump the run's span stream as Chrome
/// trace-event JSON.  The confirmation goes to stderr so stdout stays
/// byte-diffable across worker counts even when the two runs write to
/// different files.
fn write_trace_out(args: &Args, report: &FleetReport) -> Result<()> {
    if let Some(file) = args.flag("trace-out") {
        let json = pocketllm::telemetry::trace::chrome_trace_json(
            &report.spans,
        );
        std::fs::write(file, json)
            .with_context(|| format!("writing trace to {file}"))?;
        eprintln!(
            "fleet trace: {} spans -> {file}",
            report.spans.len()
        );
    }
    Ok(())
}

/// Shared between `fleet` and `fleet --recover` so CI can diff the
/// deterministic lines of a recovered run against an uninterrupted
/// one byte-for-byte.
fn print_fleet_report(report: &FleetReport, wall: f64, workers: usize) {
    for (i, o) in report.outcomes.iter().enumerate() {
        println!(
            "job {i:>3}: {:<9?} {:<4} steps {:>6}  loss {:.6}  \
             windows {}  denied {}",
            o.status,
            o.optimizer.label(),
            o.steps_done,
            o.final_loss,
            o.windows_used,
            o.windows_denied
        );
    }
    let t = &report.telemetry;
    println!(
        "fleet outcomes: {}/{} completed ({:.1}%), {} stalled, {} failed",
        t.completed,
        t.jobs,
        t.completion_rate * 100.0,
        t.stalled,
        t.failed
    );
    println!("fleet oom fallbacks: {}", t.oom_fallbacks);
    let denies: Vec<String> = t
        .denied_by_reason
        .iter()
        .map(|(r, c)| format!("{r} {c}"))
        .collect();
    println!(
        "fleet denied windows: {}  [{}]",
        t.windows_denied,
        denies.join(", ")
    );
    println!(
        "fleet simulated step-seconds: {:.1}",
        t.sim_step_seconds
    );
    println!(
        "fleet split tuning: {} split windows, {} deferred, {} link \
         drops",
        t.windows_split, t.windows_deferred, t.link_drops
    );
    println!(
        "fleet link traffic: {} moved, {:.4} Wh radio",
        pocketllm::util::bytes::fmt_human(t.link_bytes),
        t.link_wh
    );
    if t.windows_deferred > 0 {
        let hist: Vec<String> = t
            .deferred_by_job
            .iter()
            .enumerate()
            .filter(|(_, d)| **d > 0)
            .map(|(i, d)| format!("{i}:{d}"))
            .collect();
        println!("fleet deferrals by job: [{}]", hist.join(", "));
    }
    println!("fleet deadline misses: {}", t.deadline_misses);
    // simulated-clock histograms: deterministic for any worker count
    println!(
        "fleet trace: {} spans",
        report.spans.len()
    );
    println!(
        "fleet dispatch latency p50/p90/p99 us: {}/{}/{}",
        t.dispatch_latency_us.percentile(0.50),
        t.dispatch_latency_us.percentile(0.90),
        t.dispatch_latency_us.percentile(0.99)
    );
    println!(
        "fleet window latency p50/p90/p99 us: {}/{}/{}",
        t.window_latency_us.percentile(0.50),
        t.window_latency_us.percentile(0.90),
        t.window_latency_us.percentile(0.99)
    );
    println!(
        "fleet link transfer p50/p90/p99 bytes: {}/{}/{}",
        t.link_transfer_bytes.percentile(0.50),
        t.link_transfer_bytes.percentile(0.90),
        t.link_transfer_bytes.percentile(0.99)
    );
    println!("fleet recovered jobs: {}", t.recovered_jobs);
    println!(
        "fleet tokenizer cache: {} builds, {} hits",
        t.tokenizer_cache_builds, t.tokenizer_cache_hits
    );
    // worker-timing-dependent telemetry: keep on the excluded lines
    println!(
        "fleet store: {} hibernations, {} rehydrations, resident \
         high-water {}, {} spilled",
        t.hibernations,
        t.rehydrations,
        pocketllm::util::bytes::fmt_human(t.resident_high_water_bytes),
        pocketllm::util::bytes::fmt_human(t.store_bytes_spilled)
    );
    println!("host wall: {wall:.2}s with {workers} workers");
}

/// `store fsck PATH` / `store compact PATH` accept either the paged
/// file itself or the store directory that contains it.
fn paged_file_path(path: &str) -> std::path::PathBuf {
    let p = std::path::PathBuf::from(path);
    if p.is_dir() {
        p.join(PAGED_FILE_NAME)
    } else {
        p
    }
}

fn cmd_store(args: &Args) -> Result<()> {
    let verb = args.positional.first().map(|s| s.as_str());
    let path = args.positional.get(1);
    match verb {
        Some("inspect") => {}
        Some("fsck") => {
            let file = paged_file_path(path.context(
                "usage: pocketllm store fsck PATH",
            )?);
            let report = PagedEngine::fsck(&file)
                .with_context(|| format!("fsck {}", file.display()))?;
            println!("{report}");
            if !report.is_clean() {
                bail!("fsck: {} is corrupt", file.display());
            }
            return Ok(());
        }
        Some("compact") => {
            let file = paged_file_path(path.context(
                "usage: pocketllm store compact PATH",
            )?);
            let engine = PagedEngine::open(&file).with_context(|| {
                format!("opening {}", file.display())
            })?;
            let before = std::fs::metadata(&file)?.len();
            let (moved, reclaimed) = engine.compact()?;
            let after = std::fs::metadata(&file)?.len();
            println!(
                "compacted {}: moved {moved} blob(s), reclaimed \
                 {reclaimed} B ({before} -> {after} B on disk)",
                file.display()
            );
            return Ok(());
        }
        other => bail!(
            "usage: pocketllm store <inspect|fsck|compact> PATH \
             (got {:?})",
            other
        ),
    }
    let path = path.context("usage: pocketllm store inspect PATH")?;
    let ck = Checkpoint::open(path)?;
    let human = pocketllm::util::bytes::fmt_human;
    println!("checkpoint: {path}");
    match ck.image() {
        Some(img) => {
            let total = ck.size_bytes()?;
            let params = img.param_bytes();
            let moments = img.moment_bytes();
            println!("form: session image v{} (CRC verified)",
                     pocketllm::store::image::VERSION);
            println!("config: {}", img.config);
            println!("task: {}", img.task.label());
            println!("optimizer: {}", img.optimizer.label());
            println!("precision: {} ({} B/param on disk)",
                     img.precision, img.precision.param_bytes());
            println!("step: {}", img.step);
            println!("master seed: {}", img.master_seed);
            println!("data seed: {}", img.data_seed);
            println!("batch: {}  batcher position: {}", img.batch,
                     img.batcher_pos);
            println!("tensors: {}", img.params.len());
            println!("size: {} total = {} params + {} optimizer \
                      state + {} metadata",
                     human(total),
                     human(params),
                     human(moments),
                     human(total.saturating_sub(params + moments)));
            // the paper's Table-1 asymmetry, durable: MeZO images are
            // params + O(100) bytes; Adam images carry 2x f32 moments
            if img.adam_m.is_empty() {
                println!("optimizer state: (master_seed, step) — 16 \
                          bytes of counters, no tensors");
            }
        }
        None => {
            println!("form: legacy checkpoint directory (read shim; \
                      params are f32)");
            println!("config: {}", ck.config);
            println!("optimizer: {}", ck.optimizer.label());
            println!("precision: {}", ck.precision);
            println!("step: {}", ck.step);
            println!("master seed: {}", ck.master_seed);
            println!("size: {} total", human(ck.size_bytes()?));
        }
    }
    Ok(())
}

/// `trace STORE_DIR` — replay a durable fleet's journal into per-job
/// window timelines, an aggregate kernel breakdown (with simulated
/// GFLOP/s), and latency percentiles.  Reads only the CRC-protected
/// journal records, so it works on crashed runs and never touches the
/// session images.
fn cmd_trace(args: &Args) -> Result<()> {
    use pocketllm::store::{journal, SessionStore};
    use pocketllm::telemetry::trace::SpanKind;
    use pocketllm::telemetry::LogHistogram;

    let path = args.positional.first().context(
        "usage: pocketllm trace STORE_DIR [--trace-out FILE]",
    )?;
    let store = SessionStore::open_auto(path, 0)
        .with_context(|| format!("opening store at {path}"))?;
    // durable journal keys are `jrn{job}-{seq:08}`; the key scan is
    // the job discovery, so a crashed run with no terminal images
    // still traces
    let mut jobs: Vec<u32> = store
        .iter_keys()
        .iter()
        .filter_map(|k| k.strip_prefix("jrn"))
        .filter_map(|k| k.split_once('-'))
        .filter_map(|(job, _)| job.parse().ok())
        .collect();
    jobs.sort_unstable();
    jobs.dedup();
    if jobs.is_empty() {
        bail!(
            "no journal records under {path} — only fleets started \
             with --store-dir keep a durable journal"
        );
    }
    println!("trace: {} journaled job(s) in {path}", jobs.len());

    let mut all_spans = Vec::new();
    let mut dispatch_us = LogHistogram::new();
    let mut window_us = LogHistogram::new();
    let mut link_bytes = LogHistogram::new();
    // kernel label -> (span count, flops, bytes, simulated us)
    let mut kernels: std::collections::BTreeMap<
        String,
        (u64, u64, u64, u64),
    > = std::collections::BTreeMap::new();
    for &job in &jobs {
        let rep = journal::replay(&store, job, None).with_context(
            || format!("replaying journal for job {job}"),
        )?;
        let points: usize = rep
            .metrics
            .series
            .values()
            .map(|s| s.points.len())
            .sum();
        println!(
            "job {job:>3}: {} record(s), {} event(s), {} span(s), \
             {} metric point(s)",
            rep.records,
            rep.events.len(),
            rep.spans.len(),
            points
        );
        for s in &rep.spans {
            match s.kind {
                SpanKind::Dispatch => dispatch_us.record(s.dur_us),
                SpanKind::Window => {
                    println!(
                        "  w{:<3} {:<8} {:<12} t={}us dur={}us",
                        s.window, s.label, s.detail, s.t_us, s.dur_us
                    );
                    if s.label == "local" || s.label == "split" {
                        window_us.record(s.dur_us);
                    }
                }
                SpanKind::Link => link_bytes.record(s.bytes),
                SpanKind::Kernel => {
                    let k = kernels
                        .entry(s.label.clone())
                        .or_insert((0, 0, 0, 0));
                    k.0 += 1;
                    k.1 = k.1.saturating_add(s.flops);
                    k.2 = k.2.saturating_add(s.bytes);
                    k.3 = k.3.saturating_add(s.dur_us);
                }
                SpanKind::Mode | SpanKind::Step => {}
            }
        }
        all_spans.extend(rep.spans);
    }

    if !kernels.is_empty() {
        println!("kernel breakdown (simulated clock):");
        for (label, (n, flops, bytes, us)) in &kernels {
            let gflops = if *us > 0 {
                *flops as f64 / (*us as f64 / 1e6) / 1e9
            } else {
                0.0
            };
            println!(
                "  {label:<22} {n:>6} span(s)  {:>14} flops  \
                 {:>10} B  {gflops:>8.1} GFLOP/s",
                flops, bytes
            );
        }
    }
    println!(
        "dispatch latency p50/p90/p99 us: {}/{}/{}",
        dispatch_us.percentile(0.50),
        dispatch_us.percentile(0.90),
        dispatch_us.percentile(0.99)
    );
    println!(
        "window latency p50/p90/p99 us: {}/{}/{}",
        window_us.percentile(0.50),
        window_us.percentile(0.90),
        window_us.percentile(0.99)
    );
    println!(
        "link transfer p50/p90/p99 bytes: {}/{}/{}",
        link_bytes.percentile(0.50),
        link_bytes.percentile(0.90),
        link_bytes.percentile(0.99)
    );
    if let Some(file) = args.flag("trace-out") {
        let json = pocketllm::telemetry::trace::chrome_trace_json(
            &all_spans,
        );
        std::fs::write(file, json)
            .with_context(|| format!("writing trace to {file}"))?;
        eprintln!(
            "trace: {} spans -> {file}",
            all_spans.len()
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let mut t = pocketllm::telemetry::Table::new("AOT programs")
        .header(&["config", "kind", "batch", "file", "inputs", "outputs"]);
    for p in &rt.manifest.programs {
        t.row(&[
            p.config.clone(),
            p.kind.clone(),
            p.batch.to_string(),
            p.file.clone(),
            p.inputs.len().to_string(),
            p.outputs.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("platform: {}", rt.platform());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn value_flags_cover_queries_and_batch_window() {
        // the PR-2 regression: k-query SPSA existed in the library but
        // `--queries` was not a value flag, so the binary couldn't
        // reach it (the next token was swallowed as a boolean)
        let a = Args::parse(
            &argv(&["finetune", "--queries", "4", "--batch-window",
                    "64", "--steps", "2"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.get_usize("queries", 1).unwrap(), 4);
        assert_eq!(a.get_usize("batch-window", 512).unwrap(), 64);
        assert_eq!(a.get_u64("steps", 0).unwrap(), 2);
        assert!(a.positional.is_empty(),
                "values must not leak into positionals");
    }

    #[test]
    fn value_flags_cover_precision() {
        let a = Args::parse(
            &argv(&["finetune", "--precision", "f16", "--steps", "2"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(parse_precision(&a).unwrap(), Precision::F16);
        assert!(a.positional.is_empty(),
                "precision value must not leak into positionals");
        let bad = Args::parse(
            &argv(&["finetune", "--precision", "fp64"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert!(parse_precision(&bad).is_err());
    }

    #[test]
    fn value_flags_cover_fleet_knobs() {
        let a = Args::parse(
            &argv(&["fleet", "--jobs", "3", "--workers", "2",
                    "--policy", "always"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("fleet"));
        assert_eq!(a.get_usize("jobs", 0).unwrap(), 3);
        assert_eq!(a.get_usize("workers", 0).unwrap(), 2);
        assert_eq!(a.get_or("policy", "overnight"), "always");
        assert!(a.positional.is_empty());
    }

    #[test]
    fn value_flags_cover_store_and_budget_knobs() {
        // the ISSUE-5 regression class: a library feature whose CLI
        // flag swallows the next token as a boolean
        let a = Args::parse(
            &argv(&["fleet", "--jobs", "64", "--resident-budget",
                    "64k", "--deadline", "30", "--store-dir",
                    "/tmp/s"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.flag("resident-budget"), Some("64k"));
        assert_eq!(
            pocketllm::util::bytes::parse_bytes(
                a.flag("resident-budget").unwrap()
            ),
            Some(65536)
        );
        assert_eq!(a.flag("deadline"), Some("30"));
        assert_eq!(a.flag("store-dir"), Some("/tmp/s"));
        assert!(a.positional.is_empty(),
                "values must not leak into positionals");
        // store inspect takes positionals, not flags
        let s = Args::parse(&argv(&["store", "inspect", "/tmp/x.plsi"]),
                            VALUE_FLAGS)
            .unwrap();
        assert_eq!(s.subcommand.as_deref(), Some("store"));
        assert_eq!(s.positional,
                   vec!["inspect".to_string(),
                        "/tmp/x.plsi".to_string()]);
    }

    #[test]
    fn value_flags_cover_link_and_mode_knobs() {
        // same regression class as --queries: a library feature whose
        // CLI flag must consume its value token
        let a = Args::parse(
            &argv(&["fleet", "--link", "metered", "--mode", "auto",
                    "--max-energy", "0.05", "--jobs", "16"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.get_or("link", "wifi"), "metered");
        assert!(LinkSpec::profile(a.get_or("link", "wifi")).is_some());
        assert_eq!(a.get_or("mode", "local"), "auto");
        assert_eq!(ModePolicy::parse(a.get_or("mode", "local")),
                   Some(ModePolicy::Auto));
        assert_eq!(a.flag("max-energy"), Some("0.05"));
        assert!(a.positional.is_empty(),
                "values must not leak into positionals");
        // defaults reproduce the pre-split fleet exactly
        let d = Args::parse(&argv(&["fleet"]), VALUE_FLAGS).unwrap();
        assert_eq!(
            LinkSpec::profile(d.get_or("link", "wifi")).unwrap(),
            LinkSpec::wifi()
        );
        assert_eq!(ModePolicy::parse(d.get_or("mode", "local")),
                   Some(ModePolicy::ForceLocal));
    }

    #[test]
    fn value_flags_cover_recovery_knobs() {
        // same regression class: --store-engine / --kill-at-window
        // must consume their value; --recover stays boolean
        let a = Args::parse(
            &argv(&["fleet", "--store-engine", "paged",
                    "--kill-at-window", "3", "--recover",
                    "--store-dir", "/tmp/s"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.get_or("store-engine", "dir"), "paged");
        assert!(EngineKind::parse(a.get_or("store-engine", "dir"))
            .is_ok());
        assert_eq!(a.flag("kill-at-window"), Some("3"));
        assert!(a.has("recover"));
        assert!(a.positional.is_empty(),
                "values must not leak into positionals");
        // fsck/compact are positional verbs like inspect
        let s = Args::parse(&argv(&["store", "fsck", "/tmp/s"]),
                            VALUE_FLAGS)
            .unwrap();
        assert_eq!(s.positional,
                   vec!["fsck".to_string(), "/tmp/s".to_string()]);
        assert_eq!(paged_file_path("/nonexistent/x.plpg"),
                   std::path::PathBuf::from("/nonexistent/x.plpg"));
    }

    #[test]
    fn value_flags_cover_trace_out() {
        // same regression class: --trace-out must consume its file
        // argument on both `fleet` and `trace`
        let a = Args::parse(
            &argv(&["fleet", "--trace-out", "/tmp/t.json", "--jobs",
                    "2"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(a.flag("trace-out"), Some("/tmp/t.json"));
        assert!(a.positional.is_empty(),
                "values must not leak into positionals");
        // `trace` takes the store dir as a positional, like `store`
        let t = Args::parse(
            &argv(&["trace", "/tmp/s", "--trace-out", "/tmp/t.json"]),
            VALUE_FLAGS,
        )
        .unwrap();
        assert_eq!(t.subcommand.as_deref(), Some("trace"));
        assert_eq!(t.positional, vec!["/tmp/s".to_string()]);
        assert_eq!(t.flag("trace-out"), Some("/tmp/t.json"));
    }
}
