//! Deterministic fixed-bucket log2 histograms for fleet latency and
//! size distributions.
//!
//! A [`LogHistogram`] has one bucket per power of two over the full
//! `u64` range (bucket 0 holds the value 0; bucket `1 + floor(log2 v)`
//! holds `v >= 1`), so recording is a pure function of the value —
//! no dynamic rebucketing, no configuration, nothing that could make
//! two runs disagree about shape.  Merging is element-wise addition,
//! which is commutative and associative, so folding per-worker
//! histograms is **merge-order-invariant** and bit-identical to the
//! sequential oracle for any worker count (pinned in
//! `rust/tests/proptests.rs`).
//!
//! Percentiles walk the fixed buckets and return the bucket's lower
//! bound — deterministic and conservative (never over-reports a
//! latency), exact for zeros and powers of two.  `BENCH_fleet.json`
//! exports p50/p90/p99 dispatch latency through this path, and
//! `pocketllm trace` renders the same rows from a replayed journal.

/// Bucket 0 for the value 0, buckets 1..=64 for `1 + floor(log2 v)`.
pub const BUCKETS: usize = 65;

/// Fixed-bucket log2 histogram over `u64` values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// The fixed bucket a value lands in: 0 for 0, else
    /// `1 + floor(log2 v)` (so 1 -> 1, 2..3 -> 2, 4..7 -> 3, ...,
    /// `u64::MAX` -> 64).
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// The smallest value that lands in bucket `i` — what percentiles
    /// report (conservative: never over-reports).
    pub fn bucket_floor(i: usize) -> u64 {
        match i {
            0 => 0,
            _ => 1u64 << (i - 1),
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Element-wise fold of `other` into `self`.  Addition commutes
    /// and associates, so ANY merge tree over the same per-item
    /// records yields the same histogram — the property that lets
    /// per-worker histograms be folded in job order (or any order)
    /// and still match the sequential oracle bit-for-bit.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of every recorded value (u128: 2^64 values of 2^64
    /// cannot overflow it).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn min(&self) -> Option<u64> {
        (self.count > 0).then_some(self.min)
    }

    pub fn max(&self) -> Option<u64> {
        (self.count > 0).then_some(self.max)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The value at quantile `p` in [0, 1]: the floor of the bucket
    /// holding the `ceil(p * count)`-th smallest recorded value
    /// (clamped to rank 1).  0 on an empty histogram.  Exact for the
    /// min (p=0 region), exact when every value in the target bucket
    /// is its floor (zeros, powers of two), otherwise a <=2x
    /// underestimate — the log2 resolution this format trades for
    /// determinism.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // the histogram's true min/max tighten the two
                // terminal buckets for free
                return Self::bucket_floor(i)
                    .max(self.min)
                    .min(self.max);
            }
        }
        self.max
    }

    /// Per-bucket counts (index = [`bucket_index`]) — for renderers
    /// and the proptest oracle.
    pub fn counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(LogHistogram::bucket_index(0), 0);
        assert_eq!(LogHistogram::bucket_index(1), 1);
        assert_eq!(LogHistogram::bucket_index(2), 2);
        assert_eq!(LogHistogram::bucket_index(3), 2);
        assert_eq!(LogHistogram::bucket_index(4), 3);
        assert_eq!(LogHistogram::bucket_index(7), 3);
        assert_eq!(LogHistogram::bucket_index(8), 4);
        assert_eq!(LogHistogram::bucket_index(u64::MAX), 64);
        assert_eq!(LogHistogram::bucket_floor(0), 0);
        assert_eq!(LogHistogram::bucket_floor(1), 1);
        assert_eq!(LogHistogram::bucket_floor(64), 1u64 << 63);
        for v in [0u64, 1, 2, 4, 1 << 20, 1 << 63, u64::MAX] {
            let i = LogHistogram::bucket_index(v);
            assert!(LogHistogram::bucket_floor(i) <= v,
                    "floor of bucket {i} must not exceed {v}");
        }
    }

    #[test]
    fn record_and_percentiles() {
        let mut h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.5), 0);
        assert!(h.mean().is_nan());
        for v in [0u64, 1, 2, 3, 4, 8, 8, 8, 1024, 1 << 40] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.min(), Some(0));
        assert_eq!(h.max(), Some(1 << 40));
        assert_eq!(h.sum(), (2 + 3 + 4 + 8 + 8 + 8 + 1024) as u128
                   + (1u128 << 40) + 1);
        // rank 5 of 10 at p50 -> the value 4's bucket floor
        assert_eq!(h.percentile(0.5), 4);
        // p99 -> rank 10 -> the 2^40 bucket
        assert_eq!(h.percentile(0.99), 1 << 40);
        // p0 clamps to rank 1 -> the zero bucket
        assert_eq!(h.percentile(0.0), 0);
    }

    #[test]
    fn merge_is_elementwise_and_commutative() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in [1u64, 5, 9] {
            a.record(v);
        }
        for v in [0u64, 1 << 30] {
            b.record(v);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.count(), 5);
        assert_eq!(ab.min(), Some(0));
        assert_eq!(ab.max(), Some(1 << 30));
        // merging an empty histogram is the identity
        let mut id = ab.clone();
        id.merge(&LogHistogram::new());
        assert_eq!(id, ab);
    }
}
