//! Fixed-width table rendering for `pocketllm report` — the output that
//! mirrors the paper's Tables 1 and 2 row-for-row.

/// Simple aligned-text table builder.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), header: Vec::new(), rows: Vec::new() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.rows.push(cells.iter().map(|s| s.to_string()).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("── {} ──\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                line.push_str(&format!("{:<width$}  ", cell, width = w));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let total: usize =
                widths.iter().sum::<usize>() + 2 * widths.len();
            out.push_str(&"-".repeat(total.saturating_sub(2)));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo").header(&["name", "value"]);
        t.row_str(&["alpha", "1"]);
        t.row_str(&["b", "23456"]);
        let s = t.render();
        assert!(s.contains("── Demo ──"));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns align: 'value' and '23456' start at the same offset
        let hdr_off = lines[1].find("value").unwrap();
        let row_off = lines[4].find("23456").unwrap();
        assert_eq!(hdr_off, row_off);
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("").header(&["a", "b", "c"]);
        t.row_str(&["1"]);
        let s = t.render();
        assert!(s.contains('1'));
    }
}
