//! Minimal benchmark harness (the offline environment has no criterion).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries, each of which
//! uses this module: warmup, N timed iterations, Welford stats, and a
//! rendered table.  Measurements are wall-clock per iteration.

use std::time::Instant;

use crate::util::timer::Stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub stats: Stats,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.stats.mean() * 1e3
    }
}

/// Time `iters` runs of `f` after `warmup` runs.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        stats.push(t.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), stats }
}

/// Render measurements as a table (mean / std / min / max in ms).
pub fn render(title: &str, ms: &[Measurement]) -> String {
    let mut t = super::Table::new(title).header(&[
        "benchmark", "mean ms", "std ms", "min ms", "max ms", "iters",
    ]);
    for m in ms {
        t.row(&[
            m.name.clone(),
            format!("{:.3}", m.stats.mean() * 1e3),
            format!("{:.3}", m.stats.std() * 1e3),
            format!("{:.3}", m.stats.min() * 1e3),
            format!("{:.3}", m.stats.max() * 1e3),
            m.stats.count().to_string(),
        ]);
    }
    t.render()
}

/// Read an override from the environment (bench knobs without flags).
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Current process resident-set size (bytes) from /proc (Linux).
pub fn current_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim()
                .parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Process lifetime peak RSS (bytes).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim()
                .parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let m = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.stats.count(), 10);
        assert!(m.stats.mean() >= 0.0);
    }

    #[test]
    fn render_contains_rows() {
        let m = bench("x", 0, 3, || {});
        let s = render("T", &[m]);
        assert!(s.contains("x"));
        assert!(s.contains("mean ms"));
    }

    #[test]
    fn rss_readable_on_linux() {
        assert!(current_rss_bytes().unwrap_or(0) > 0);
        assert!(peak_rss_bytes().unwrap_or(0) > 0);
    }
}
