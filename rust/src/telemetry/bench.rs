//! Minimal benchmark harness (the offline environment has no criterion).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries, each of which
//! uses this module: warmup, N timed iterations, Welford stats, and a
//! rendered table.  Measurements are wall-clock per iteration.

use std::time::Instant;

use crate::util::timer::Stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub stats: Stats,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.stats.mean() * 1e3
    }
}

/// Time `iters` runs of `f` after `warmup` runs.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        stats.push(t.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), stats }
}

/// Render measurements as a table (mean / std / min / max in ms).
pub fn render(title: &str, ms: &[Measurement]) -> String {
    let mut t = super::Table::new(title).header(&[
        "benchmark", "mean ms", "std ms", "min ms", "max ms", "iters",
    ]);
    for m in ms {
        t.row(&[
            m.name.clone(),
            format!("{:.3}", m.stats.mean() * 1e3),
            format!("{:.3}", m.stats.std() * 1e3),
            format!("{:.3}", m.stats.min() * 1e3),
            format!("{:.3}", m.stats.max() * 1e3),
            m.stats.count().to_string(),
        ]);
    }
    t.render()
}

/// Serialize measurements (plus derived scalar metrics) as a JSON
/// report — the durable form of a bench run (`make bench` writes
/// `BENCH_*.json` at the repo root so perf changes leave a trail CI
/// can archive and PRs can diff).
pub fn dump_json(
    path: &str,
    title: &str,
    ms: &[Measurement],
    extra: &[(&str, f64)],
) -> std::io::Result<()> {
    use crate::util::json::Json;
    let rows: Vec<Json> = ms
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("name", Json::str(&m.name)),
                ("mean_ms", Json::num(m.stats.mean() * 1e3)),
                ("std_ms", Json::num(m.stats.std() * 1e3)),
                ("min_ms", Json::num(m.stats.min() * 1e3)),
                ("max_ms", Json::num(m.stats.max() * 1e3)),
                ("iters", Json::num(m.stats.count() as f64)),
            ])
        })
        .collect();
    let mut pairs: Vec<(&str, Json)> = vec![
        ("title", Json::str(title)),
        ("measurements", Json::Arr(rows)),
    ];
    for (k, v) in extra {
        pairs.push((k, Json::num(*v)));
    }
    std::fs::write(path, Json::obj(pairs).dump())
}

/// Read an override from the environment (bench knobs without flags).
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Current process resident-set size (bytes) from /proc (Linux).
pub fn current_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim()
                .parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Process lifetime peak RSS (bytes).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim()
                .parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let m = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.stats.count(), 10);
        assert!(m.stats.mean() >= 0.0);
    }

    #[test]
    fn render_contains_rows() {
        let m = bench("x", 0, 3, || {});
        let s = render("T", &[m]);
        assert!(s.contains("x"));
        assert!(s.contains("mean ms"));
    }

    #[test]
    fn dump_json_writes_parseable_report() {
        let m = bench("probe", 0, 2, || {});
        let path = std::env::temp_dir().join("pocketllm_bench_dump.json");
        let path = path.to_str().unwrap().to_string();
        dump_json(&path, "T", &[m], &[("derived_ms", 1.5)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::util::json::parse(&text).unwrap();
        assert_eq!(json.get("title").as_str(), Some("T"));
        assert_eq!(json.get("derived_ms").as_f64(), Some(1.5));
        let rows = json.get("measurements").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").as_str(), Some("probe"));
        assert_eq!(rows[0].get("iters").as_f64(), Some(2.0));
    }

    #[test]
    fn rss_readable_on_linux() {
        assert!(current_rss_bytes().unwrap_or(0) > 0);
        assert!(peak_rss_bytes().unwrap_or(0) > 0);
    }
}
