//! Step-indexed metric series (loss curves, step times, memory) with CSV
//! and JSON export.  This is what EXPERIMENTS.md's recorded runs are
//! generated from.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// One named series of (step, value) points.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<(u64, f64)>,
}

impl Series {
    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        self.points.iter().map(|&(_, v)| v).sum::<f64>()
            / self.points.len() as f64
    }

    /// Mean of the first / last `k` points — used for "did the loss go
    /// down" assertions in tests and benches.
    pub fn head_mean(&self, k: usize) -> f64 {
        let k = k.min(self.points.len());
        self.points[..k].iter().map(|&(_, v)| v).sum::<f64>() / k.max(1) as f64
    }

    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.points.len();
        let k = k.min(n);
        self.points[n - k..].iter().map(|&(_, v)| v).sum::<f64>()
            / k.max(1) as f64
    }
}

/// A bundle of named series sharing a step axis.
#[derive(Debug, Clone, Default)]
pub struct MetricLog {
    pub series: BTreeMap<String, Series>,
}

impl MetricLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push(step, value);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// CSV with a `step` column and one column per series (empty cells
    /// where a series has no point at that step).
    pub fn to_csv(&self) -> String {
        let mut steps: Vec<u64> = Vec::new();
        for s in self.series.values() {
            for &(st, _) in &s.points {
                steps.push(st);
            }
        }
        steps.sort();
        steps.dedup();

        let names: Vec<&String> = self.series.keys().collect();
        let mut out = String::from("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for st in steps {
            out.push_str(&st.to_string());
            for n in &names {
                out.push(',');
                let s = &self.series[*n];
                if let Some(&(_, v)) =
                    s.points.iter().find(|&&(p, _)| p == st)
                {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|&(st, v)| {
                                    Json::Arr(vec![
                                        Json::Num(st as f64),
                                        Json::Num(v),
                                    ])
                                })
                                .collect(),
                        ),
                    )
                })
                .collect(),
        )
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = MetricLog::new();
        m.record("loss", 0, 2.0);
        m.record("loss", 1, 1.0);
        m.record("time", 0, 5.0);
        assert_eq!(m.get("loss").unwrap().last(), Some(1.0));
        assert_eq!(m.get("loss").unwrap().mean(), 1.5);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn head_tail_means() {
        let mut s = Series::default();
        for i in 0..10 {
            s.push(i, i as f64);
        }
        assert_eq!(s.head_mean(2), 0.5);
        assert_eq!(s.tail_mean(2), 8.5);
    }

    #[test]
    fn csv_shape() {
        let mut m = MetricLog::new();
        m.record("a", 0, 1.0);
        m.record("b", 1, 2.0);
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,,2");
    }

    #[test]
    fn json_export_parses() {
        let mut m = MetricLog::new();
        m.record("loss", 3, 0.25);
        let j = m.to_json().dump();
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("loss").at(0).at(1).as_f64(), Some(0.25));
    }
}
