//! Step-indexed metric series (loss curves, step times, memory) with CSV
//! and JSON export.  This is what EXPERIMENTS.md's recorded runs are
//! generated from.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// One named series of (step, value) points.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<(u64, f64)>,
}

impl Series {
    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        self.points.iter().map(|&(_, v)| v).sum::<f64>()
            / self.points.len() as f64
    }

    /// Mean of the first / last `k` points — used for "did the loss go
    /// down" assertions in tests and benches.  Like [`Series::mean`],
    /// `NaN` on an empty series (they used to return `0.0`, silently
    /// passing "loss improved" assertions on a series that never
    /// recorded anything).
    pub fn head_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let k = k.min(self.points.len());
        self.points[..k].iter().map(|&(_, v)| v).sum::<f64>()
            / k.max(1) as f64
    }

    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let n = self.points.len();
        let k = k.min(n);
        self.points[n - k..].iter().map(|&(_, v)| v).sum::<f64>()
            / k.max(1) as f64
    }
}

/// A bundle of named series sharing a step axis.
#[derive(Debug, Clone, Default)]
pub struct MetricLog {
    pub series: BTreeMap<String, Series>,
}

impl MetricLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push(step, value);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Fold `other` into this log (series merged by name, points
    /// appended in `other`'s order).  Consumes the source — the fleet
    /// aggregation path drops it anyway, and moving the buffers avoids
    /// re-cloning every point of fleet-scale per-job logs.
    pub fn merge(&mut self, other: MetricLog) {
        for (name, mut s) in other.series {
            self.series
                .entry(name)
                .or_default()
                .points
                .append(&mut s.points);
        }
    }

    /// CSV with a `step` column and one column per series (empty cells
    /// where a series has no point at that step).
    ///
    /// Single merge pass with one cursor per series — O(total points ·
    /// log) — replacing the old per-cell linear `find`, which was
    /// quadratic in run length and pathological for fleet-scale logs
    /// (pinned by `to_csv_large_log_is_not_quadratic`).  Cell semantics
    /// are unchanged: for duplicate steps within a series, the
    /// first-recorded value wins (stable sort preserves record order).
    pub fn to_csv(&self) -> String {
        // global step axis
        let mut steps: Vec<u64> = Vec::new();
        for s in self.series.values() {
            for &(st, _) in &s.points {
                steps.push(st);
            }
        }
        steps.sort();
        steps.dedup();

        // per-series step-sorted view (indices; stable for ties) +
        // cursor
        let cols: Vec<(&String, &Series, Vec<usize>)> = self
            .series
            .iter()
            .map(|(name, s)| {
                let mut idx: Vec<usize> = (0..s.points.len()).collect();
                idx.sort_by_key(|&i| s.points[i].0);
                (name, s, idx)
            })
            .collect();
        let mut cursors = vec![0usize; cols.len()];

        let mut out = String::from("step");
        for (name, _, _) in &cols {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for st in steps {
            out.push_str(&st.to_string());
            for (ci, (_, s, idx)) in cols.iter().enumerate() {
                out.push(',');
                let cur = &mut cursors[ci];
                while *cur < idx.len() && s.points[idx[*cur]].0 < st {
                    *cur += 1;
                }
                if *cur < idx.len() && s.points[idx[*cur]].0 == st {
                    let v = s.points[idx[*cur]].1;
                    out.push_str(&format!("{v}"));
                    // skip duplicates of this step; they were never
                    // emitted by the old code either
                    while *cur < idx.len() && s.points[idx[*cur]].0 == st
                    {
                        *cur += 1;
                    }
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|&(st, v)| {
                                    Json::Arr(vec![
                                        Json::Num(st as f64),
                                        Json::Num(v),
                                    ])
                                })
                                .collect(),
                        ),
                    )
                })
                .collect(),
        )
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = MetricLog::new();
        m.record("loss", 0, 2.0);
        m.record("loss", 1, 1.0);
        m.record("time", 0, 5.0);
        assert_eq!(m.get("loss").unwrap().last(), Some(1.0));
        assert_eq!(m.get("loss").unwrap().mean(), 1.5);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn head_tail_means() {
        let mut s = Series::default();
        for i in 0..10 {
            s.push(i, i as f64);
        }
        assert_eq!(s.head_mean(2), 0.5);
        assert_eq!(s.tail_mean(2), 8.5);
        // k larger than the series degrades to the whole-series mean
        assert_eq!(s.head_mean(100), s.mean());
        assert_eq!(s.tail_mean(100), s.mean());
    }

    #[test]
    fn empty_series_means_are_nan() {
        // all three means agree on empty: NaN, never a fake 0.0 that
        // could satisfy a "loss improved" assertion vacuously
        let s = Series::default();
        assert!(s.mean().is_nan());
        assert!(s.head_mean(3).is_nan());
        assert!(s.tail_mean(3).is_nan());
        // and k=0 on a non-empty series stays finite (0-point mean is
        // 0/max(1) — unchanged behaviour, only the empty case moved)
        let mut ne = Series::default();
        ne.push(0, 2.0);
        assert_eq!(ne.head_mean(0), 0.0);
        assert_eq!(ne.tail_mean(0), 0.0);
    }

    #[test]
    fn csv_shape() {
        let mut m = MetricLog::new();
        m.record("a", 0, 1.0);
        m.record("b", 1, 2.0);
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,,2");
    }

    /// The pre-rewrite per-cell linear-scan implementation, kept as the
    /// shape oracle for the merge-pass `to_csv`.
    fn to_csv_reference(m: &MetricLog) -> String {
        let mut steps: Vec<u64> = Vec::new();
        for s in m.series.values() {
            for &(st, _) in &s.points {
                steps.push(st);
            }
        }
        steps.sort();
        steps.dedup();
        let names: Vec<&String> = m.series.keys().collect();
        let mut out = String::from("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for st in steps {
            out.push_str(&st.to_string());
            for n in &names {
                out.push(',');
                let s = &m.series[*n];
                if let Some(&(_, v)) =
                    s.points.iter().find(|&&(p, _)| p == st)
                {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    #[test]
    fn csv_merge_pass_matches_reference_shape() {
        // sparse, interleaved, duplicate and out-of-order steps — every
        // corner the per-series cursors must reproduce
        let mut m = MetricLog::new();
        for (st, v) in [(0, 1.0), (2, 2.0), (2, 99.0), (7, 3.0)] {
            m.record("a", st, v);
        }
        for (st, v) in [(5, 4.0), (1, 5.0), (1, 6.0), (2, 7.0)] {
            m.record("b", st, v); // out of order + duplicate step 1
        }
        m.record("c", 1_000_000, 8.0);
        assert_eq!(m.to_csv(), to_csv_reference(&m));
        // and the duplicate-step rule is first-recorded-wins
        assert!(m.to_csv().contains("\n2,2,7,\n"), "{}", m.to_csv());
    }

    #[test]
    fn to_csv_large_log_is_not_quadratic() {
        // fleet-scale smoke: 4 series x 20k points with disjoint step
        // ranges (worst case for the old per-cell scan: 80k rows x 4
        // series x 20k finds).  The merge pass renders this instantly;
        // the old code would hang the test suite.
        let mut m = MetricLog::new();
        for j in 0..4u64 {
            for i in 0..20_000u64 {
                m.record(&format!("job{j}.loss"), j * 20_000 + i,
                         i as f64);
            }
        }
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 1 + 4 * 20_000);
        let first = csv.lines().nth(1).unwrap();
        assert_eq!(first, "0,0,,,");
        let last = csv.lines().last().unwrap();
        assert_eq!(last, "79999,,,,19999");
    }

    #[test]
    fn merge_appends_series_by_name() {
        let mut a = MetricLog::new();
        a.record("loss", 0, 1.0);
        a.record("loss", 1, 0.5);
        let mut b = MetricLog::new();
        b.record("loss", 2, 0.25);
        b.record("aux", 0, 9.0);
        a.merge(b);
        assert_eq!(a.get("loss").unwrap().points,
                   vec![(0, 1.0), (1, 0.5), (2, 0.25)]);
        assert_eq!(a.get("aux").unwrap().points, vec![(0, 9.0)]);
    }

    #[test]
    fn json_export_parses() {
        let mut m = MetricLog::new();
        m.record("loss", 3, 0.25);
        let j = m.to_json().dump();
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("loss").at(0).at(1).as_f64(), Some(0.25));
    }
}
