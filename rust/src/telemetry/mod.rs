//! Telemetry: metric recording, CSV export, deterministic tracing and
//! latency histograms, and the fixed-width table renderer used by
//! `pocketllm report` and the bench harness.

pub mod bench;
pub mod hist;
pub mod metrics;
pub mod table;
pub mod trace;

pub use hist::LogHistogram;
pub use metrics::{MetricLog, Series};
pub use table::Table;
pub use trace::{Span, SpanKind};
