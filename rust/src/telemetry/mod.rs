//! Telemetry: metric recording, CSV export, and the fixed-width table
//! renderer used by `pocketllm report` and the bench harness.

pub mod bench;
pub mod metrics;
pub mod table;

pub use metrics::{MetricLog, Series};
pub use table::Table;
