//! Span-based structured tracing, simulation-clocked.
//!
//! Every layer of a fleet run emits [`Span`]s — dispatch, window,
//! mode selection, step batch, per-kernel work, link round trips —
//! timestamped on the **simulated clock** (microseconds since the
//! fleet epoch; window `w` starts at `w * trace_step_minutes * 60e6`)
//! and carrying only deterministic payloads (bytes moved, energy
//! billed, deny reason, precision, link weather).  Trace content is
//! therefore **bit-identical for any worker count**, exactly like
//! events and metrics, and is journaled/replayed with them
//! ([`crate::store::journal`]).
//!
//! ## The wall-clock segregation rule
//!
//! Host time is allowed into a trace through exactly ONE door:
//! [`host_now_us`], the only wall-clock read in this module (and the
//! only `src/` file outside `util/timer.rs`/`telemetry/bench.rs`/
//! `main.rs` on pallas-lint D002's allowlist).  Its readings ride in
//! [`Span::host_us`] — an `Option` that is **excluded** from
//! [`Span::det_line`] fingerprints, from the journal wire format, and
//! stripped from `--trace-out` JSON by the CI diff — so wall time can
//! inform a human without ever perturbing a deterministic output.
//!
//! Which [`Span`] fields are deterministic:
//!
//! | field | deterministic? |
//! |----------------------------------------|------------------|
//! | `job`, `window`, `kind`, `label`       | yes |
//! | `detail`, `t_us`, `dur_us`             | yes |
//! | `bytes`, `uwh`, `flops`                | yes |
//! | `host_us`                              | **no** — wall clock |

use std::sync::OnceLock;
use std::time::Instant;

use crate::runtime::manifest::ConfigInfo;
use crate::runtime::native::math;
use crate::util::json::Json;

/// What a span measures.  Codes are the journal wire encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Job enqueue -> first policy-admitted window.
    Dispatch,
    /// One simulated policy window (admitted, denied, or deferred).
    Window,
    /// The tuning-mode decision for an admitted window.
    Mode,
    /// A link round trip (split payload or mid-flight drop).
    Link,
    /// The window's step batch (local or split).
    Step,
    /// One dense kernel's share of a step batch (analytic profile).
    Kernel,
}

impl SpanKind {
    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Dispatch => "dispatch",
            SpanKind::Window => "window",
            SpanKind::Mode => "mode",
            SpanKind::Link => "link",
            SpanKind::Step => "step",
            SpanKind::Kernel => "kernel",
        }
    }

    pub fn code(&self) -> u8 {
        match self {
            SpanKind::Dispatch => 0,
            SpanKind::Window => 1,
            SpanKind::Mode => 2,
            SpanKind::Link => 3,
            SpanKind::Step => 4,
            SpanKind::Kernel => 5,
        }
    }

    pub fn from_code(c: u8) -> Option<SpanKind> {
        Some(match c {
            0 => SpanKind::Dispatch,
            1 => SpanKind::Window,
            2 => SpanKind::Mode,
            3 => SpanKind::Link,
            4 => SpanKind::Step,
            5 => SpanKind::Kernel,
            _ => return None,
        })
    }
}

/// One traced interval.  All fields except `host_us` are
/// deterministic (see the module table); `host_us` is the segregated
/// wall-clock sidecar.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub job: u32,
    /// Simulated window index this span belongs to.
    pub window: u32,
    pub kind: SpanKind,
    /// Deterministic identity: mode / deny reason / kernel name /
    /// precision.
    pub label: String,
    /// Deterministic payload rendered as `k=v` pairs (link weather,
    /// step count, kernel call count).
    pub detail: String,
    /// Sim-clock start, microseconds since the fleet epoch.
    pub t_us: u64,
    /// Sim-clock duration, microseconds.
    pub dur_us: u64,
    /// Payload bytes moved over the link (0 when not a transfer).
    pub bytes: u64,
    /// Energy billed, micro-watt-hours (quantized, deterministic).
    pub uwh: u64,
    /// Analytic floating-point operations (kernel spans).
    pub flops: u64,
    /// Wall-clock duration in microseconds — telemetry only, never
    /// journaled, never fingerprinted, stripped by the CI trace diff.
    pub host_us: Option<u64>,
}

impl Span {
    /// The deterministic rendering of this span: every field except
    /// `host_us`, one line.  Equal `det_line`s mean bit-equal
    /// deterministic content — the unit the worker-count and
    /// crash-replay identity tests compare.
    pub fn det_line(&self) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
            self.job,
            self.window,
            self.kind.label(),
            self.label,
            self.detail,
            self.t_us,
            self.dur_us,
            self.bytes,
            self.uwh,
            self.flops
        )
    }
}

/// Joined [`Span::det_line`]s — the whole-trace deterministic
/// fingerprint.
pub fn fingerprint(spans: &[Span]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str(&s.det_line());
        out.push('\n');
    }
    out
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Microseconds of host wall clock since this process first asked.
///
/// THE one sanctioned wall-clock capture point for trace data: every
/// `host_us` in the tree is a difference of two readings of this
/// function.  pallas-lint D002 allowlists exactly this file; any
/// other simulated-device code reaching for `Instant::now` stays a
/// lint error (fixture-pinned in `rust/tests/lint.rs`).
pub fn host_now_us() -> u64 {
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_micros() as u64
}

/// Quantize simulated seconds to whole microseconds — the trace time
/// base.  Deterministic: one f64 multiply and one round, no
/// accumulation.
pub fn sim_us(seconds: f64) -> u64 {
    let us = (seconds * 1e6).round();
    if us.is_finite() && us > 0.0 { us as u64 } else { 0 }
}

/// Quantize watt-hours to whole micro-watt-hours.
pub fn sim_uwh(wh: f64) -> u64 {
    sim_us(wh)
}

/// Render spans as Chrome trace-event JSON (one complete event per
/// line), loadable in Perfetto / `chrome://tracing`.  `pid` is always
/// 0, `tid` is the job index, `ts`/`dur` are sim-clock microseconds.
/// The wall-clock sidecar is emitted as a top-level `host_dur_us`
/// key so CI can strip it with one `sed` before diffing worker
/// counts.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, s) in spans.iter().enumerate() {
        let args = Json::obj(vec![
            ("bytes", Json::num(s.bytes as f64)),
            ("detail", Json::str(&s.detail)),
            ("flops", Json::num(s.flops as f64)),
            ("uwh", Json::num(s.uwh as f64)),
        ]);
        let mut ev = vec![
            ("args", args),
            ("cat", Json::str(s.kind.label())),
            ("dur", Json::num(s.dur_us as f64)),
            ("name", Json::str(&s.label)),
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(s.job as f64)),
            ("ts", Json::num(s.t_us as f64)),
        ];
        if let Some(h) = s.host_us {
            ev.push(("host_dur_us", Json::num(h as f64)));
        }
        out.push_str(&Json::obj(ev).dump());
        if i + 1 < spans.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// One kernel's analytic totals for a single training step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelProfile {
    pub name: &'static str,
    pub calls: u64,
    pub flops: u64,
    pub bytes: u64,
}

/// The analytic per-step kernel profile of a model: which dense
/// kernels one step calls, how often, and their flop/byte totals —
/// computed from the manifest dims with the same cost formulas
/// `benches/hotpath.rs` reports measured GFLOP/s against
/// ([`math::matmul_cost`] / [`math::col_sums_cost`]), so `pocketllm
/// trace` can show a per-step kernel breakdown without running the
/// bench harness.  `forwards` is the forward-equivalent count per
/// step (MeZO two-point = `2 * queries`, Adam fwd+bwd ~ 3, split
/// forward-only = 1).
pub fn step_kernel_profile(
    cfg: &ConfigInfo,
    batch: usize,
    seq: usize,
    forwards: u64,
) -> Vec<KernelProfile> {
    let bs = batch * seq;
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let heads = cfg.n_heads.max(1);
    let dh = (d / heads).max(1);
    let layers = cfg.n_layers as u64;
    let scaled = |name, calls_per_fwd: u64, c: math::KernelCost| {
        let calls = calls_per_fwd * forwards;
        KernelProfile {
            name,
            calls,
            flops: c.flops.saturating_mul(calls),
            bytes: c.bytes.saturating_mul(calls),
        }
    };
    let attn_calls = (batch * heads) as u64 * layers;
    let mut out = vec![
        scaled("matmul_bias(qkv+o)", 4 * layers,
               math::matmul_cost(bs, d, d)),
        scaled("matmul_bt(scores)", attn_calls,
               math::matmul_cost(seq, dh, seq)),
        scaled("matmul(attn_v)", attn_calls,
               math::matmul_cost(seq, seq, dh)),
        scaled("matmul_bias(ffn)", 2 * layers,
               math::matmul_cost(bs, d, ff)),
    ];
    if cfg.kind == "decoder" {
        out.push(scaled("matmul_bt(lm_head)", 1,
                        math::matmul_cost(bs, d, cfg.vocab)));
    } else {
        out.push(scaled("matmul_bias(head)", 1,
                        math::matmul_cost(batch, d,
                                          cfg.n_classes.max(1))));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(job: u32, host: Option<u64>) -> Span {
        Span {
            job,
            window: 3,
            kind: SpanKind::Window,
            label: "local".into(),
            detail: "steps=4".into(),
            t_us: 1_800_000_000,
            dur_us: 2_500_000,
            bytes: 0,
            uwh: 1200,
            flops: 0,
            host_us: host,
        }
    }

    #[test]
    fn det_line_ignores_host_wall_clock() {
        let a = span(1, None);
        let b = span(1, Some(987_654));
        assert_ne!(a, b);
        assert_eq!(a.det_line(), b.det_line(),
                   "host_us must never reach the fingerprint");
        assert_eq!(fingerprint(&[a.clone()]), fingerprint(&[b]));
        assert_ne!(a.det_line(), span(2, None).det_line());
    }

    #[test]
    fn sim_us_quantizes_deterministically() {
        assert_eq!(sim_us(0.0), 0);
        assert_eq!(sim_us(-1.0), 0);
        assert_eq!(sim_us(1.0), 1_000_000);
        assert_eq!(sim_us(2.5e-6), 3); // round half away from zero
        assert_eq!(sim_us(f64::NAN), 0);
    }

    #[test]
    fn host_clock_is_monotone() {
        let a = host_now_us();
        let b = host_now_us();
        assert!(b >= a);
    }

    #[test]
    fn chrome_json_one_event_per_line_and_strippable() {
        let spans = vec![span(0, Some(42)), span(1, None)];
        let j = chrome_trace_json(&spans);
        assert!(j.starts_with("{\"traceEvents\":[\n"));
        assert!(j.trim_end().ends_with("],\"displayTimeUnit\":\"ms\"}"));
        let events: Vec<&str> = j
            .lines()
            .filter(|l| l.starts_with('{') && l.contains("\"ph\""))
            .collect();
        assert_eq!(events.len(), 2);
        assert!(events[0].contains(",\"host_dur_us\":42"),
                "{}", events[0]);
        assert!(!events[1].contains("host_dur_us"));
        // the CI strip discipline: removing the host key makes the
        // two runs' lines comparable
        let stripped = events[0].replace(",\"host_dur_us\":42", "");
        assert!(!stripped.contains("host"));
        // and it parses as JSON
        assert!(crate::util::json::parse(&j).is_ok());
    }

    #[test]
    fn kernel_profile_scales_with_forwards() {
        let cfg = ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 512,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 128,
            max_seq: 32,
            n_classes: 2,
            use_pallas: false,
            n_params: 0,
            params: Vec::new(),
        };
        let one = step_kernel_profile(&cfg, 4, 32, 1);
        let two = step_kernel_profile(&cfg, 4, 32, 2);
        assert_eq!(one.len(), 5);
        for (a, b) in one.iter().zip(&two) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.calls * 2, b.calls);
            assert_eq!(a.flops * 2, b.flops);
        }
        // qkv+o per forward: 4 calls/layer x 2 layers
        assert_eq!(one[0].calls, 8);
        // flops formula shared with the bench harness
        let c = math::matmul_cost(4 * 32, 64, 64);
        assert_eq!(one[0].flops, c.flops * 8);
    }
}
