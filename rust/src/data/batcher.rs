//! Batch assembly: tokenize, frame, pad, shuffle — producing exactly the
//! `[B, S]` tensors the AOT step programs take.
//!
//! Layout contract (must match `python/compile/aot.py`):
//! * `ids`    — i32 `[B, S]`, BOS + tokens + EOS, PAD-filled,
//! * `mask`   — f32 `[B, S]`, 1.0 on real tokens (incl. BOS/EOS),
//! * `labels` — classification: i32 `[B]`; causal LM: i32 `[B, S]` = ids.

use super::bpe::{Bpe, BOS, EOS, PAD};
use super::corpus::Sample;
use crate::util::rng::Rng;

/// One ready-to-execute batch (row-major host buffers).
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub ids: Vec<i32>,
    pub mask: Vec<f32>,
    /// `[B]` for classification, `[B*S]` (== ids) for LM.
    pub labels: Vec<i32>,
    pub lm: bool,
}

impl Batch {
    /// Fraction of non-pad positions (useful for throughput accounting).
    pub fn density(&self) -> f64 {
        let live: f64 = self.mask.iter().map(|&m| m as f64).sum();
        live / self.mask.len() as f64
    }
}

/// Deterministic epoch-shuffling batcher over a sample set.
pub struct Batcher<'a> {
    bpe: &'a Bpe,
    samples: &'a [Sample],
    batch: usize,
    seq: usize,
    lm: bool,
    vocab_limit: i32,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: u64,
}

/// A resumable snapshot of a [`Batcher`]'s position in its stream.
///
/// The batcher is deterministic under (data, seed), so a session can
/// cap its batch cache at a fixed window and still regenerate any
/// batch: resume from the last snapshot for the sequential case (O(1)
/// per step), or replay from step 0 on a cold miss.  This is what
/// bounds the per-session memory of million-step runs (ROADMAP
/// "Batcher scalability").
#[derive(Debug, Clone)]
pub struct BatcherState {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(
        bpe: &'a Bpe,
        samples: &'a [Sample],
        batch: usize,
        seq: usize,
        lm: bool,
        vocab_limit: usize,
        seed: u64,
    ) -> Batcher<'a> {
        assert!(!samples.is_empty(), "empty dataset");
        assert!(batch > 0 && seq > 2, "bad batch geometry");
        let mut b = Batcher {
            bpe,
            samples,
            batch,
            seq,
            lm,
            vocab_limit: vocab_limit as i32,
            order: (0..samples.len()).collect(),
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Snapshot the stream position (see [`BatcherState`]).
    pub fn state(&self) -> BatcherState {
        BatcherState {
            order: self.order.clone(),
            cursor: self.cursor,
            rng: self.rng.clone(),
            epoch: self.epoch,
        }
    }

    /// Resume from a [`state`](Batcher::state) snapshot taken on a
    /// batcher with the same (data, seed, geometry).
    pub fn restore(&mut self, st: &BatcherState) {
        self.order = st.order.clone();
        self.cursor = st.cursor;
        self.rng = st.rng.clone();
        self.epoch = st.epoch;
    }

    /// Encode one sample into a fixed-length row.
    fn encode_row(&self, s: &Sample, ids: &mut [i32], mask: &mut [f32]) {
        let toks = self.bpe.encode(&s.text);
        ids.fill(PAD);
        mask.fill(0.0);
        ids[0] = BOS;
        mask[0] = 1.0;
        let take = toks.len().min(self.seq - 2);
        for (j, &t) in toks[..take].iter().enumerate() {
            // clamp to the model's embedding table size
            ids[j + 1] = if t < self.vocab_limit { t } else { super::bpe::UNK };
            mask[j + 1] = 1.0;
        }
        ids[take + 1] = EOS;
        mask[take + 1] = 1.0;
    }

    /// Advance the stream position by `n` batches WITHOUT tokenizing
    /// or materializing them: identical cursor/epoch/RNG evolution to
    /// `n` [`next`](Batcher::next) calls (pinned by test), but each
    /// skipped batch costs only index arithmetic plus one shuffle per
    /// epoch wrap.  This is how a rehydrated session rebuilds its
    /// [`BatcherState`] from the bare stream position a session image
    /// stores — O(100) bytes durable instead of the order vector.
    pub fn skip(&mut self, n: usize) {
        for _ in 0..n {
            for _ in 0..self.batch {
                if self.cursor >= self.order.len() {
                    self.cursor = 0;
                    self.epoch += 1;
                    self.rng.shuffle(&mut self.order);
                }
                self.cursor += 1;
            }
        }
    }

    /// Next batch; wraps epochs (reshuffling) as needed.
    pub fn next(&mut self) -> Batch {
        let mut ids = vec![PAD; self.batch * self.seq];
        let mut mask = vec![0.0f32; self.batch * self.seq];
        let mut cls_labels = Vec::with_capacity(self.batch);
        for r in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epoch += 1;
                self.rng.shuffle(&mut self.order);
            }
            let s = &self.samples[self.order[self.cursor]];
            self.cursor += 1;
            let lo = r * self.seq;
            self.encode_row(s, &mut ids[lo..lo + self.seq],
                            &mut mask[lo..lo + self.seq]);
            cls_labels.push(s.label.max(0));
        }
        let labels = if self.lm { ids.clone() } else { cls_labels };
        Batch { batch: self.batch, seq: self.seq, ids, mask, labels,
                lm: self.lm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;
    use crate::data::task::{TaskData, TaskKind};

    fn setup() -> (Bpe, TaskData) {
        let texts = corpus::tokenizer_corpus(1, 200);
        let bpe = Bpe::train(&texts, 320);
        let data = TaskData::generate(TaskKind::Sst2, 2, 64, 8);
        (bpe, data)
    }

    #[test]
    fn batch_geometry() {
        let (bpe, data) = setup();
        let mut b = Batcher::new(&bpe, &data.train, 4, 16, false, 512, 3);
        let batch = b.next();
        assert_eq!(batch.ids.len(), 64);
        assert_eq!(batch.mask.len(), 64);
        assert_eq!(batch.labels.len(), 4);
        // row framing: BOS first, mask matches non-pad
        for r in 0..4 {
            assert_eq!(batch.ids[r * 16], BOS);
            assert_eq!(batch.mask[r * 16], 1.0);
            for j in 0..16 {
                let live = batch.mask[r * 16 + j] > 0.0;
                let pad = batch.ids[r * 16 + j] == PAD;
                assert_eq!(live, !pad);
            }
            // exactly one EOS per live row
            let eos = (0..16)
                .filter(|&j| batch.ids[r * 16 + j] == EOS)
                .count();
            assert_eq!(eos, 1);
        }
    }

    #[test]
    fn lm_labels_mirror_ids() {
        let (bpe, _) = setup();
        let data = TaskData::generate(TaskKind::ChatLm, 5, 32, 4);
        let mut b = Batcher::new(&bpe, &data.train, 2, 16, true, 512, 3);
        let batch = b.next();
        assert_eq!(batch.labels, batch.ids);
    }

    #[test]
    fn wraps_epochs_and_reshuffles() {
        let (bpe, data) = setup();
        let mut b = Batcher::new(&bpe, &data.train, 32, 16, false, 512, 3);
        let first = b.next();
        assert_eq!(b.epoch(), 0);
        let _second = b.next();
        let third = b.next(); // 96 > 64 samples -> wrapped
        assert!(b.epoch() >= 1);
        assert_ne!(first.ids, third.ids);
    }

    #[test]
    fn deterministic_under_seed() {
        let (bpe, data) = setup();
        let a = Batcher::new(&bpe, &data.train, 4, 16, false, 512, 9).next();
        let b = Batcher::new(&bpe, &data.train, 4, 16, false, 512, 9).next();
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn truncates_long_text() {
        let (bpe, _) = setup();
        let long = Sample {
            text: "word ".repeat(100).trim().to_string(),
            label: 1,
        };
        let samples = vec![long];
        let mut b = Batcher::new(&bpe, &samples, 1, 8, false, 512, 1);
        let batch = b.next();
        assert_eq!(batch.ids.len(), 8);
        assert!(batch.mask.iter().all(|&m| m == 1.0)); // fully packed
    }

    #[test]
    fn snapshot_resume_continues_the_exact_stream() {
        let (bpe, data) = setup();
        // reference: 10 consecutive batches from one batcher
        let mut reference = Batcher::new(&bpe, &data.train, 4, 16, false,
                                         512, 9);
        let want: Vec<Batch> = (0..10).map(|_| reference.next()).collect();
        // snapshot after 6, resume in a fresh batcher, take the tail
        let mut a = Batcher::new(&bpe, &data.train, 4, 16, false, 512, 9);
        for _ in 0..6 {
            a.next();
        }
        let st = a.state();
        let mut b = Batcher::new(&bpe, &data.train, 4, 16, false, 512, 9);
        b.restore(&st);
        for w in &want[6..] {
            let got = b.next();
            assert_eq!(got.ids, w.ids);
            assert_eq!(got.labels, w.labels);
        }
    }

    #[test]
    fn skip_evolves_state_exactly_like_next() {
        // skip must reproduce next()'s cursor/epoch/rng mutations
        // bit-exactly, including across epoch wraps (64 samples, batch
        // 4 -> 40 batches span multiple epochs)
        let (bpe, data) = setup();
        for n in [0usize, 1, 5, 16, 40] {
            let mut a = Batcher::new(&bpe, &data.train, 4, 16, false,
                                     512, 9);
            for _ in 0..n {
                a.next();
            }
            let mut b = Batcher::new(&bpe, &data.train, 4, 16, false,
                                     512, 9);
            b.skip(n);
            assert_eq!(format!("{:?}", a.state()),
                       format!("{:?}", b.state()),
                       "skip({n}) diverged from {n} next() calls");
            // and the streams continue identically
            let x = a.next();
            let y = b.next();
            assert_eq!(x.ids, y.ids);
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn density_counts_padding() {
        let (bpe, data) = setup();
        let mut b = Batcher::new(&bpe, &data.train, 4, 32, false, 512, 3);
        let batch = b.next();
        assert!(batch.density() > 0.1 && batch.density() <= 1.0);
    }
}
