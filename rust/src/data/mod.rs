//! On-device data pipeline: tokenizer + synthetic personal-data tasks.
//!
//! The paper fine-tunes on SST-2 and SuperGLUE.  Those corpora (and the
//! user's real typing data the paper motivates with) are not available
//! here, so this module builds the closest synthetic equivalents that
//! exercise the same code path: template-grammar generators with enough
//! lexical signal to *learn from* ([`task`]), a from-scratch byte-pair
//! tokenizer trained on the generated corpus ([`bpe`]), and a padding /
//! shuffling batcher that emits exactly the `[B, S]` i32/f32 tensors the
//! AOT artifacts expect ([`batcher`]).
//!
//! Everything is deterministic under a seed: a fine-tuning session is
//! fully reproducible from `(task, seed)`.

pub mod batcher;
pub mod bpe;
pub mod corpus;
pub mod task;

// lint:allow(D001): the artifact-cache map below is lookup-only —
// eviction order lives in the VecDeque, never in map iteration
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use bpe::Bpe;
use task::{TaskData, TaskKind};

/// The per-session data artifacts that are pure functions of their key:
/// the generated train/eval split and the BPE tokenizer trained over
/// the corpus + train texts.
pub struct SessionArtifacts {
    pub data: TaskData,
    pub bpe: Bpe,
}

/// Cache key: everything the artifact build reads.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ArtifactKey {
    task: TaskKind,
    seed: u64,
    n_train: usize,
    n_eval: usize,
    bpe_vocab: usize,
}

/// Entries kept resident (bounds a long-lived fleet process).  Past
/// the cap the OLDEST key is evicted (FIFO) — one at a time, so a
/// busy process degrades to rebuilding its coldest artifact instead
/// of thrashing the whole cache.
const ARTIFACT_CACHE_CAP: usize = 64;

/// One cache slot: created under the map lock, initialized (the
/// expensive build) under its own per-key `OnceLock` — so distinct
/// keys build fully in parallel while same-key racers block on each
/// other, not on the whole cache.
type ArtifactCell = Arc<OnceLock<Arc<SessionArtifacts>>>;

/// Cell map + FIFO insertion order (for eviction), under one lock.
#[derive(Default)]
struct ArtifactCache {
    // lint:allow(D001): lookup-only; FIFO eviction walks `order`
    map: HashMap<ArtifactKey, ArtifactCell>,
    order: VecDeque<ArtifactKey>,
}

static ARTIFACT_CACHE: OnceLock<Mutex<ArtifactCache>> = OnceLock::new();
static ARTIFACT_HITS: AtomicU64 = AtomicU64::new(0);
static ARTIFACT_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Build-or-share the tokenizer/corpus artifacts for one session.
///
/// N same-`(task, seed)` sessions (fleet re-runs, bench iterations,
/// A/B sessions over one user's data) train the BPE and generate the
/// corpus exactly once; the result is shared by `Arc`, so this changes
/// wall-clock and memory only — the artifacts a session sees are
/// value-identical to a private build (the build body below is the
/// former `SessionBuilder::build` code verbatim).
///
/// The map lock is held only to look up / insert the per-key cell;
/// the build itself runs under that cell's `OnceLock`.  Distinct keys
/// therefore build concurrently, while N same-key requesters resolve
/// to exactly one build and N-1 hits regardless of scheduling — which
/// keeps the hit/build counters deterministic for any fleet worker
/// count.
pub fn shared_artifacts(
    task: TaskKind,
    seed: u64,
    n_train: usize,
    n_eval: usize,
    bpe_vocab: usize,
) -> Arc<SessionArtifacts> {
    let key = ArtifactKey { task, seed, n_train, n_eval, bpe_vocab };
    let cache = ARTIFACT_CACHE.get_or_init(Default::default);
    let (cell, existing) = {
        let mut cache = cache.lock().unwrap();
        match cache.map.get(&key) {
            Some(c) => (c.clone(), true),
            None => {
                while cache.map.len() >= ARTIFACT_CACHE_CAP {
                    // evict the oldest key; in-flight holders keep
                    // their Arc cells alive independently
                    match cache.order.pop_front() {
                        Some(old) => {
                            cache.map.remove(&old);
                        }
                        None => break,
                    }
                }
                let c: ArtifactCell = Arc::new(OnceLock::new());
                cache.map.insert(key.clone(), c.clone());
                cache.order.push_back(key);
                (c, false)
            }
        }
    };
    if existing {
        ARTIFACT_HITS.fetch_add(1, Ordering::Relaxed);
    }
    cell.get_or_init(|| {
        let data = TaskData::generate(task, seed, n_train, n_eval);
        let mut corpus_texts =
            corpus::tokenizer_corpus(seed ^ 0xC0, 1024);
        corpus_texts.extend(data.train_texts());
        let bpe = Bpe::train(&corpus_texts, bpe_vocab);
        ARTIFACT_BUILDS.fetch_add(1, Ordering::Relaxed);
        Arc::new(SessionArtifacts { data, bpe })
    })
    .clone()
}

/// Process-lifetime `(hits, builds)` counters for the shared-artifact
/// cache.  Fleet telemetry reports the delta across its run; note the
/// counters are process-global, so two fleets running concurrently in
/// ONE process fold each other's session builds into their deltas
/// (the shipped CLI runs one fleet per process, where the delta is
/// exact and worker-count-deterministic).
pub fn artifact_cache_stats() -> (u64, u64) {
    (
        ARTIFACT_HITS.load(Ordering::Relaxed),
        ARTIFACT_BUILDS.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_key_sessions_share_one_build() {
        // unique key for this test so parallel tests can't pollute it
        let seed = 0xA57F_0001;
        let (h0, b0) = artifact_cache_stats();
        let a = shared_artifacts(TaskKind::Sst2, seed, 64, 16, 300);
        let b = shared_artifacts(TaskKind::Sst2, seed, 64, 16, 300);
        assert!(Arc::ptr_eq(&a, &b), "second request must share");
        let (h1, b1) = artifact_cache_stats();
        assert!(h1 >= h0 + 1, "at least our one hit");
        assert!(b1 >= b0 + 1, "at least our one build");
        // a different seed is a different artifact set
        let c = shared_artifacts(TaskKind::Sst2, seed + 1, 64, 16, 300);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(a.data.train.len(), 64);
    }
}
