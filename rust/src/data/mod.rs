//! On-device data pipeline: tokenizer + synthetic personal-data tasks.
//!
//! The paper fine-tunes on SST-2 and SuperGLUE.  Those corpora (and the
//! user's real typing data the paper motivates with) are not available
//! here, so this module builds the closest synthetic equivalents that
//! exercise the same code path: template-grammar generators with enough
//! lexical signal to *learn from* ([`task`]), a from-scratch byte-pair
//! tokenizer trained on the generated corpus ([`bpe`]), and a padding /
//! shuffling batcher that emits exactly the `[B, S]` i32/f32 tensors the
//! AOT artifacts expect ([`batcher`]).
//!
//! Everything is deterministic under a seed: a fine-tuning session is
//! fully reproducible from `(task, seed)`.

pub mod batcher;
pub mod bpe;
pub mod corpus;
pub mod task;
