//! Fine-tuning tasks: dataset objects over the synthetic generators.
//!
//! [`TaskKind`] enumerates the paper's workloads: SST-2 (the RoBERTa-large
//! experiment), two SuperGLUE-style tasks (the OPT experiments), and the
//! personal-chat LM corpus the introduction motivates.  A [`TaskData`]
//! is a fully materialized train/eval split, deterministic in the seed.

use super::corpus::{self, Sample};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Sentence classification, 2 classes (positive/negative).
    Sst2,
    /// Yes/no question answering over a passage (SuperGLUE BoolQ style).
    BoolQ,
    /// Textual entailment (SuperGLUE RTE style).
    Rte,
    /// Causal-LM on the user's message history (personalization).
    ChatLm,
}

impl TaskKind {
    pub fn parse(s: &str) -> Option<TaskKind> {
        match s {
            "sst2" => Some(TaskKind::Sst2),
            "boolq" => Some(TaskKind::BoolQ),
            "rte" => Some(TaskKind::Rte),
            "chatlm" | "chat" => Some(TaskKind::ChatLm),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::Sst2 => "sst2",
            TaskKind::BoolQ => "boolq",
            TaskKind::Rte => "rte",
            TaskKind::ChatLm => "chatlm",
        }
    }

    /// Classification tasks have labels; LM tasks self-supervise.
    pub fn is_classification(&self) -> bool {
        !matches!(self, TaskKind::ChatLm)
    }

    pub fn generate(&self, rng: &mut Rng) -> Sample {
        match self {
            TaskKind::Sst2 => corpus::sentiment_sample(rng),
            TaskKind::BoolQ => corpus::boolq_sample(rng),
            TaskKind::Rte => corpus::rte_sample(rng),
            TaskKind::ChatLm => corpus::chat_sample(rng),
        }
    }
}

/// A materialized dataset split.
#[derive(Debug, Clone)]
pub struct TaskData {
    pub kind: TaskKind,
    pub train: Vec<Sample>,
    pub eval: Vec<Sample>,
}

impl TaskData {
    /// Generate `n_train` + `n_eval` samples deterministically.
    pub fn generate(kind: TaskKind, seed: u64, n_train: usize,
                    n_eval: usize) -> TaskData {
        let mut rng = Rng::new(seed);
        let train = (0..n_train).map(|_| kind.generate(&mut rng)).collect();
        let eval = (0..n_eval).map(|_| kind.generate(&mut rng)).collect();
        TaskData { kind, train, eval }
    }

    /// The raw text of the training split (for tokenizer training).
    pub fn train_texts(&self) -> Vec<String> {
        self.train.iter().map(|s| s.text.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [TaskKind::Sst2, TaskKind::BoolQ, TaskKind::Rte,
                  TaskKind::ChatLm] {
            assert_eq!(TaskKind::parse(k.label()), Some(k));
        }
        assert_eq!(TaskKind::parse("nope"), None);
    }

    #[test]
    fn generate_sizes_and_determinism() {
        let a = TaskData::generate(TaskKind::Sst2, 7, 100, 20);
        assert_eq!(a.train.len(), 100);
        assert_eq!(a.eval.len(), 20);
        let b = TaskData::generate(TaskKind::Sst2, 7, 100, 20);
        assert_eq!(a.train, b.train);
        // train and eval are disjoint draws (overwhelmingly different)
        assert_ne!(a.train[..20], a.eval[..]);
    }

    #[test]
    fn lm_task_has_no_labels() {
        let d = TaskData::generate(TaskKind::ChatLm, 1, 10, 2);
        assert!(d.train.iter().all(|s| s.label == -1));
        assert!(!TaskKind::ChatLm.is_classification());
    }
}
