//! Template-grammar text generation: the synthetic stand-in for the
//! personal data the paper motivates ("the wealth of valuable, non-public
//! data generated daily" on phones) and for SST-2/SuperGLUE text.
//!
//! The generators produce sentences with *learnable* structure — polarity
//! is carried by lexical choice, entailment by substring/negation
//! relations — so fine-tuning has a real signal to descend on, which is
//! all Fig. 1 requires.  Grammar quality is deliberately simple; the
//! point is a controlled, deterministic corpus, not linguistic realism.

use crate::util::rng::Rng;

pub const POSITIVE_ADJ: &[&str] = &[
    "great", "wonderful", "brilliant", "fantastic", "moving", "charming",
    "delightful", "masterful", "gripping", "superb", "touching", "fresh",
];

pub const NEGATIVE_ADJ: &[&str] = &[
    "terrible", "boring", "awful", "bland", "tedious", "clumsy",
    "forgettable", "dreadful", "lifeless", "shallow", "messy", "dull",
];

pub const SUBJECTS: &[&str] = &[
    "the movie", "the film", "this picture", "the story", "the plot",
    "the acting", "the screenplay", "the direction", "the cast",
    "the soundtrack", "the dialogue", "the pacing",
];

pub const INTENSIFIERS: &[&str] =
    &["really", "truly", "quite", "absolutely", "remarkably", "simply"];

pub const FACT_SUBJECTS: &[&str] = &[
    "the river", "the mountain", "the library", "the museum", "the bridge",
    "the market", "the garden", "the station", "the harbor", "the tower",
];

pub const FACT_PREDICATES: &[&str] = &[
    "is open on sundays", "was built in the last century",
    "is close to the city center", "is longer than ten kilometers",
    "attracts many visitors", "was renovated recently",
    "is free to enter", "is closed in winter",
];

/// Personal-messaging vocabulary for the LM personalization scenario.
pub const CHAT_OPENERS: &[&str] = &[
    "hey are we still on for", "running late for", "dont forget",
    "can you pick up", "see you at", "just finished", "on my way to",
    "what time is", "lets reschedule", "thanks again for",
];

pub const CHAT_TOPICS: &[&str] = &[
    "dinner tonight", "the gym session", "the team meeting",
    "the groceries", "the airport run", "the weekend trip",
    "the project review", "the birthday party", "coffee tomorrow",
    "the dentist appointment",
];

/// A generated labelled sentence.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub text: String,
    pub label: i32,
}

/// SST-2-style sentiment sentence with its polarity label (1 = positive).
pub fn sentiment_sample(rng: &mut Rng) -> Sample {
    let positive = rng.chance(0.5);
    let adj_pool = if positive { POSITIVE_ADJ } else { NEGATIVE_ADJ };
    let subj = rng.choose(SUBJECTS);
    let adj = rng.choose(adj_pool);
    let text = match rng.below(4) {
        0 => format!("{subj} was {adj}"),
        1 => {
            let int = rng.choose(INTENSIFIERS);
            format!("{subj} was {int} {adj}")
        }
        2 => {
            let adj2 = rng.choose(adj_pool);
            format!("{subj} was {adj} and {adj2}")
        }
        _ => {
            let subj2 = rng.choose(SUBJECTS);
            let adj2 = rng.choose(adj_pool);
            format!("{subj} was {adj} but {subj2} was {adj2}")
        }
    };
    Sample { text, label: positive as i32 }
}

/// BoolQ-style (passage, question) pair; label 1 = yes.
/// The question restates or negates the passage predicate.
pub fn boolq_sample(rng: &mut Rng) -> Sample {
    let subj = rng.choose(FACT_SUBJECTS);
    let pred = rng.choose(FACT_PREDICATES);
    let answer_yes = rng.chance(0.5);
    let q_pred = if answer_yes {
        pred.to_string()
    } else {
        // ask about a different predicate -> "no"
        loop {
            let other = rng.choose(FACT_PREDICATES);
            if other != pred {
                break other.to_string();
            }
        }
    };
    let text = format!("passage : {subj} {pred} . question : {subj} {q_pred} ?");
    Sample { text, label: answer_yes as i32 }
}

/// RTE-style premise/hypothesis pair; label 1 = entailment.
/// Entailed hypotheses drop a conjunct; contradictions negate.
pub fn rte_sample(rng: &mut Rng) -> Sample {
    let subj = rng.choose(SUBJECTS);
    let (a, b) = (rng.choose(POSITIVE_ADJ), rng.choose(POSITIVE_ADJ));
    let entailed = rng.chance(0.5);
    let hypothesis = if entailed {
        format!("{subj} was {a}")
    } else {
        let neg = rng.choose(NEGATIVE_ADJ);
        format!("{subj} was {neg}")
    };
    let text =
        format!("premise : {subj} was {a} and {b} . hypothesis : {hypothesis}");
    Sample { text, label: entailed as i32 }
}

/// One line of a user's synthetic message history (for the causal-LM
/// personalization task).  Labels are unused (-1).
pub fn chat_sample(rng: &mut Rng) -> Sample {
    let opener = rng.choose(CHAT_OPENERS);
    let topic = rng.choose(CHAT_TOPICS);
    let text = if rng.chance(0.3) {
        let topic2 = rng.choose(CHAT_TOPICS);
        format!("{opener} {topic} and {topic2}")
    } else {
        format!("{opener} {topic}")
    };
    Sample { text, label: -1 }
}

/// Build a raw text corpus for tokenizer training: a mix of all
/// generators so the vocabulary covers every task.
pub fn tokenizer_corpus(seed: u64, lines: usize) -> Vec<String> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(lines);
    for i in 0..lines {
        let s = match i % 4 {
            0 => sentiment_sample(&mut rng).text,
            1 => boolq_sample(&mut rng).text,
            2 => rte_sample(&mut rng).text,
            _ => chat_sample(&mut rng).text,
        };
        out.push(s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentiment_labels_match_lexicon() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = sentiment_sample(&mut rng);
            let has_pos = POSITIVE_ADJ.iter().any(|a| s.text.contains(a));
            let has_neg = NEGATIVE_ADJ.iter().any(|a| s.text.contains(a));
            if s.label == 1 {
                assert!(has_pos && !has_neg, "{:?}", s);
            } else {
                assert!(has_neg && !has_pos, "{:?}", s);
            }
        }
    }

    #[test]
    fn boolq_yes_iff_predicate_repeated() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let s = boolq_sample(&mut rng);
            let parts: Vec<&str> = s.text.split(" . question : ").collect();
            assert_eq!(parts.len(), 2);
            let passage_pred = parts[0]
                .trim_start_matches("passage : ")
                .to_string();
            let repeated = parts[1].trim_end_matches(" ?")
                .ends_with(passage_pred.split_once(' ').unwrap().1);
            assert_eq!(repeated, s.label == 1, "{:?}", s);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let a = tokenizer_corpus(9, 50);
        let b = tokenizer_corpus(9, 50);
        assert_eq!(a, b);
        let c = tokenizer_corpus(10, 50);
        assert_ne!(a, c);
    }

    #[test]
    fn labels_roughly_balanced() {
        let mut rng = Rng::new(3);
        let pos: i32 = (0..1000).map(|_| sentiment_sample(&mut rng).label).sum();
        assert!((350..650).contains(&pos), "{pos}");
    }
}
