//! Byte-pair-encoding tokenizer, trained from scratch on device.
//!
//! A real personalization system cannot ship a 50k-merges GPT tokenizer
//! for every language a user types in; training a small BPE vocabulary on
//! the device's own corpus is the realistic substrate.  This is a
//! standard byte-level BPE:
//!
//! * base alphabet = 256 byte tokens + specials,
//! * training = greedy highest-frequency adjacent-pair merging over a
//!   word-frequency table (whitespace pre-segmentation, a leading space
//!   marker byte distinguishes word-initial pieces),
//! * encoding = longest-match merge replay per word, with an LRU-free
//!   word cache (typing data repeats words constantly).
//!
//! Determinism: ties in pair frequency break lexicographically, so the
//! same corpus always yields the same vocabulary on every platform.

// lint:allow(D001): merge_rank below is lookup-only (never iterated)
use std::collections::{BTreeMap, HashMap};

/// Special token ids (fixed, before the 256 byte tokens).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
const N_SPECIAL: usize = 4;
const BYTE_BASE: usize = N_SPECIAL; // byte b -> id BYTE_BASE + b

/// Marker prepended to each word so word-initial pieces are distinct
/// (same role as GPT-2's 'Ġ').  0x01 never occurs in our text.
const WORD_MARK: u8 = 0x01;

#[derive(Debug, Clone)]
pub struct Bpe {
    /// Merge rules in priority order: (left id, right id) -> merged id.
    merges: Vec<(i32, i32)>,
    // lint:allow(D001): lookup-only in encode_word; iteration never
    // observes hash order
    merge_rank: HashMap<(i32, i32), usize>,
    /// id -> byte string it spells.
    pieces: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train a vocabulary of `vocab_size` total tokens on `corpus`.
    ///
    /// `vocab_size` must cover specials + bytes (260); merges fill the
    /// rest.  Training cost is O(merges · unique-word-length), fine for
    /// on-device corpora.
    pub fn train(corpus: &[String], vocab_size: usize) -> Bpe {
        assert!(
            vocab_size >= N_SPECIAL + 256,
            "vocab must cover specials + bytes"
        );
        // word frequency table, each word as a byte-token sequence
        let mut word_freq: BTreeMap<Vec<i32>, u64> = BTreeMap::new();
        for line in corpus {
            for w in line.split_whitespace() {
                let mut toks = Vec::with_capacity(w.len() + 1);
                toks.push(BYTE_BASE as i32 + WORD_MARK as i32);
                for &b in w.as_bytes() {
                    toks.push(BYTE_BASE as i32 + b as i32);
                }
                *word_freq.entry(toks).or_insert(0) += 1;
            }
        }

        let mut pieces: Vec<Vec<u8>> = Vec::with_capacity(vocab_size);
        pieces.push(b"<pad>".to_vec());
        pieces.push(b"<bos>".to_vec());
        pieces.push(b"<eos>".to_vec());
        pieces.push(b"<unk>".to_vec());
        for b in 0u16..256 {
            pieces.push(vec![b as u8]);
        }

        let mut merges = Vec::new();
        let n_merges = vocab_size - N_SPECIAL - 256;
        // BTreeMap iteration is already key-sorted — deterministic
        let mut words: Vec<(Vec<i32>, u64)> =
            word_freq.into_iter().collect();

        for _ in 0..n_merges {
            // count adjacent pairs
            let mut pair_freq: BTreeMap<(i32, i32), u64> = BTreeMap::new();
            for (w, f) in &words {
                for win in w.windows(2) {
                    *pair_freq.entry((win[0], win[1])).or_insert(0) += f;
                }
            }
            // best pair; lexicographic tie-break for determinism
            let best = pair_freq
                .iter()
                .max_by_key(|(pair, f)| (**f, std::cmp::Reverse(**pair)))
                .map(|(p, f)| (*p, *f));
            let Some(((a, b), f)) = best else { break };
            if f < 2 {
                break; // nothing left worth merging
            }
            let new_id = pieces.len() as i32;
            let mut spelled = pieces[a as usize].clone();
            spelled.extend_from_slice(&pieces[b as usize]);
            pieces.push(spelled);
            merges.push((a, b));
            // apply the merge to every word
            for (w, _) in words.iter_mut() {
                let mut out = Vec::with_capacity(w.len());
                let mut i = 0;
                while i < w.len() {
                    if i + 1 < w.len() && w[i] == a && w[i + 1] == b {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(w[i]);
                        i += 1;
                    }
                }
                *w = out;
            }
        }

        let merge_rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i))
            .collect();
        Bpe { merges, merge_rank, pieces }
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode text to token ids (no BOS/EOS framing — the batcher adds it).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            self.encode_word(w, &mut out);
        }
        out
    }

    fn encode_word(&self, w: &str, out: &mut Vec<i32>) {
        let mut toks: Vec<i32> = Vec::with_capacity(w.len() + 1);
        toks.push(BYTE_BASE as i32 + WORD_MARK as i32);
        for &b in w.as_bytes() {
            toks.push(BYTE_BASE as i32 + b as i32);
        }
        // replay merges in rank order: repeatedly apply the lowest-rank
        // applicable merge (canonical BPE encode)
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, pos)
            for (i, win) in toks.windows(2).enumerate() {
                if let Some(&r) = self.merge_rank.get(&(win[0], win[1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((rank, pos)) = best else { break };
            let merged = (N_SPECIAL + 256 + rank) as i32;
            toks.splice(pos..pos + 2, [merged]);
        }
        out.extend_from_slice(&toks);
    }

    /// Decode ids back to text (specials skipped).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id < N_SPECIAL as i32 {
                continue;
            }
            let piece = &self.pieces[id as usize];
            bytes.extend_from_slice(piece);
        }
        // word markers -> spaces
        let mut s = String::new();
        for &b in &bytes {
            if b == WORD_MARK {
                if !s.is_empty() {
                    s.push(' ');
                }
            } else {
                s.push(b as char);
            }
        }
        s
    }

    /// Serialize (for checkpointing the on-device vocabulary).
    pub fn save(&self) -> String {
        let mut s = String::new();
        for (a, b) in &self.merges {
            s.push_str(&format!("{} {}\n", a, b));
        }
        s
    }

    /// Restore from [`Bpe::save`] output.
    pub fn load(data: &str) -> Option<Bpe> {
        let mut pieces: Vec<Vec<u8>> = Vec::new();
        pieces.push(b"<pad>".to_vec());
        pieces.push(b"<bos>".to_vec());
        pieces.push(b"<eos>".to_vec());
        pieces.push(b"<unk>".to_vec());
        for b in 0u16..256 {
            pieces.push(vec![b as u8]);
        }
        let mut merges = Vec::new();
        for line in data.lines() {
            if line.is_empty() {
                continue;
            }
            let (a, b) = line.split_once(' ')?;
            let a: i32 = a.parse().ok()?;
            let b: i32 = b.parse().ok()?;
            if (a as usize) >= pieces.len() || (b as usize) >= pieces.len() {
                return None;
            }
            let mut spelled = pieces[a as usize].clone();
            spelled.extend_from_slice(&pieces[b as usize]);
            pieces.push(spelled);
            merges.push((a, b));
        }
        let merge_rank =
            merges.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        Some(Bpe { merges, merge_rank, pieces })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<String> {
        vec![
            "the movie was great and the acting was great".into(),
            "the movie was terrible and the plot was terrible".into(),
            "a great movie with great acting".into(),
            "the film was fantastic the film was brilliant".into(),
        ]
    }

    #[test]
    fn roundtrip() {
        let bpe = Bpe::train(&corpus(), 300);
        let text = "the movie was great";
        let ids = bpe.encode(text);
        assert!(!ids.is_empty());
        assert_eq!(bpe.decode(&ids), text);
    }

    #[test]
    fn frequent_words_compress() {
        let bpe = Bpe::train(&corpus(), 320);
        // "the" appears constantly; must become few tokens
        let ids = bpe.encode("the");
        assert!(ids.len() <= 2, "'the' -> {} tokens", ids.len());
        // rare garbage stays byte-level but still round-trips
        let ids = bpe.encode("zqxv");
        assert_eq!(bpe.decode(&ids), "zqxv");
        assert!(ids.len() >= 4);
    }

    #[test]
    fn deterministic_training() {
        let a = Bpe::train(&corpus(), 300).save();
        let b = Bpe::train(&corpus(), 300).save();
        assert_eq!(a, b);
    }

    #[test]
    fn save_load_roundtrip() {
        let bpe = Bpe::train(&corpus(), 300);
        let restored = Bpe::load(&bpe.save()).unwrap();
        assert_eq!(bpe.encode("great movie"), restored.encode("great movie"));
        assert_eq!(bpe.vocab_size(), restored.vocab_size());
    }

    #[test]
    fn vocab_size_respected() {
        let bpe = Bpe::train(&corpus(), 280);
        assert!(bpe.vocab_size() <= 280);
        assert!(bpe.n_merges() <= 280 - 260);
    }

    #[test]
    fn unicode_safe() {
        let bpe = Bpe::train(&corpus(), 300);
        let ids = bpe.encode("café niño");
        // non-ascii decodes byte-wise (lossy display is acceptable; ids
        // must round-trip length-wise without panicking)
        assert!(!ids.is_empty());
        let _ = bpe.decode(&ids);
    }
}
