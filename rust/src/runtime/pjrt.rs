//! PJRT/XLA execution backend (`--features pjrt`) — the original seed
//! path: compile the AOT HLO-text artifacts through the `xla` crate's
//! PJRT CPU client and execute them on device buffers.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that the crate's xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! This backend implements only the literal `run()` convention; the
//! session's `run_in_place` calls reach it through the trait's default
//! bridge (materialize donated literals → run → scatter outputs), so
//! trajectories stay identical to the native donation path at the cost
//! of the copies.  True XLA input/output aliasing is a ROADMAP item.
//!
//! Building this module requires adding the `xla` crate to
//! `rust/Cargo.toml` (see the comment there) — it binds a local XLA
//! install, which the default native backend deliberately avoids.

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{Backend, Executable};
use super::literal::Literal;
use super::manifest::{Dtype, Manifest, ProgramSpec};

/// The PJRT client, bound to the host CPU platform.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn Executable>> {
        if manifest.builtin {
            bail!("the builtin manifest has no HLO artifacts; run `make \
                   artifacts` and load artifacts/manifest.json for PJRT");
        }
        let path = manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.file))?;
        Ok(Box::new(PjrtProgram { spec: spec.clone(), exe }))
    }
}

struct PjrtProgram {
    spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: `v` is a live, initialized slice; `T: Copy` guarantees
    // plain-old data with no drop glue, every byte of which is valid
    // to read as u8, and size_of_val gives exactly its byte length.
    // The borrow ties the returned lifetime to `v`.
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

/// Host [`Literal`] -> `xla::Literal`.
fn to_xla(l: &Literal) -> Result<xla::Literal> {
    let (ty, bytes) = match l.dtype() {
        Dtype::F32 => (xla::ElementType::F32, bytes_of(l.f32_slice()?)),
        Dtype::I32 => (xla::ElementType::S32, bytes_of(l.i32_slice()?)),
        Dtype::U32 => (xla::ElementType::U32, bytes_of(l.u32_slice()?)),
        // reduced-precision storage never crosses the PJRT boundary:
        // ExecState dequantizes to f32 in donated_literals()
        Dtype::F16 | Dtype::I8 => bail!(
            "pjrt backend takes f32 calling-convention literals; \
             dequantize {:?} storage first",
            l.dtype()
        ),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, l.shape(), bytes)
        .map_err(|e| anyhow!("building xla literal: {e:?}"))
}

/// `xla::Literal` -> host [`Literal`], typed/shaped per the manifest.
fn from_xla(l: &xla::Literal, want: &super::manifest::TensorSpec)
    -> Result<Literal>
{
    let shape = want.shape.clone();
    match want.dtype {
        Dtype::F32 => Literal::from_f32(
            l.to_vec::<f32>()
                .map_err(|e| anyhow!("literal->f32: {e:?}"))?,
            shape,
        ),
        Dtype::I32 => Literal::from_i32(
            l.to_vec::<i32>()
                .map_err(|e| anyhow!("literal->i32: {e:?}"))?,
            shape,
        ),
        Dtype::U32 => Literal::from_u32(
            l.to_vec::<u32>()
                .map_err(|e| anyhow!("literal->u32: {e:?}"))?,
            shape,
        ),
        Dtype::F16 | Dtype::I8 => bail!(
            "manifest outputs are f32 calling-convention tensors; \
             got storage dtype {:?}",
            want.dtype
        ),
    }
}

impl Executable for PjrtProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let xla_inputs: Vec<xla::Literal> =
            inputs.iter().map(|l| to_xla(l)).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = xla_inputs.iter().collect();
        let result = self
            .exe
            .execute::<&xla::Literal>(&refs)
            .with_context(|| format!("executing {}", self.spec.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        let outs = tuple.to_tuple().context("decomposing output tuple")?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "program {} returned {} outputs, manifest says {}",
                self.spec.file,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(l, want)| from_xla(l, want))
            .collect()
    }
}
