//! The execution-backend abstraction.
//!
//! A [`Backend`] turns a manifest [`ProgramSpec`] into an [`Executable`]
//! — the step-program unit the session hot loop calls.  Two
//! implementations exist:
//!
//! * [`native`](super::native) — a pure-Rust interpreter of the step
//!   program semantics (tiny-transformer forward, softmax-xent loss,
//!   counter-RNG SPSA perturbation, Adam update).  Default; hermetic;
//!   needs no artifacts beyond the manifest.
//! * [`pjrt`](super::pjrt) (`--features pjrt`) — compiles the AOT HLO
//!   text through the `xla` crate's PJRT CPU client, the original
//!   seed-repo path.
//!
//! Everything above this trait (optimizers, tuner, coordinator, benches)
//! is backend-agnostic: it sees only [`Literal`]s and `ProgramSpec`s.

use anyhow::Result;

use super::literal::Literal;
use super::manifest::{Manifest, ProgramSpec};

/// A compiled, ready-to-run step program (one (config, kind, batch)).
pub trait Executable: Send + Sync {
    /// Execute with host literals.  Input order follows `spec.inputs`;
    /// the output vector follows `spec.outputs`.  Arity is checked by
    /// the [`Program`](super::Program) wrapper, not here.
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>>;
}

/// An execution engine bound to one artifact directory / manifest.
pub trait Backend: Send + Sync {
    /// Human-readable platform tag (e.g. `cpu-native`, `cpu` for PJRT).
    fn platform(&self) -> String;

    /// Compile one step program.  Called once per (config, kind, batch);
    /// the [`Runtime`](super::Runtime) caches the result.
    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn Executable>>;
}
