//! The execution-backend abstraction.
//!
//! A [`Backend`] turns a manifest [`ProgramSpec`] into an [`Executable`]
//! — the step-program unit the session hot loop calls.  Two
//! implementations exist:
//!
//! * [`native`](super::native) — a pure-Rust interpreter of the step
//!   program semantics (tiny-transformer forward, softmax-xent loss,
//!   counter-RNG SPSA perturbation, Adam update).  Default; hermetic;
//!   needs no artifacts beyond the manifest.
//! * [`pjrt`](super::pjrt) (`--features pjrt`) — compiles the AOT HLO
//!   text through the `xla` crate's PJRT CPU client, the original
//!   seed-repo path.
//!
//! Executables expose two calling conventions:
//!
//! * [`Executable::run`] — pure literal-in/literal-out; every parameter
//!   crosses the boundary as a fresh host tensor both ways.
//! * [`Executable::run_in_place`] — XLA-style input/output aliasing
//!   (buffer donation): the parameter and optimizer-moment tensors live
//!   in a caller-owned [`ExecState`] that the program mutates directly,
//!   and only the non-donated inputs (batch tensors + scalars) are
//!   passed as literals.  The default implementation bridges onto
//!   `run()` (clone in, scatter out), so literal-only backends like
//!   PJRT keep working unchanged; the native backend overrides it with
//!   a true zero-copy path.
//!
//! Everything above this trait (optimizers, tuner, coordinator, benches)
//! is backend-agnostic: it sees only [`Literal`]s and `ProgramSpec`s.

use anyhow::{Context, Result};

use super::literal::Literal;
use super::manifest::{Manifest, ProgramSpec};
use super::state::ExecState;

/// A compiled, ready-to-run step program (one (config, kind, batch)).
pub trait Executable: Send + Sync {
    /// Execute with host literals.  Input order follows `spec.inputs`;
    /// the output vector follows `spec.outputs`.  Arity is checked by
    /// the [`Program`](super::Program) wrapper, not here.
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>>;

    /// Execute with donated state: the tensors in `state` (params, then
    /// Adam m/v when present) stand in for the leading `spec.inputs`
    /// and are updated in place; `inputs` carries only the remaining
    /// (batch + scalar) literals, in spec order.  Returns the step's
    /// scalar loss — only programs whose final output is that scalar
    /// support this path.
    ///
    /// Aliasing contract: during the call the donated tensors belong to
    /// the program (the caller must not read them); after it returns
    /// they hold the post-step values.  The default implementation
    /// routes through [`run`](Executable::run) — materialize donated
    /// literals, execute, scatter the outputs back — which preserves
    /// exact step semantics at the cost of the copies; backends
    /// override it to make those copies disappear.
    fn run_in_place(
        &self,
        state: &mut ExecState,
        inputs: &[&Literal],
    ) -> Result<f32> {
        bridge_via_run(&mut |full| self.run(full), state, inputs)
    }
}

/// The literal-path bridge behind the default
/// [`Executable::run_in_place`]: materialize the donated tensors, run
/// the literal convention, pop the loss, scatter the remaining outputs
/// back into the state.  `Program::execute_in_place_via_run` calls this
/// same body, so the compat path and the default impl can never
/// diverge.
pub fn bridge_via_run(
    run: &mut dyn FnMut(&[&Literal]) -> Result<Vec<Literal>>,
    state: &mut ExecState,
    inputs: &[&Literal],
) -> Result<f32> {
    let donated = state.donated_literals()?;
    let mut full: Vec<&Literal> =
        Vec::with_capacity(donated.len() + inputs.len());
    full.extend(donated.iter());
    full.extend(inputs.iter().copied());
    let mut outs = run(&full)?;
    let loss = outs
        .pop()
        .context("step program returned no outputs")?
        .f32_scalar()?;
    if !outs.is_empty() {
        state.absorb(outs)?;
    }
    Ok(loss)
}

/// An execution engine bound to one artifact directory / manifest.
pub trait Backend: Send + Sync {
    /// Human-readable platform tag (e.g. `cpu-native`, `cpu` for PJRT).
    fn platform(&self) -> String;

    /// Compile one step program.  Called once per (config, kind, batch);
    /// the [`Runtime`](super::Runtime) caches the result.
    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn Executable>>;
}
