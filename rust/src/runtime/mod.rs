//! PJRT execution runtime: loads the AOT artifacts and runs them.
//!
//! This is the only module that touches the `xla` crate.  Flow:
//!
//! ```text
//!   manifest.json ──> Manifest (calling convention: configs, programs)
//!   *.hlo.txt     ──> HloModuleProto::from_text_file ──> compile (once)
//!   step loop     ──> Program::execute(&[&Literal]) ──> output literals
//! ```
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that the crate's xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! Compiled executables are cached per (config, kind, batch), so the
//! session hot loop pays compilation exactly once.

pub mod literal;
pub mod manifest;
pub mod state;

pub use literal::{f32_1, i32_tensor, f32_tensor, u32_1, LiteralExt};
pub use manifest::{ConfigInfo, Dtype, Manifest, ParamSpecInfo, ProgramSpec,
                   TensorSpec};
pub use state::ModelState;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

/// A compiled, ready-to-execute step program.
pub struct Program {
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Program {
    /// Execute with host literals; returns the decomposed output tuple.
    ///
    /// Input count/order must follow `spec.inputs` (checked).  Output is
    /// the artifact's tuple flattened to a `Vec<Literal>` following
    /// `spec.outputs`.
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {}/{} expects {} inputs, got {}",
                self.spec.config,
                self.spec.kind,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.spec.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        let outs = tuple.to_tuple().context("decomposing output tuple")?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "program {} returned {} outputs, manifest says {}",
                self.spec.file,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// The PJRT client + program cache, bound to one artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<(String, String, usize), std::sync::Arc<Program>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over a loaded manifest.
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling + caching on first use) a step program.
    pub fn program(
        &self,
        config: &str,
        kind: &str,
        batch: usize,
    ) -> Result<std::sync::Arc<Program>> {
        let key = (config.to_string(), kind.to_string(), batch);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(&key) {
                return Ok(p.clone());
            }
        }
        let spec = self
            .manifest
            .find_program(config, kind, batch)
            .ok_or_else(|| {
                anyhow!("no artifact for ({config}, {kind}, bs={batch}); \
                         run `make artifacts`")
            })?
            .clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.file))?;
        let program = std::sync::Arc::new(Program { spec, exe });
        self.cache.lock().unwrap().insert(key, program.clone());
        Ok(program)
    }

    /// Number of programs compiled so far (telemetry / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
