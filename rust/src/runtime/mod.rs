//! Execution runtime: loads a manifest and runs step programs through a
//! pluggable [`Backend`].
//!
//! ```text
//!   manifest.json / Manifest::builtin ──> calling convention
//!   Backend::compile(spec)             ──> Executable   (cached once)
//!   step loop ──> Program::execute(&[&Literal]) ──> output literals
//! ```
//!
//! Backends:
//! * **native** (default) — pure-Rust interpreter of the step-program
//!   semantics; hermetic, no XLA, no artifacts required.
//! * **pjrt** (`--features pjrt`) — compiles the AOT HLO text through
//!   the `xla` crate's PJRT CPU client (the original seed-repo path).
//!
//! Compiled executables are cached per (config, kind, batch), so the
//! session hot loop pays compilation exactly once.

pub mod backend;
pub mod literal;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod precision;
pub mod state;

pub use backend::{Backend, Executable};
pub use literal::{f32_1, f32_tensor, i32_tensor, u32_1, Literal};
pub use manifest::{ConfigInfo, Dtype, Manifest, ParamSpecInfo, ProgramSpec,
                   TensorSpec};
pub use precision::Precision;
pub use state::{ExecState, ModelState};

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

/// A compiled, ready-to-execute step program.
pub struct Program {
    pub spec: ProgramSpec,
    exe: Box<dyn Executable>,
}

impl Program {
    /// Execute with host literals; returns the output tuple.
    ///
    /// Input count/order must follow `spec.inputs` (checked).  Output
    /// follows `spec.outputs` (checked).
    pub fn execute(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {}/{} expects {} inputs, got {}",
                self.spec.config,
                self.spec.kind,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let outs = self.exe.run(inputs)?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "program {} returned {} outputs, manifest says {}",
                self.spec.file,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Arity/shape gate shared by the two in-place entry points: the
    /// donated tensors in `state` plus `inputs` must cover exactly
    /// `spec.inputs`, and the program's final output must be the scalar
    /// loss (so `run_in_place` has something to return).
    fn check_in_place(
        &self,
        state: &ExecState,
        inputs: &[&Literal],
    ) -> Result<()> {
        if state.tensor_count() + inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {}/{} expects {} inputs, got {} donated + {} \
                 literals",
                self.spec.config,
                self.spec.kind,
                self.spec.inputs.len(),
                state.tensor_count(),
                inputs.len()
            );
        }
        let last = self
            .spec
            .outputs
            .last()
            .ok_or_else(|| anyhow!("program {} has no outputs",
                                   self.spec.file))?;
        if last.elements() != 1 {
            bail!(
                "program {}/{} has no scalar loss output; use execute()",
                self.spec.config,
                self.spec.kind
            );
        }
        Ok(())
    }

    /// Execute through the buffer-donation path: `state` holds the
    /// donated parameter (and Adam m/v) tensors, mutated in place;
    /// `inputs` holds the remaining batch/scalar literals in spec
    /// order.  Returns the step's scalar loss.
    pub fn execute_in_place(
        &self,
        state: &mut ExecState,
        inputs: &[&Literal],
    ) -> Result<f32> {
        self.check_in_place(state, inputs)?;
        self.exe.run_in_place(state, inputs)
    }

    /// Same contract as [`execute_in_place`](Program::execute_in_place)
    /// but forced through the literal `run()` path (materialize donated
    /// literals, execute, scatter outputs back).  This is the
    /// every-backend fallback made callable directly so tests and
    /// benches can pin that the two paths produce bit-identical
    /// trajectories — and measure exactly what the donation path saves.
    pub fn execute_in_place_via_run(
        &self,
        state: &mut ExecState,
        inputs: &[&Literal],
    ) -> Result<f32> {
        self.check_in_place(state, inputs)?;
        backend::bridge_via_run(&mut |full| self.exe.run(full), state,
                                inputs)
    }
}

/// The backend + program cache, bound to one manifest.
pub struct Runtime {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
    // BTreeMap, not HashMap: `compiled_count` and any future cache
    // walk must observe a process-independent order (D001)
    cache: Mutex<BTreeMap<(String, String, usize), std::sync::Arc<Program>>>,
}

impl Runtime {
    /// Create a runtime over the default (native) execution backend.
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        Runtime::with_backend(manifest,
                              Box::new(native::NativeBackend::new()))
    }

    /// Create a runtime over an explicit backend.
    pub fn with_backend(
        manifest: Manifest,
        backend: Box<dyn Backend>,
    ) -> Result<Runtime> {
        Ok(Runtime { backend, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Create a runtime over the PJRT/XLA backend (needs real AOT
    /// artifacts on disk; see `runtime::pjrt`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(manifest: Manifest) -> Result<Runtime> {
        let backend = pjrt::PjrtBackend::new()?;
        Runtime::with_backend(manifest, Box::new(backend))
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Get (compiling + caching on first use) a step program.
    pub fn program(
        &self,
        config: &str,
        kind: &str,
        batch: usize,
    ) -> Result<std::sync::Arc<Program>> {
        let key = (config.to_string(), kind.to_string(), batch);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(&key) {
                return Ok(p.clone());
            }
        }
        let spec = self
            .manifest
            .find_program(config, kind, batch)
            .ok_or_else(|| {
                anyhow!("no program for ({config}, {kind}, bs={batch}) in \
                         the manifest")
            })?
            .clone();
        let exe = self.backend.compile(&self.manifest, &spec)?;
        let program = std::sync::Arc::new(Program { spec, exe });
        self.cache.lock().unwrap().insert(key, program.clone());
        Ok(program)
    }

    /// Number of programs compiled so far (telemetry / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_compiles_and_caches() {
        let rt = Runtime::new(Manifest::builtin()).unwrap();
        assert_eq!(rt.platform(), "cpu-native");
        let a = rt.program("pocket-tiny", "eval", 4).unwrap();
        let n = rt.compiled_count();
        let b = rt.program("pocket-tiny", "eval", 4).unwrap();
        assert_eq!(rt.compiled_count(), n);
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert!(rt.program("pocket-tiny", "adam_step", 4).is_err());
        assert!(rt.program("pocket-tiny", "mezo_step", 999).is_err());
    }

    #[test]
    fn arity_checked_before_execution() {
        let rt = Runtime::new(Manifest::builtin()).unwrap();
        let prog = rt.program("pocket-tiny", "loss_eval", 4).unwrap();
        assert!(prog.execute(&[]).is_err());
    }
}
