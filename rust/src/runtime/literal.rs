//! Backend-owned host tensors.
//!
//! [`Literal`] is the value type that crosses the [`Backend`]
//! (crate::runtime::backend) boundary: a shape plus typed host data.
//! The step programs speak three element types (f32/i32/u32) and two
//! scalar conventions (shape-(1,) scalars for seed/lr/eps; shape-()
//! for the returned loss).  These helpers centralize that plumbing so
//! the session code stays readable and backend-agnostic — the PJRT
//! backend converts to/from `xla::Literal` internally, the native
//! backend operates on these buffers directly.

use anyhow::{bail, Result};

use super::manifest::Dtype;

/// Typed element storage of one literal.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// A host tensor: row-major data plus shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<usize>,
    data: LiteralData,
}

impl Literal {
    fn check(n: usize, shape: &[usize]) -> Result<()> {
        let want: usize = shape.iter().product();
        if want != n {
            bail!("shape {:?} vs {} values", shape, n);
        }
        Ok(())
    }

    pub fn from_f32(data: Vec<f32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::F32(data) })
    }

    pub fn from_i32(data: Vec<i32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::I32(data) })
    }

    pub fn from_u32(data: Vec<u32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::U32(data) })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            LiteralData::F32(_) => Dtype::F32,
            LiteralData::I32(_) => Dtype::I32,
            LiteralData::U32(_) => Dtype::U32,
        }
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U32(v) => v.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.element_count()
    }

    pub fn is_empty(&self) -> bool {
        self.element_count() == 0
    }

    pub fn f32_slice(&self) -> Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            _ => bail!("expected f32 literal, got {:?}", self.dtype()),
        }
    }

    pub fn i32_slice(&self) -> Result<&[i32]> {
        match &self.data {
            LiteralData::I32(v) => Ok(v),
            _ => bail!("expected i32 literal, got {:?}", self.dtype()),
        }
    }

    pub fn u32_slice(&self) -> Result<&[u32]> {
        match &self.data {
            LiteralData::U32(v) => Ok(v),
            _ => bail!("expected u32 literal, got {:?}", self.dtype()),
        }
    }

    /// All elements as f32 (errors on dtype mismatch).
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.f32_slice()?.to_vec())
    }

    /// Consume the literal, moving its f32 storage out without a copy
    /// (the `ExecState::absorb` write-back path).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        let dt = self.dtype();
        match self.data {
            LiteralData::F32(v) => Ok(v),
            _ => bail!("expected f32 literal, got {:?}", dt),
        }
    }

    /// First element as f32 (works for shape-() and shape-(1,)).
    pub fn f32_scalar(&self) -> Result<f32> {
        match self.f32_slice()?.first() {
            Some(v) => Ok(*v),
            None => bail!("empty literal has no scalar"),
        }
    }

    /// First element as u32.
    pub fn u32_scalar(&self) -> Result<u32> {
        match self.u32_slice()?.first() {
            Some(v) => Ok(*v),
            None => bail!("empty literal has no scalar"),
        }
    }

    /// Raw little-endian bytes (checkpoint format).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.element_count() * 4);
        match &self.data {
            LiteralData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::U32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }
}

/// f32 tensor literal of the given shape (row-major data).
pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<Literal> {
    Literal::from_f32(data.to_vec(), shape.to_vec())
}

/// i32 tensor literal.
pub fn i32_tensor(data: &[i32], shape: &[usize]) -> Result<Literal> {
    Literal::from_i32(data.to_vec(), shape.to_vec())
}

/// Shape-(1,) f32 scalar (the step programs' scalar convention).
pub fn f32_1(v: f32) -> Result<Literal> {
    Literal::from_f32(vec![v], vec![1])
}

/// Shape-(1,) u32 scalar (the MeZO seed).
pub fn u32_1(v: u32) -> Result<Literal> {
    Literal::from_u32(vec![v], vec![1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.f32_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.shape(), &[2, 2]);
        assert_eq!(l.dtype(), Dtype::F32);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_tensor(&[1.0], &[2]).is_err());
        assert!(i32_tensor(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalars() {
        let l = f32_1(0.5).unwrap();
        assert_eq!(l.f32_scalar().unwrap(), 0.5);
        let u = u32_1(7).unwrap();
        assert_eq!(u.u32_scalar().unwrap(), 7);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let u = u32_1(7).unwrap();
        assert!(u.f32_vec().is_err());
        let f = f32_1(1.0).unwrap();
        assert!(f.i32_slice().is_err());
    }

    #[test]
    fn le_bytes_match_format() {
        let l = f32_tensor(&[1.0, -2.0], &[2]).unwrap();
        let b = l.to_le_bytes();
        assert_eq!(b.len(), 8);
        assert_eq!(&b[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&b[4..8], &(-2.0f32).to_le_bytes());
    }
}
