//! Backend-owned host tensors.
//!
//! [`Literal`] is the value type that crosses the [`Backend`]
//! (crate::runtime::backend) boundary: a shape plus typed host data.
//! The step programs speak three element types (f32/i32/u32) and two
//! scalar conventions (shape-(1,) scalars for seed/lr/eps; shape-()
//! for the returned loss).  These helpers centralize that plumbing so
//! the session code stays readable and backend-agnostic — the PJRT
//! backend converts to/from `xla::Literal` internally, the native
//! backend operates on these buffers directly.

use anyhow::{bail, ensure, Result};

use super::manifest::Dtype;
use super::precision::{self, Precision};

/// Typed element storage of one literal.
///
/// `F32`/`I32`/`U32` are the program calling-convention types; `F16`
/// and `I8` are reduced-precision *parameter storage* (see
/// [`Precision`]) with the conversion semantics documented in
/// [`precision`](super::precision): f16 is IEEE binary16 with
/// round-to-nearest-even encode, int8 is symmetric per-tensor absmax
/// with an f32 scale.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    F16(Vec<u16>),
    I8 { data: Vec<i8>, scale: f32 },
    /// Per-channel int8: `scales.len()` rows of `data.len() /
    /// scales.len()` codes each.  The row grouping travels with the
    /// data (not the shape), so flat durable forms reshape safely.
    I8C { data: Vec<i8>, scales: Vec<f32> },
}

/// A host tensor: row-major data plus shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<usize>,
    data: LiteralData,
}

impl Literal {
    fn check(n: usize, shape: &[usize]) -> Result<()> {
        let want: usize = shape.iter().product();
        if want != n {
            bail!("shape {:?} vs {} values", shape, n);
        }
        Ok(())
    }

    pub fn from_f32(data: Vec<f32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::F32(data) })
    }

    pub fn from_i32(data: Vec<i32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::I32(data) })
    }

    pub fn from_u32(data: Vec<u32>, shape: Vec<usize>) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::U32(data) })
    }

    /// f16 tensor from raw binary16 bits.
    pub fn from_f16_bits(data: Vec<u16>, shape: Vec<usize>)
        -> Result<Literal>
    {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::F16(data) })
    }

    /// int8 tensor with its per-tensor scale.
    pub fn from_i8(data: Vec<i8>, scale: f32, shape: Vec<usize>)
        -> Result<Literal>
    {
        Self::check(data.len(), &shape)?;
        Ok(Literal { shape, data: LiteralData::I8 { data, scale } })
    }

    /// Per-channel int8 tensor: `scales.len()` must divide
    /// `data.len()` evenly (0 scales only for an empty tensor).
    pub fn from_i8_rows(
        data: Vec<i8>,
        scales: Vec<f32>,
        shape: Vec<usize>,
    ) -> Result<Literal> {
        Self::check(data.len(), &shape)?;
        if scales.is_empty() {
            ensure!(data.is_empty(),
                    "per-channel int8 with 0 scales but {} codes",
                    data.len());
        } else {
            ensure!(data.len() % scales.len() == 0,
                    "per-channel int8: {} codes not divisible into {} \
                     rows",
                    data.len(), scales.len());
        }
        Ok(Literal { shape, data: LiteralData::I8C { data, scales } })
    }

    /// Quantize f32 data into a literal stored at `precision`
    /// (`Precision::F32` stores it as-is).  Rounding semantics are the
    /// documented ones in [`precision`]: RNE for f16, absmax/127 with
    /// ties-away rounding for int8.
    pub fn quantize_from_f32(
        data: &[f32],
        shape: &[usize],
        precision: Precision,
    ) -> Result<Literal> {
        Self::check(data.len(), shape)?;
        let stored = match precision {
            Precision::F32 => LiteralData::F32(data.to_vec()),
            Precision::F16 => {
                let mut bits = vec![0u16; data.len()];
                precision::f16_encode_into(data, &mut bits);
                LiteralData::F16(bits)
            }
            Precision::Int8 => {
                let mut q = vec![0i8; data.len()];
                let scale = precision::i8_quantize_into(data, &mut q);
                LiteralData::I8 { data: q, scale }
            }
            Precision::Int8Pc => {
                // one scale per output row for rank >= 2 tensors;
                // rank <= 1 degenerates to the per-tensor layout
                let rows = match shape {
                    [r, _, ..] => *r,
                    _ if data.is_empty() => 0,
                    _ => 1,
                };
                let mut q = vec![0i8; data.len()];
                let mut scales = vec![0f32; rows];
                precision::i8_quantize_rows_into(data, &mut q,
                                                 &mut scales);
                LiteralData::I8C { data: q, scales }
            }
        };
        Ok(Literal { shape: shape.to_vec(), data: stored })
    }

    /// Rebuild a parameter-storage literal from its
    /// [`to_le_bytes`](Literal::to_le_bytes) serialization — the read
    /// half of the session-image format.  Exact for every precision:
    /// the stored bits are installed verbatim, no re-quantization.
    pub fn from_storage_bytes(
        precision: Precision,
        shape: Vec<usize>,
        bytes: &[u8],
    ) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if precision == Precision::Int8Pc {
            // self-describing: [u32 n_scales][scales f32][codes i8]
            ensure!(bytes.len() >= 4,
                    "int8pc storage too short: {} bytes", bytes.len());
            let ns = u32::from_le_bytes([
                bytes[0], bytes[1], bytes[2], bytes[3],
            ]) as usize;
            ensure!(bytes.len() == 4 + 4 * ns + n,
                    "int8pc storage of shape {:?} with {} scales is \
                     {} bytes, got {}",
                    shape, ns, 4 + 4 * ns + n, bytes.len());
            let scales: Vec<f32> = bytes[4..4 + 4 * ns]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let data: Vec<i8> =
                bytes[4 + 4 * ns..].iter().map(|&b| b as i8).collect();
            return Literal::from_i8_rows(data, scales, shape);
        }
        ensure!(bytes.len() as u64 == precision.storage_bytes(n),
                "{} storage of shape {:?} is {} bytes, got {}",
                precision, shape, precision.storage_bytes(n),
                bytes.len());
        match precision {
            Precision::F32 => {
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Literal::from_f32(data, shape)
            }
            Precision::F16 => {
                let data: Vec<u16> = bytes
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect();
                Literal::from_f16_bits(data, shape)
            }
            Precision::Int8 => {
                let scale = f32::from_le_bytes([
                    bytes[0], bytes[1], bytes[2], bytes[3],
                ]);
                let data: Vec<i8> =
                    bytes[4..].iter().map(|&b| b as i8).collect();
                Literal::from_i8(data, scale, shape)
            }
            Precision::Int8Pc => unreachable!("handled above"),
        }
    }

    /// Replace the shape without touching the element storage (used
    /// when durable forms, which store tensors flat, are re-attached
    /// to a manifest's shaped parameter specs).
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Literal> {
        Self::check(self.element_count(), &shape)?;
        self.shape = shape;
        Ok(self)
    }

    /// Overwrite this literal's storage by re-quantizing `src` in
    /// place — the zero-allocation writeback half of the precision
    /// residency loop (int8 recomputes its per-tensor scale).
    pub fn requantize_from_f32(&mut self, src: &[f32]) -> Result<()> {
        ensure!(src.len() == self.element_count(),
                "requantize: {} values into a {}-element literal",
                src.len(), self.element_count());
        match &mut self.data {
            LiteralData::F32(v) => v.copy_from_slice(src),
            LiteralData::F16(v) => precision::f16_encode_into(src, v),
            LiteralData::I8 { data, scale } => {
                *scale = precision::i8_quantize_into(src, data);
            }
            LiteralData::I8C { data, scales } => {
                precision::i8_quantize_rows_into(src, data, scales);
            }
            other => bail!(
                "requantize_from_f32 on non-parameter dtype {:?}",
                match other {
                    LiteralData::I32(_) => Dtype::I32,
                    _ => Dtype::U32,
                }
            ),
        }
        Ok(())
    }

    /// Dequantize into a caller-provided f32 buffer (exact for f32 and
    /// f16 storage; `q * scale` for int8).  The hot-path form of
    /// [`as_f32_iter`](Literal::as_f32_iter).
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == self.element_count(),
                "dequantize: {}-element buffer for a {}-element literal",
                out.len(), self.element_count());
        match &self.data {
            LiteralData::F32(v) => out.copy_from_slice(v),
            LiteralData::F16(v) => precision::f16_decode_into(v, out),
            LiteralData::I8 { data, scale } => {
                precision::i8_dequantize_into(data, *scale, out)
            }
            LiteralData::I8C { data, scales } => {
                precision::i8_dequantize_rows_into(data, scales, out)
            }
            _ => bail!("dequantize on non-parameter dtype {:?}",
                       self.dtype()),
        }
        Ok(())
    }

    /// Every element as f32, whatever the parameter storage dtype —
    /// the round-trip accessor: f32 passes through, f16 decodes
    /// exactly, int8 yields `q * scale`.  Errors for i32/u32 literals.
    pub fn as_f32_iter(
        &self,
    ) -> Result<Box<dyn Iterator<Item = f32> + '_>> {
        match &self.data {
            LiteralData::F32(v) => Ok(Box::new(v.iter().copied())),
            LiteralData::F16(v) => Ok(Box::new(
                v.iter().map(|&h| precision::f16_bits_to_f32(h)),
            )),
            LiteralData::I8 { data, scale } => {
                let s = *scale;
                Ok(Box::new(data.iter().map(move |&q| q as f32 * s)))
            }
            LiteralData::I8C { data, scales } => {
                let cols =
                    (data.len() / scales.len().max(1)).max(1);
                let scales = scales.as_slice();
                Ok(Box::new(data.iter().enumerate().map(
                    move |(i, &q)| q as f32 * scales[i / cols],
                )))
            }
            _ => bail!("as_f32_iter on non-parameter dtype {:?}",
                       self.dtype()),
        }
    }

    /// The storage precision of a parameter literal (`None` for the
    /// integer calling-convention dtypes).
    pub fn storage_precision(&self) -> Option<Precision> {
        match self.data {
            LiteralData::F32(_) => Some(Precision::F32),
            LiteralData::F16(_) => Some(Precision::F16),
            LiteralData::I8 { .. } => Some(Precision::Int8),
            LiteralData::I8C { .. } => Some(Precision::Int8Pc),
            _ => None,
        }
    }

    /// Actual host bytes this literal's element storage occupies
    /// (int8 includes its 4-byte scale; per-channel int8 its scale
    /// row).
    pub fn resident_bytes(&self) -> u64 {
        match &self.data {
            LiteralData::F32(v) => 4 * v.len() as u64,
            LiteralData::I32(v) => 4 * v.len() as u64,
            LiteralData::U32(v) => 4 * v.len() as u64,
            LiteralData::F16(v) => 2 * v.len() as u64,
            LiteralData::I8 { data, .. } => data.len() as u64 + 4,
            LiteralData::I8C { data, scales } => {
                data.len() as u64 + 4 * scales.len() as u64
            }
        }
    }

    /// Exact length of [`to_le_bytes`](Literal::to_le_bytes) without
    /// materializing it.  Equals `precision.storage_bytes(len)` for
    /// the fixed layouts; per-channel int8 adds its scale row
    /// (`4 + 4 * n_scales + codes`).
    pub fn storage_len(&self) -> u64 {
        match &self.data {
            LiteralData::F32(v) => 4 * v.len() as u64,
            LiteralData::I32(v) => 4 * v.len() as u64,
            LiteralData::U32(v) => 4 * v.len() as u64,
            LiteralData::F16(v) => 2 * v.len() as u64,
            LiteralData::I8 { data, .. } => data.len() as u64 + 4,
            LiteralData::I8C { data, scales } => {
                4 + 4 * scales.len() as u64 + data.len() as u64
            }
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            LiteralData::F32(_) => Dtype::F32,
            LiteralData::I32(_) => Dtype::I32,
            LiteralData::U32(_) => Dtype::U32,
            LiteralData::F16(_) => Dtype::F16,
            LiteralData::I8 { .. } | LiteralData::I8C { .. } => {
                Dtype::I8
            }
        }
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U32(v) => v.len(),
            LiteralData::F16(v) => v.len(),
            LiteralData::I8 { data, .. } => data.len(),
            LiteralData::I8C { data, .. } => data.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.element_count()
    }

    pub fn is_empty(&self) -> bool {
        self.element_count() == 0
    }

    pub fn f32_slice(&self) -> Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            _ => bail!("expected f32 literal, got {:?}", self.dtype()),
        }
    }

    pub fn i32_slice(&self) -> Result<&[i32]> {
        match &self.data {
            LiteralData::I32(v) => Ok(v),
            _ => bail!("expected i32 literal, got {:?}", self.dtype()),
        }
    }

    pub fn u32_slice(&self) -> Result<&[u32]> {
        match &self.data {
            LiteralData::U32(v) => Ok(v),
            _ => bail!("expected u32 literal, got {:?}", self.dtype()),
        }
    }

    /// All elements as f32 (errors on dtype mismatch).
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.f32_slice()?.to_vec())
    }

    /// Consume the literal, moving its f32 storage out without a copy
    /// (the `ExecState::absorb` write-back path).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        let dt = self.dtype();
        match self.data {
            LiteralData::F32(v) => Ok(v),
            _ => bail!("expected f32 literal, got {:?}", dt),
        }
    }

    /// First element as f32 (works for shape-() and shape-(1,)).
    pub fn f32_scalar(&self) -> Result<f32> {
        match self.f32_slice()?.first() {
            Some(v) => Ok(*v),
            None => bail!("empty literal has no scalar"),
        }
    }

    /// First element as u32.
    pub fn u32_scalar(&self) -> Result<u32> {
        match self.u32_slice()?.first() {
            Some(v) => Ok(*v),
            None => bail!("empty literal has no scalar"),
        }
    }

    /// Raw little-endian bytes (checkpoint format).  Quantized storage
    /// serializes its resident form: u16 LE for f16, and a 4-byte f32
    /// scale followed by the code bytes for int8.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.element_count() * 4);
        match &self.data {
            LiteralData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::U32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::F16(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::I8 { data, scale } => {
                out.extend_from_slice(&scale.to_le_bytes());
                for x in data {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            LiteralData::I8C { data, scales } => {
                let ns = scales.len() as u32;
                out.extend_from_slice(&ns.to_le_bytes());
                for s in scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for x in data {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }
}

/// f32 tensor literal of the given shape (row-major data).
pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<Literal> {
    Literal::from_f32(data.to_vec(), shape.to_vec())
}

/// i32 tensor literal.
pub fn i32_tensor(data: &[i32], shape: &[usize]) -> Result<Literal> {
    Literal::from_i32(data.to_vec(), shape.to_vec())
}

/// Shape-(1,) f32 scalar (the step programs' scalar convention).
pub fn f32_1(v: f32) -> Result<Literal> {
    Literal::from_f32(vec![v], vec![1])
}

/// Shape-(1,) u32 scalar (the MeZO seed).
pub fn u32_1(v: u32) -> Result<Literal> {
    Literal::from_u32(vec![v], vec![1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.f32_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.shape(), &[2, 2]);
        assert_eq!(l.dtype(), Dtype::F32);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_tensor(&[1.0], &[2]).is_err());
        assert!(i32_tensor(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalars() {
        let l = f32_1(0.5).unwrap();
        assert_eq!(l.f32_scalar().unwrap(), 0.5);
        let u = u32_1(7).unwrap();
        assert_eq!(u.u32_scalar().unwrap(), 7);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let u = u32_1(7).unwrap();
        assert!(u.f32_vec().is_err());
        let f = f32_1(1.0).unwrap();
        assert!(f.i32_slice().is_err());
    }

    #[test]
    fn le_bytes_match_format() {
        let l = f32_tensor(&[1.0, -2.0], &[2]).unwrap();
        let b = l.to_le_bytes();
        assert_eq!(b.len(), 8);
        assert_eq!(&b[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&b[4..8], &(-2.0f32).to_le_bytes());
    }

    #[test]
    fn quantized_literals_keep_shape_and_dtype_invariants() {
        let data = [1.0f32, -0.5, 0.25, 0.75];
        for p in Precision::ALL {
            let l = Literal::quantize_from_f32(&data, &[2, 2], p)
                .unwrap();
            assert_eq!(l.shape(), &[2, 2]);
            assert_eq!(l.element_count(), 4);
            assert_eq!(l.dtype(), p.dtype());
            assert_eq!(l.storage_precision(), Some(p));
            let back: Vec<f32> = l.as_f32_iter().unwrap().collect();
            assert_eq!(back.len(), 4);
            // shape mismatch rejected for every precision
            assert!(Literal::quantize_from_f32(&data, &[3], p).is_err());
        }
        // resident bytes follow the dtype widths
        let f32l =
            Literal::quantize_from_f32(&data, &[4], Precision::F32)
                .unwrap();
        let f16l =
            Literal::quantize_from_f32(&data, &[4], Precision::F16)
                .unwrap();
        let i8l =
            Literal::quantize_from_f32(&data, &[4], Precision::Int8)
                .unwrap();
        assert_eq!(f32l.resident_bytes(), 16);
        assert_eq!(f16l.resident_bytes(), 8);
        assert_eq!(i8l.resident_bytes(), 4 + 4); // codes + scale
        let i8pc =
            Literal::quantize_from_f32(&data, &[2, 2], Precision::Int8Pc)
                .unwrap();
        assert_eq!(i8pc.resident_bytes(), 4 + 2 * 4); // codes + 2 scales
        assert_eq!(i8pc.storage_len(), 4 + 2 * 4 + 4); // + n_scales u32
    }

    #[test]
    fn per_channel_literal_rows_follow_shape_then_travel_with_data() {
        // rows with very different magnitudes: per-channel keeps the
        // small row's resolution
        let data = [0.01f32, -0.02, 0.015, 100.0, -50.0, 75.0];
        let l =
            Literal::quantize_from_f32(&data, &[2, 3], Precision::Int8Pc)
                .unwrap();
        assert_eq!(l.dtype(), Dtype::I8);
        assert_eq!(l.storage_precision(), Some(Precision::Int8Pc));
        let back: Vec<f32> = l.as_f32_iter().unwrap().collect();
        let mut buf = [0f32; 6];
        l.dequantize_into(&mut buf).unwrap();
        assert_eq!(back, buf.to_vec());
        // small-row error far below what per-tensor absmax would give
        for (x, y) in data[..3].iter().zip(&back[..3]) {
            assert!((x - y).abs() <= 0.02 / 127.0 * 0.5 + 1e-7,
                    "{x} vs {y}");
        }
        // reshaping (the flat durable form) must not change values
        let flat = l.clone().reshaped(vec![6]).unwrap();
        let back2: Vec<f32> = flat.as_f32_iter().unwrap().collect();
        assert_eq!(back, back2);
        // wire roundtrip: self-describing payload, shape-independent
        let bytes = l.to_le_bytes();
        assert_eq!(bytes.len() as u64, l.storage_len());
        let rt = Literal::from_storage_bytes(Precision::Int8Pc,
                                             vec![2, 3], &bytes)
            .unwrap();
        assert_eq!(rt, l);
        // truncated payloads rejected
        assert!(Literal::from_storage_bytes(Precision::Int8Pc,
                                            vec![2, 3], &bytes[..3])
            .is_err());
        assert!(Literal::from_storage_bytes(Precision::Int8Pc,
                                            vec![2, 3],
                                            &bytes[..bytes.len() - 1])
            .is_err());
        // requantize reuses the existing row grouping
        let mut l2 = l.clone();
        l2.requantize_from_f32(&back).unwrap();
        assert_eq!(l2, l, "int8pc boundary crossings must not drift");
        // rank-1 degenerates to one scale == per-tensor arithmetic
        let r1 = Literal::quantize_from_f32(&data, &[6],
                                            Precision::Int8Pc)
            .unwrap();
        let pt = Literal::quantize_from_f32(&data, &[6],
                                            Precision::Int8)
            .unwrap();
        let a: Vec<f32> = r1.as_f32_iter().unwrap().collect();
        let b: Vec<f32> = pt.as_f32_iter().unwrap().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn f16_literal_roundtrip_is_lossless_for_f16_values() {
        // values already representable in f16 survive the full
        // quantize -> as_f32_iter -> requantize loop bit-exactly
        let data = [1.0f32, -2.5, 0.0009765625, 65504.0];
        let mut l =
            Literal::quantize_from_f32(&data, &[4], Precision::F16)
                .unwrap();
        let back: Vec<f32> = l.as_f32_iter().unwrap().collect();
        assert_eq!(back, data);
        let before = l.clone();
        l.requantize_from_f32(&back).unwrap();
        assert_eq!(l, before);
    }

    #[test]
    fn dequantize_into_matches_iter() {
        let data = [0.11f32, -0.7, 0.0, 3.3];
        for p in Precision::ALL {
            let l = Literal::quantize_from_f32(&data, &[4], p).unwrap();
            let mut buf = [9f32; 4];
            l.dequantize_into(&mut buf).unwrap();
            let it: Vec<f32> = l.as_f32_iter().unwrap().collect();
            assert_eq!(buf.to_vec(), it, "{p}");
            assert!(l.dequantize_into(&mut [0f32; 3]).is_err());
        }
        // integer calling-convention literals refuse the accessors
        let u = u32_1(7).unwrap();
        assert!(u.as_f32_iter().is_err());
        assert!(u.dequantize_into(&mut [0f32; 1]).is_err());
        assert_eq!(u.storage_precision(), None);
    }

    #[test]
    fn storage_bytes_roundtrip_bit_exactly_for_every_precision() {
        // the session-image contract: to_le_bytes -> from_storage_bytes
        // must reproduce the literal verbatim (PartialEq covers the
        // int8 scale and every stored bit)
        let data = [0.11f32, -0.7, 0.0, 3.3, -1e-5, 65504.0];
        for p in Precision::ALL {
            let l = Literal::quantize_from_f32(&data, &[2, 3], p)
                .unwrap();
            let bytes = l.to_le_bytes();
            assert_eq!(bytes.len() as u64, l.storage_len(), "{p}");
            let back =
                Literal::from_storage_bytes(p, vec![2, 3], &bytes)
                    .unwrap();
            assert_eq!(back, l, "{p}");
            // wrong byte count rejected
            assert!(Literal::from_storage_bytes(p, vec![2, 3],
                                                &bytes[1..])
                .is_err());
        }
    }

    #[test]
    fn reshaped_validates_element_count() {
        let l = f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        let r = l.clone().reshaped(vec![2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.f32_vec().unwrap(), l.f32_vec().unwrap());
        assert!(l.reshaped(vec![3]).is_err());
    }

    #[test]
    fn i8_literal_le_bytes_lead_with_scale() {
        let l = Literal::from_i8(vec![1, -2, 3], 0.5, vec![3]).unwrap();
        let b = l.to_le_bytes();
        assert_eq!(b.len(), 7);
        assert_eq!(&b[0..4], &0.5f32.to_le_bytes());
        assert_eq!(b[4], 1);
    }
}
