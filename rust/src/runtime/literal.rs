//! Host-literal construction/extraction helpers over the `xla` crate.
//!
//! The step programs speak three element types (f32/i32/u32) and two
//! scalar conventions (shape-(1,) scalars for seed/lr/eps; shape-()
//! for the returned loss).  These helpers centralize the byte-level
//! plumbing so the session code stays readable.

use anyhow::{anyhow, Context, Result};
use xla::Literal;

fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

/// f32 tensor literal of the given shape (row-major data).
pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs {} values", shape,
                    data.len());
    Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes_of(data),
    )
    .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

/// i32 tensor literal.
pub fn i32_tensor(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs {} values", shape,
                    data.len());
    Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes_of(data),
    )
    .map_err(|e| anyhow!("i32 literal: {e:?}"))
}

/// Shape-(1,) f32 scalar (the step programs' scalar convention).
pub fn f32_1(v: f32) -> Result<Literal> {
    f32_tensor(&[v], &[1])
}

/// Shape-(1,) u32 scalar (the MeZO seed).
pub fn u32_1(v: u32) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        &[1],
        bytes_of(&[v]),
    )
    .map_err(|e| anyhow!("u32 literal: {e:?}"))
}

/// Convenience extraction methods on `xla::Literal`.
pub trait LiteralExt {
    /// All elements as f32 (errors on dtype mismatch).
    fn f32_vec(&self) -> Result<Vec<f32>>;
    /// First element as f32 (works for shape-() and shape-(1,)).
    fn f32_scalar(&self) -> Result<f32>;
    /// Total element count.
    fn len(&self) -> usize;
}

impl LiteralExt for Literal {
    fn f32_vec(&self) -> Result<Vec<f32>> {
        self.to_vec::<f32>().map_err(|e| anyhow!("literal->f32 vec: {e:?}"))
    }

    fn f32_scalar(&self) -> Result<f32> {
        self.get_first_element::<f32>()
            .map_err(|e| anyhow!("literal->f32 scalar: {e:?}"))
            .context("extracting scalar")
    }

    fn len(&self) -> usize {
        self.element_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.f32_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(LiteralExt::len(&l), 4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_tensor(&[1.0], &[2]).is_err());
        assert!(i32_tensor(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalars() {
        let l = f32_1(0.5).unwrap();
        assert_eq!(l.f32_scalar().unwrap(), 0.5);
        let u = u32_1(7).unwrap();
        assert_eq!(u.get_first_element::<u32>().unwrap(), 7);
    }
}
