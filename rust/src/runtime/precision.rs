//! First-class parameter-storage precision.
//!
//! The paper's feasibility numbers are quantized deployments: OPT-1.3B
//! fits the Reno 6 in ~6.5 GB only because the parameters are fp16
//! (`device/spec.rs` `bytes_per_param`).  [`Precision`] makes that a
//! property of the tensor API instead of a simulation-only constant:
//! the session's resident parameters ([`ExecState`](super::ExecState))
//! are stored at this precision *between* steps and dequantized into
//! f32 scratch buffers only for compute.
//!
//! ## Conversion semantics (the contract the tests pin)
//!
//! * **f16** — IEEE 754 binary16.  f32 → f16 rounds to nearest, ties
//!   to even (RNE), exactly like hardware conversion instructions:
//!   values above 65504+16 overflow to ±inf, f16-subnormal magnitudes
//!   (below 2^-14) are rounded into the subnormal grid, magnitudes at
//!   or below 2^-25 underflow to ±0 (the 2^-25 tie rounds to the even
//!   candidate, zero), NaN maps to a canonical quiet NaN (payloads are
//!   not preserved), and ±inf / ±0 map through exactly.  f16 → f32 is
//!   exact for every non-NaN value, so re-encoding a decoded f16 is
//!   the identity (exhaustively tested over all 65536 bit patterns).
//! * **int8** — symmetric per-tensor absmax quantization: `scale =
//!   absmax / 127` over the *finite* elements, `q = clamp(round(x /
//!   scale), -127, 127)` with Rust's `round` (ties away from zero).
//!   An all-zero (or all-non-finite) tensor stores `scale = 0` and
//!   dequantizes to exact zeros.  Non-finite inputs: NaN → 0, ±inf →
//!   ±127.  A quantize → dequantize → quantize round trip reproduces
//!   the same codes (the absmax element sits exactly at ±127), so
//!   repeated boundary crossings do not drift.

use super::manifest::Dtype;

/// Parameter-storage policy for a session's resident tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full precision — the historical layout; the hot loop operates
    /// on the resident buffers directly and trajectories are
    /// bit-identical to the pre-precision API.
    F32,
    /// IEEE binary16 storage; f32 compute with round-to-nearest-even
    /// writeback.  Halves resident parameter bytes.
    F16,
    /// Symmetric per-tensor absmax int8 storage (+4-byte scale).
    /// Quarter resident bytes; lossy — the scale is recomputed at
    /// every writeback, and with no f32 master copy any per-element
    /// update smaller than half the quantization step (absmax/254)
    /// is absorbed entirely by the re-rounding.  This makes int8 a
    /// *residency/footprint* mode (inference, storage experiments,
    /// the BENCH_quant sweep), not a training-accuracy mode — MeZO's
    /// tiny per-step updates typically round away.  fp16 is the
    /// precision the paper's fine-tuning feasibility claims use.
    Int8,
}

impl Precision {
    pub const ALL: [Precision; 3] =
        [Precision::F32, Precision::F16, Precision::Int8];

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "f16" | "fp16" | "half" => Some(Precision::F16),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }

    /// Storage bytes per parameter element (what the device ledger and
    /// the analytic footprint model charge).  Int8's per-tensor scale
    /// is amortized to zero here; [`Literal::resident_bytes`]
    /// (super::Literal::resident_bytes) counts it exactly.
    pub fn param_bytes(&self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// The element dtype resident tensors of this precision carry.
    pub fn dtype(&self) -> Dtype {
        match self {
            Precision::F32 => Dtype::F32,
            Precision::F16 => Dtype::F16,
            Precision::Int8 => Dtype::I8,
        }
    }

    /// Stable one-byte wire code used by the durable session-image
    /// format (`store::image`).  These values are part of the on-disk
    /// contract: never renumber, only append.
    pub fn code(&self) -> u8 {
        match self {
            Precision::F32 => 0,
            Precision::F16 => 1,
            Precision::Int8 => 2,
        }
    }

    /// Inverse of [`code`](Precision::code).
    pub fn from_code(c: u8) -> Option<Precision> {
        match c {
            0 => Some(Precision::F32),
            1 => Some(Precision::F16),
            2 => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Bytes one tensor of `elems` elements occupies in storage form —
    /// both resident (`Literal::resident_bytes`) and on disk
    /// (`Literal::to_le_bytes`): 4/2/1 B per element, plus int8's
    /// 4-byte per-tensor scale.
    pub fn storage_bytes(&self, elems: usize) -> u64 {
        match self {
            Precision::F32 => 4 * elems as u64,
            Precision::F16 => 2 * elems as u64,
            Precision::Int8 => elems as u64 + 4,
        }
    }
}

impl Default for Precision {
    fn default() -> Self {
        Precision::F32
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------
// f32 <-> f16 (IEEE binary16), round-to-nearest-even
// ---------------------------------------------------------------------

/// Encode one f32 as IEEE binary16 bits, rounding to nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf stays inf; every NaN becomes the canonical quiet NaN
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let e = exp - 127 + 15; // rebias
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // f16 subnormal (or underflow to zero)
        if e < -10 {
            // magnitude <= 2^-25: below half the smallest subnormal,
            // or the exact 2^-25 tie whose even neighbour is zero
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32; // 13 mantissa bits + (1 - e)
        let half = 1u32 << (shift - 1);
        let rem = man & ((1u32 << shift) - 1);
        let base = man >> shift;
        let up = rem > half || (rem == half && base & 1 == 1);
        return sign | (base + up as u32) as u16;
    }
    // normal: drop 13 mantissa bits with RNE; a mantissa carry
    // correctly bumps the exponent (and may round up to inf)
    let base = man >> 13;
    let rem = man & 0x1FFF;
    let up = rem > 0x1000 || (rem == 0x1000 && base & 1 == 1);
    sign | (((e as u32) << 10 | base) + up as u32) as u16
}

/// Decode IEEE binary16 bits to f32 (exact for all non-NaN inputs; NaN
/// payload bits are carried into the f32 mantissa).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // +-0
        } else {
            // subnormal: value = man * 2^-24; normalize into f32
            let mut m = man;
            let mut shifts = 0u32;
            while m & 0x0400 == 0 {
                m <<= 1;
                shifts += 1;
            }
            sign | ((113 - shifts) << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Encode a slice (round-to-nearest-even per element).
pub fn f16_encode_into(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = f32_to_f16_bits(x);
    }
}

/// Decode a slice (exact).
pub fn f16_decode_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &h) in dst.iter_mut().zip(src) {
        *d = f16_bits_to_f32(h);
    }
}

// ---------------------------------------------------------------------
// f32 <-> int8 (symmetric per-tensor absmax)
// ---------------------------------------------------------------------

/// Quantize into a caller-provided buffer; returns the per-tensor
/// scale (`absmax / 127` over finite elements; 0 for an all-zero or
/// all-non-finite tensor).
pub fn i8_quantize_into(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let absmax = src
        .iter()
        .filter(|x| x.is_finite())
        .fold(0f32, |a, &x| a.max(x.abs()));
    if absmax == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = absmax / 127.0;
    for (d, &x) in dst.iter_mut().zip(src) {
        // NaN `as`-casts to 0; +-inf clamps to +-127
        *d = (x / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Dequantize: `out[i] = data[i] * scale` (exact zeros for scale 0).
pub fn i8_dequantize_into(src: &[i8], scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &q) in dst.iter_mut().zip(src) {
        *d = q as f32 * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels() {
        assert_eq!(Precision::parse("f16"), Some(Precision::F16));
        assert_eq!(Precision::parse("fp16"), Some(Precision::F16));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("bf16"), None);
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.label()), Some(p));
        }
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn param_bytes_ordering() {
        assert_eq!(Precision::F32.param_bytes(), 4);
        assert_eq!(Precision::F16.param_bytes(), 2);
        assert_eq!(Precision::Int8.param_bytes(), 1);
    }

    #[test]
    fn wire_codes_roundtrip_and_stay_stable() {
        // on-disk contract: these numbers are baked into session images
        assert_eq!(Precision::F32.code(), 0);
        assert_eq!(Precision::F16.code(), 1);
        assert_eq!(Precision::Int8.code(), 2);
        for p in Precision::ALL {
            assert_eq!(Precision::from_code(p.code()), Some(p));
        }
        assert_eq!(Precision::from_code(3), None);
    }

    #[test]
    fn storage_bytes_count_the_int8_scale() {
        assert_eq!(Precision::F32.storage_bytes(10), 40);
        assert_eq!(Precision::F16.storage_bytes(10), 20);
        assert_eq!(Precision::Int8.storage_bytes(10), 14);
        assert_eq!(Precision::Int8.storage_bytes(0), 4);
    }

    #[test]
    fn f16_known_values() {
        // (f32, f16 bits) pins from the IEEE 754 tables
        let cases: [(f32, u16); 8] = [
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (65504.0, 0x7BFF),         // f16 max
            (6.103_515_6e-5, 0x0400),  // smallest normal, 2^-14
            (5.960_464_5e-8, 0x0001),  // smallest subnormal, 2^-24
            (0.333_251_95, 0x3555),    // 1/3 rounded to f16
        ];
        for (x, h) in cases {
            assert_eq!(f32_to_f16_bits(x), h, "encode {x}");
            assert_eq!(f16_bits_to_f32(h).to_bits(), x.to_bits(),
                       "decode {h:#06x}");
        }
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between f16(1.0) and the next
        // representable 1 + 2^-10: RNE picks the even mantissa (1.0)
        let tie_down = 1.0f32 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_down), 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 (odd mantissa) and
        // 1+2^-9 (even mantissa): RNE rounds UP to the even one
        let tie_up = 1.0f32 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3C02);
        // just off the tie rounds to nearest as usual
        assert_eq!(f32_to_f16_bits(tie_down + 1e-7), 0x3C01);
        assert_eq!(f32_to_f16_bits(tie_down - 1e-7), 0x3C00);
    }

    #[test]
    fn f16_nan_inf_subnormal_edges() {
        // NaN -> canonical quiet NaN, still NaN after decode
        let h = f32_to_f16_bits(f32::NAN);
        assert_eq!(h & 0x7C00, 0x7C00);
        assert_ne!(h & 0x03FF, 0);
        assert!(f16_bits_to_f32(h).is_nan());
        // infinities map through with sign
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        // overflow -> inf (65520 ties between 65504 and 65536; the
        // 65504 mantissa is odd, so RNE overflows to inf)
        assert_eq!(f32_to_f16_bits(1e5), 0x7C00);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
        assert_eq!(f32_to_f16_bits(65519.0), 0x7BFF);
        // f32 values inside the f16-subnormal range round onto the
        // subnormal grid
        let x = 1.5 * 2f32.powi(-24); // 1.5 * smallest subnormal: tie
        assert_eq!(f32_to_f16_bits(x), 0x0002, "tie to even (2)");
        assert_eq!(f32_to_f16_bits(1.25 * 2f32.powi(-24)), 0x0001);
        // underflow: at or below 2^-25 becomes signed zero
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16_bits(-2f32.powi(-26)), 0x8000);
        // an f32 subnormal (way below 2^-25) underflows too
        assert_eq!(f32_to_f16_bits(f32::from_bits(1)), 0x0000);
    }

    #[test]
    fn f16_decode_encode_is_identity_for_all_bit_patterns() {
        // decode is exact, so re-encoding must reproduce every non-NaN
        // pattern bit-for-bit; NaNs must at least stay NaN
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(x), h,
                           "bits {h:#06x} decoded to {x} did not \
                            re-encode");
            }
        }
    }

    #[test]
    fn i8_roundtrip_is_stable() {
        let src = [0.5f32, -1.0, 0.25, 0.999, -0.123, 0.0];
        let mut q = [0i8; 6];
        let scale = i8_quantize_into(&src, &mut q);
        assert!(scale > 0.0);
        assert_eq!(q[1], -127, "the absmax element must hit the rail");
        let mut deq = [0f32; 6];
        i8_dequantize_into(&q, scale, &mut deq);
        // re-quantizing the dequantized tensor reproduces the codes
        let mut q2 = [0i8; 6];
        i8_quantize_into(&deq, &mut q2);
        assert_eq!(q, q2, "int8 boundary crossings must not drift");
        // error bounded by half a step
        for (x, d) in src.iter().zip(&deq) {
            assert!((x - d).abs() <= scale * 0.5 + 1e-7);
        }
    }

    #[test]
    fn i8_zero_tensor_and_nonfinite() {
        let mut q = [3i8; 4];
        let s = i8_quantize_into(&[0.0, 0.0, -0.0, 0.0], &mut q);
        assert_eq!(s, 0.0);
        assert_eq!(q, [0i8; 4]);
        let mut deq = [1f32; 4];
        i8_dequantize_into(&q, s, &mut deq);
        assert_eq!(deq, [0f32; 4], "scale 0 dequantizes to exact zeros");

        // non-finite inputs: NaN -> 0, +-inf clamps to the rails;
        // the scale comes from the finite elements only
        let src = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let mut q = [0i8; 4];
        let s = i8_quantize_into(&src, &mut q);
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(q, [0, 127, -127, 127]);

        // all-non-finite: nothing finite to scale by -> zeros
        let mut q = [5i8; 2];
        let s = i8_quantize_into(&[f32::NAN, f32::INFINITY], &mut q);
        assert_eq!(s, 0.0);
        assert_eq!(q, [0, 0]);
    }
}
