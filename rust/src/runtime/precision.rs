//! First-class parameter-storage precision.
//!
//! The paper's feasibility numbers are quantized deployments: OPT-1.3B
//! fits the Reno 6 in ~6.5 GB only because the parameters are fp16
//! (`device/spec.rs` `bytes_per_param`).  [`Precision`] makes that a
//! property of the tensor API instead of a simulation-only constant:
//! the session's resident parameters ([`ExecState`](super::ExecState))
//! are stored at this precision *between* steps and dequantized into
//! f32 scratch buffers only for compute.
//!
//! ## Conversion semantics (the contract the tests pin)
//!
//! * **f16** — IEEE 754 binary16.  f32 → f16 rounds to nearest, ties
//!   to even (RNE), exactly like hardware conversion instructions:
//!   values above 65504+16 overflow to ±inf, f16-subnormal magnitudes
//!   (below 2^-14) are rounded into the subnormal grid, magnitudes at
//!   or below 2^-25 underflow to ±0 (the 2^-25 tie rounds to the even
//!   candidate, zero), NaN maps to a canonical quiet NaN (payloads are
//!   not preserved), and ±inf / ±0 map through exactly.  f16 → f32 is
//!   exact for every non-NaN value, so re-encoding a decoded f16 is
//!   the identity (exhaustively tested over all 65536 bit patterns).
//! * **int8** — symmetric per-tensor absmax quantization: `scale =
//!   absmax / 127` over the *finite* elements, `q = clamp(round(x /
//!   scale), -127, 127)` with Rust's `round` (ties away from zero).
//!   An all-zero (or all-non-finite) tensor stores `scale = 0` and
//!   dequantizes to exact zeros.  Non-finite inputs: NaN → 0, ±inf →
//!   ±127.  A quantize → dequantize → quantize round trip reproduces
//!   the same codes (the absmax element sits exactly at ±127), so
//!   repeated boundary crossings do not drift.

use super::manifest::Dtype;

/// Parameter-storage policy for a session's resident tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full precision — the historical layout; the hot loop operates
    /// on the resident buffers directly and trajectories are
    /// bit-identical to the pre-precision API.
    F32,
    /// IEEE binary16 storage; f32 compute with round-to-nearest-even
    /// writeback.  Halves resident parameter bytes.
    F16,
    /// Symmetric per-tensor absmax int8 storage (+4-byte scale).
    /// Quarter resident bytes; lossy — the scale is recomputed at
    /// every writeback, and with no f32 master copy any per-element
    /// update smaller than half the quantization step (absmax/254)
    /// is absorbed entirely by the re-rounding.  This makes int8 a
    /// *residency/footprint* mode (inference, storage experiments,
    /// the BENCH_quant sweep), not a training-accuracy mode — MeZO's
    /// tiny per-step updates typically round away.  fp16 is the
    /// precision the paper's fine-tuning feasibility claims use.
    Int8,
    /// Per-channel int8: one absmax scale per output row (`shape[0]`
    /// for rank >= 2 tensors, per-tensor otherwise), so a tensor with
    /// mixed-magnitude rows doesn't burn its quantization budget on
    /// the largest row.  Same rounding arithmetic as [`Int8`]
    /// (Precision::Int8) per row; the BENCH_quant sweep compares the
    /// two.  Storage is self-describing (`[n_scales][scales][codes]`)
    /// because session images store tensors flat.
    Int8Pc,
}

impl Precision {
    pub const ALL: [Precision; 4] = [
        Precision::F32,
        Precision::F16,
        Precision::Int8,
        Precision::Int8Pc,
    ];

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "f16" | "fp16" | "half" => Some(Precision::F16),
            "int8" | "i8" => Some(Precision::Int8),
            "int8pc" | "i8pc" => Some(Precision::Int8Pc),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
            Precision::Int8Pc => "int8pc",
        }
    }

    /// Storage bytes per parameter element (what the device ledger and
    /// the analytic footprint model charge).  Int8's per-tensor scale
    /// is amortized to zero here; [`Literal::resident_bytes`]
    /// (super::Literal::resident_bytes) counts it exactly.
    pub fn param_bytes(&self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F16 => 2,
            Precision::Int8 | Precision::Int8Pc => 1,
        }
    }

    /// The element dtype resident tensors of this precision carry.
    pub fn dtype(&self) -> Dtype {
        match self {
            Precision::F32 => Dtype::F32,
            Precision::F16 => Dtype::F16,
            Precision::Int8 | Precision::Int8Pc => Dtype::I8,
        }
    }

    /// Stable one-byte wire code used by the durable session-image
    /// format (`store::image`).  These values are part of the on-disk
    /// contract: never renumber, only append.
    pub fn code(&self) -> u8 {
        match self {
            Precision::F32 => 0,
            Precision::F16 => 1,
            Precision::Int8 => 2,
            Precision::Int8Pc => 3,
        }
    }

    /// Inverse of [`code`](Precision::code).
    pub fn from_code(c: u8) -> Option<Precision> {
        match c {
            0 => Some(Precision::F32),
            1 => Some(Precision::F16),
            2 => Some(Precision::Int8),
            3 => Some(Precision::Int8Pc),
            _ => None,
        }
    }

    /// Bytes one tensor of `elems` elements occupies in storage form —
    /// both resident (`Literal::resident_bytes`) and on disk
    /// (`Literal::to_le_bytes`): 4/2/1 B per element, plus int8's
    /// 4-byte per-tensor scale.  `Int8Pc` storage depends on the
    /// tensor's row count, not just `elems`; this returns the 1-row
    /// size (`[n_scales][scale][codes]`) — use
    /// [`Literal::storage_len`](super::Literal::storage_len) wherever
    /// the exact byte count matters.
    pub fn storage_bytes(&self, elems: usize) -> u64 {
        match self {
            Precision::F32 => 4 * elems as u64,
            Precision::F16 => 2 * elems as u64,
            Precision::Int8 => elems as u64 + 4,
            Precision::Int8Pc => elems as u64 + 8,
        }
    }
}

impl Default for Precision {
    fn default() -> Self {
        Precision::F32
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------
// f32 <-> f16 (IEEE binary16), round-to-nearest-even
// ---------------------------------------------------------------------

/// Encode one f32 as IEEE binary16 bits, rounding to nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf stays inf; every NaN becomes the canonical quiet NaN
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let e = exp - 127 + 15; // rebias
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // f16 subnormal (or underflow to zero)
        if e < -10 {
            // magnitude <= 2^-25: below half the smallest subnormal,
            // or the exact 2^-25 tie whose even neighbour is zero
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32; // 13 mantissa bits + (1 - e)
        let half = 1u32 << (shift - 1);
        let rem = man & ((1u32 << shift) - 1);
        let base = man >> shift;
        let up = rem > half || (rem == half && base & 1 == 1);
        return sign | (base + up as u32) as u16;
    }
    // normal: drop 13 mantissa bits with RNE; a mantissa carry
    // correctly bumps the exponent (and may round up to inf)
    let base = man >> 13;
    let rem = man & 0x1FFF;
    let up = rem > 0x1000 || (rem == 0x1000 && base & 1 == 1);
    sign | (((e as u32) << 10 | base) + up as u32) as u16
}

/// Decode IEEE binary16 bits to f32 (exact for all non-NaN inputs; NaN
/// payload bits are carried into the f32 mantissa).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // +-0
        } else {
            // subnormal: value = man * 2^-24; normalize into f32
            let mut m = man;
            let mut shifts = 0u32;
            while m & 0x0400 == 0 {
                m <<= 1;
                shifts += 1;
            }
            sign | ((113 - shifts) << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Branch-free encode body: both the normal-range rounding and the
/// subnormal rounding are computed unconditionally (with shift counts
/// clamped into a defined range) and the result is picked with
/// selects, so the bulk encoder below is a flat, unit-stride loop the
/// compiler can if-convert and vectorize.  Bit-for-bit equal to
/// [`f32_to_f16_bits`] for every f32 pattern (tests cross-check an
/// exhaustive f16 sweep plus a structured exponent × mantissa sweep).
#[inline]
fn f16_bits_branchless(bits: u32) -> u16 {
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = (bits >> 23) & 0xFF;
    let man = bits & 0x007F_FFFF;
    let e = exp as i32 - 112; // rebased f16 exponent (-127 + 15)

    // normal path (meaningful for 0 < e < 31; a mantissa-rounding
    // carry bumps the exponent and may round up to inf)
    let nbase = man >> 13;
    let nrem = man & 0x1FFF;
    let nup = (nrem > 0x1000 || (nrem == 0x1000 && nbase & 1 == 1))
        as u32;
    let normal = (((e as u32) & 0x1F) << 10 | nbase) + nup;

    // subnormal path (meaningful for e <= 0).  shift >= 25 always
    // rounds to zero, so clamping at 26 both keeps the shift defined
    // for every exponent and reproduces the scalar encoder's
    // "magnitude <= 2^-25 underflows" rule exactly.
    let man24 = man | 0x0080_0000;
    let shift = (14 - e).clamp(1, 26) as u32;
    let half = 1u32 << (shift - 1);
    let srem = man24 & ((1u32 << shift) - 1);
    let sbase = man24 >> shift;
    let sup = (srem > half || (srem == half && sbase & 1 == 1)) as u32;
    let sub = sbase + sup;

    let mag = if e >= 0x1F {
        0x7C00u16 // overflow -> inf
    } else if e > 0 {
        normal as u16
    } else {
        sub as u16
    };
    let mag = if exp == 0xFF {
        if man == 0 { 0x7C00 } else { 0x7E00 } // inf / canonical qNaN
    } else {
        mag
    };
    sign | mag
}

/// Branch-free decode body (the classic "magic float" half-to-float):
/// shift exponent+mantissa into f32 position, rebias, then fix the
/// two exponent edge cases with selects — inf/NaN get the rest of the
/// rebias, zero/subnormal renormalize through one exact f32 subtract.
/// Bit-for-bit equal to [`f16_bits_to_f32`] for all 65536 patterns
/// (exhaustively tested), NaN payloads included.
#[inline]
fn f16_to_f32_branchless(h: u16) -> f32 {
    const SHIFTED_EXP: u32 = 0x7C00 << 13;
    const MAGIC: f32 = f32::from_bits(113 << 23); // 2^-14
    let sign = ((h & 0x8000) as u32) << 16;
    let mut o = ((h & 0x7FFF) as u32) << 13;
    let exp = o & SHIFTED_EXP;
    o = o.wrapping_add((127 - 15) << 23);
    o = o.wrapping_add(if exp == SHIFTED_EXP {
        (128 - 16) << 23 // inf/NaN: rebias the rest of the way to 255
    } else {
        0
    });
    let sub = (f32::from_bits(o.wrapping_add(1 << 23)) - MAGIC).to_bits();
    o = if exp == 0 { sub } else { o };
    f32::from_bits(o | sign)
}

/// Encode a slice (round-to-nearest-even per element).  Unit-stride
/// loop over the branch-free kernel; results are bit-identical to
/// mapping [`f32_to_f16_bits`].
pub fn f16_encode_into(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = f16_bits_branchless(x.to_bits());
    }
}

/// Decode a slice (exact).  Unit-stride loop over the branch-free
/// kernel; results are bit-identical to mapping [`f16_bits_to_f32`].
pub fn f16_decode_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &h) in dst.iter_mut().zip(src) {
        *d = f16_to_f32_branchless(h);
    }
}

// ---------------------------------------------------------------------
// f32 <-> int8 (symmetric per-tensor absmax)
// ---------------------------------------------------------------------

/// Finite absmax of a slice, computed with 8 independent max lanes so
/// the reduction vectorizes.  Reassociating a max over non-negative
/// values is exact (unlike a float sum), and mapping non-finite
/// elements to 0.0 — the fold's identity — reproduces the original
/// `filter(is_finite)` semantics bit-for-bit.
fn finite_absmax(src: &[f32]) -> f32 {
    let mut lanes = [0f32; 8];
    let mut chunks = src.chunks_exact(8);
    for c in chunks.by_ref() {
        for (m, &x) in lanes.iter_mut().zip(c) {
            let a = if x.is_finite() { x.abs() } else { 0.0 };
            *m = m.max(a);
        }
    }
    let mut mx = lanes.iter().fold(0f32, |a, &b| a.max(b));
    for &x in chunks.remainder() {
        let a = if x.is_finite() { x.abs() } else { 0.0 };
        mx = mx.max(a);
    }
    mx
}

/// Quantize into a caller-provided buffer; returns the per-tensor
/// scale (`absmax / 127` over finite elements; 0 for an all-zero or
/// all-non-finite tensor).  The rounding arithmetic is exactly the
/// historical `(x / scale).round().clamp(..)` — only the absmax
/// reduction is lane-parallel (legal: max is order-independent).
pub fn i8_quantize_into(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let absmax = finite_absmax(src);
    if absmax == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = absmax / 127.0;
    for (d, &x) in dst.iter_mut().zip(src) {
        // NaN `as`-casts to 0; +-inf clamps to +-127
        *d = (x / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Dequantize: `out[i] = data[i] * scale` (exact zeros for scale 0).
pub fn i8_dequantize_into(src: &[i8], scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &q) in dst.iter_mut().zip(src) {
        *d = q as f32 * scale;
    }
}

/// Per-channel (per-output-row) symmetric absmax quantization: one
/// scale per row of a `[rows, cols]` tensor, where `rows ==
/// scales.len()` and `cols == src.len() / rows`.  Each row uses the
/// same arithmetic as [`i8_quantize_into`], so a 1-row call is
/// bit-identical to the per-tensor path.
pub fn i8_quantize_rows_into(
    src: &[f32],
    dst: &mut [i8],
    scales: &mut [f32],
) {
    debug_assert_eq!(src.len(), dst.len());
    let rows = scales.len();
    if rows == 0 {
        debug_assert!(src.is_empty());
        return;
    }
    let cols = src.len() / rows;
    debug_assert_eq!(cols * rows, src.len());
    for (r, sc) in scales.iter_mut().enumerate() {
        *sc = i8_quantize_into(&src[r * cols..(r + 1) * cols],
                               &mut dst[r * cols..(r + 1) * cols]);
    }
}

/// Per-channel dequantize: `out[r][j] = data[r][j] * scales[r]`.
pub fn i8_dequantize_rows_into(
    src: &[i8],
    scales: &[f32],
    dst: &mut [f32],
) {
    debug_assert_eq!(src.len(), dst.len());
    let rows = scales.len();
    if rows == 0 {
        debug_assert!(src.is_empty());
        return;
    }
    let cols = src.len() / rows;
    debug_assert_eq!(cols * rows, src.len());
    for (r, &sc) in scales.iter().enumerate() {
        i8_dequantize_into(&src[r * cols..(r + 1) * cols], sc,
                           &mut dst[r * cols..(r + 1) * cols]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels() {
        assert_eq!(Precision::parse("f16"), Some(Precision::F16));
        assert_eq!(Precision::parse("fp16"), Some(Precision::F16));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("int8pc"), Some(Precision::Int8Pc));
        assert_eq!(Precision::parse("i8pc"), Some(Precision::Int8Pc));
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("bf16"), None);
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.label()), Some(p));
        }
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn param_bytes_ordering() {
        assert_eq!(Precision::F32.param_bytes(), 4);
        assert_eq!(Precision::F16.param_bytes(), 2);
        assert_eq!(Precision::Int8.param_bytes(), 1);
    }

    #[test]
    fn wire_codes_roundtrip_and_stay_stable() {
        // on-disk contract: these numbers are baked into session images
        assert_eq!(Precision::F32.code(), 0);
        assert_eq!(Precision::F16.code(), 1);
        assert_eq!(Precision::Int8.code(), 2);
        assert_eq!(Precision::Int8Pc.code(), 3);
        for p in Precision::ALL {
            assert_eq!(Precision::from_code(p.code()), Some(p));
        }
        assert_eq!(Precision::from_code(4), None);
    }

    #[test]
    fn storage_bytes_count_the_int8_scale() {
        assert_eq!(Precision::F32.storage_bytes(10), 40);
        assert_eq!(Precision::F16.storage_bytes(10), 20);
        assert_eq!(Precision::Int8.storage_bytes(10), 14);
        assert_eq!(Precision::Int8.storage_bytes(0), 4);
        // int8pc: the 1-row layout (n_scales + scale + codes); exact
        // multi-row sizes come from Literal::storage_len
        assert_eq!(Precision::Int8Pc.storage_bytes(10), 18);
    }

    #[test]
    fn f16_known_values() {
        // (f32, f16 bits) pins from the IEEE 754 tables
        let cases: [(f32, u16); 8] = [
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (65504.0, 0x7BFF),         // f16 max
            (6.103_515_6e-5, 0x0400),  // smallest normal, 2^-14
            (5.960_464_5e-8, 0x0001),  // smallest subnormal, 2^-24
            (0.333_251_95, 0x3555),    // 1/3 rounded to f16
        ];
        for (x, h) in cases {
            assert_eq!(f32_to_f16_bits(x), h, "encode {x}");
            assert_eq!(f16_bits_to_f32(h).to_bits(), x.to_bits(),
                       "decode {h:#06x}");
        }
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between f16(1.0) and the next
        // representable 1 + 2^-10: RNE picks the even mantissa (1.0)
        let tie_down = 1.0f32 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_down), 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 (odd mantissa) and
        // 1+2^-9 (even mantissa): RNE rounds UP to the even one
        let tie_up = 1.0f32 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3C02);
        // just off the tie rounds to nearest as usual
        assert_eq!(f32_to_f16_bits(tie_down + 1e-7), 0x3C01);
        assert_eq!(f32_to_f16_bits(tie_down - 1e-7), 0x3C00);
    }

    #[test]
    fn f16_nan_inf_subnormal_edges() {
        // NaN -> canonical quiet NaN, still NaN after decode
        let h = f32_to_f16_bits(f32::NAN);
        assert_eq!(h & 0x7C00, 0x7C00);
        assert_ne!(h & 0x03FF, 0);
        assert!(f16_bits_to_f32(h).is_nan());
        // infinities map through with sign
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        // overflow -> inf (65520 ties between 65504 and 65536; the
        // 65504 mantissa is odd, so RNE overflows to inf)
        assert_eq!(f32_to_f16_bits(1e5), 0x7C00);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
        assert_eq!(f32_to_f16_bits(65519.0), 0x7BFF);
        // f32 values inside the f16-subnormal range round onto the
        // subnormal grid
        let x = 1.5 * 2f32.powi(-24); // 1.5 * smallest subnormal: tie
        assert_eq!(f32_to_f16_bits(x), 0x0002, "tie to even (2)");
        assert_eq!(f32_to_f16_bits(1.25 * 2f32.powi(-24)), 0x0001);
        // underflow: at or below 2^-25 becomes signed zero
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16_bits(-2f32.powi(-26)), 0x8000);
        // an f32 subnormal (way below 2^-25) underflows too
        assert_eq!(f32_to_f16_bits(f32::from_bits(1)), 0x0000);
    }

    #[test]
    fn f16_decode_encode_is_identity_for_all_bit_patterns() {
        // decode is exact, so re-encoding must reproduce every non-NaN
        // pattern bit-for-bit; NaNs must at least stay NaN
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(x), h,
                           "bits {h:#06x} decoded to {x} did not \
                            re-encode");
            }
        }
    }

    #[test]
    fn branchless_f16_decode_matches_scalar_exhaustively() {
        // every one of the 65536 f16 patterns, NaN payloads included
        for h in 0..=u16::MAX {
            let mut out = [0f32; 1];
            f16_decode_into(&[h], &mut out);
            assert_eq!(out[0].to_bits(), f16_bits_to_f32(h).to_bits(),
                       "decode {h:#06x}");
        }
    }

    #[test]
    fn branchless_f16_encode_matches_scalar() {
        // structured sweep: every f32 exponent x mantissa edge
        // patterns x both signs, hitting all rounding branches (tie
        // up/down, carry into exponent, subnormal grid, underflow,
        // overflow, inf, NaN payloads)
        let mans = [
            0u32, 1, 0xFFF, 0x1000, 0x1001, 0x1FFF, 0x2000, 0x2FFF,
            0x3000, 0x3001, 0x7F_FFFF, 0x40_0000, 0x12_3456,
        ];
        for exp in 0..=0xFFu32 {
            for &man in &mans {
                for sign in [0u32, 0x8000_0000] {
                    let bits = sign | exp << 23 | man;
                    let want = f32_to_f16_bits(f32::from_bits(bits));
                    let mut out = [0u16; 1];
                    f16_encode_into(&[f32::from_bits(bits)], &mut out);
                    assert_eq!(out[0], want, "encode bits {bits:#010x}");
                }
            }
        }
        // randomized cross-check over the full f32 space
        let mut state = 0x1234_5678_9ABC_DEF0u64;
        for _ in 0..2_000_000u32 {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let bits = (state >> 32) as u32;
            let x = f32::from_bits(bits);
            let mut out = [0u16; 1];
            f16_encode_into(&[x], &mut out);
            assert_eq!(out[0], f32_to_f16_bits(x),
                       "encode bits {bits:#010x}");
        }
    }

    #[test]
    fn finite_absmax_matches_filter_fold() {
        let mut state = 0xDEAD_BEEFu64;
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let mut v = Vec::with_capacity(len);
            for i in 0..len {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                let r = ((state >> 40) as f32) / (1u64 << 24) as f32;
                v.push(match i % 11 {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    2 => f32::NEG_INFINITY,
                    _ => (r - 0.5) * 8.0,
                });
            }
            let want = v
                .iter()
                .filter(|x| x.is_finite())
                .fold(0f32, |a, &x| a.max(x.abs()));
            assert_eq!(finite_absmax(&v).to_bits(), want.to_bits(),
                       "len {len}");
        }
    }

    #[test]
    fn per_row_quantize_matches_per_tensor_on_each_row() {
        // 3 rows x 5 cols with very different row magnitudes
        let src = [
            0.5f32, -1.0, 0.25, 0.9, -0.1, // absmax 1.0
            100.0, -50.0, 25.0, 0.0, 75.0, // absmax 100
            0.0, 0.0, 0.0, 0.0, 0.0,       // all-zero row
        ];
        let mut q = [0i8; 15];
        let mut scales = [0f32; 3];
        i8_quantize_rows_into(&src, &mut q, &mut scales);
        for r in 0..3 {
            let mut qr = [0i8; 5];
            let s = i8_quantize_into(&src[r * 5..(r + 1) * 5], &mut qr);
            assert_eq!(s.to_bits(), scales[r].to_bits(), "row {r}");
            assert_eq!(&q[r * 5..(r + 1) * 5], &qr, "row {r}");
        }
        assert_eq!(scales[2], 0.0);
        let mut deq = [0f32; 15];
        i8_dequantize_rows_into(&q, &scales, &mut deq);
        for r in 0..3 {
            let mut dr = [0f32; 5];
            i8_dequantize_into(&q[r * 5..(r + 1) * 5], scales[r],
                               &mut dr);
            assert_eq!(&deq[r * 5..(r + 1) * 5], &dr, "row {r}");
        }
        // per-channel beats per-tensor on the mixed-magnitude tensor
        let mut qt = [0i8; 15];
        let st = i8_quantize_into(&src, &mut qt);
        let mut deqt = [0f32; 15];
        i8_dequantize_into(&qt, st, &mut deqt);
        let rmse = |a: &[f32], b: &[f32]| {
            let s: f32 = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            (s / a.len() as f32).sqrt()
        };
        assert!(rmse(&src, &deq) < rmse(&src, &deqt),
                "per-channel should reduce quantization error here");
    }

    #[test]
    fn i8_roundtrip_is_stable() {
        let src = [0.5f32, -1.0, 0.25, 0.999, -0.123, 0.0];
        let mut q = [0i8; 6];
        let scale = i8_quantize_into(&src, &mut q);
        assert!(scale > 0.0);
        assert_eq!(q[1], -127, "the absmax element must hit the rail");
        let mut deq = [0f32; 6];
        i8_dequantize_into(&q, scale, &mut deq);
        // re-quantizing the dequantized tensor reproduces the codes
        let mut q2 = [0i8; 6];
        i8_quantize_into(&deq, &mut q2);
        assert_eq!(q, q2, "int8 boundary crossings must not drift");
        // error bounded by half a step
        for (x, d) in src.iter().zip(&deq) {
            assert!((x - d).abs() <= scale * 0.5 + 1e-7);
        }
    }

    #[test]
    fn i8_zero_tensor_and_nonfinite() {
        let mut q = [3i8; 4];
        let s = i8_quantize_into(&[0.0, 0.0, -0.0, 0.0], &mut q);
        assert_eq!(s, 0.0);
        assert_eq!(q, [0i8; 4]);
        let mut deq = [1f32; 4];
        i8_dequantize_into(&q, s, &mut deq);
        assert_eq!(deq, [0f32; 4], "scale 0 dequantizes to exact zeros");

        // non-finite inputs: NaN -> 0, +-inf clamps to the rails;
        // the scale comes from the finite elements only
        let src = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let mut q = [0i8; 4];
        let s = i8_quantize_into(&src, &mut q);
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(q, [0, 127, -127, 127]);

        // all-non-finite: nothing finite to scale by -> zeros
        let mut q = [5i8; 2];
        let s = i8_quantize_into(&[f32::NAN, f32::INFINITY], &mut q);
        assert_eq!(s, 0.0);
        assert_eq!(q, [0, 0]);
    }
}
