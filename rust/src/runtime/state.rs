//! Model state: the tensors held between steps, in two forms.
//!
//! * [`ModelState`] — parameters as host `Literal`s in manifest order;
//!   the currency of checkpoints, init loading, and the literal-based
//!   `run()` compatibility path.
//! * [`ExecState`] — the buffer-donation form the hot loop uses: raw
//!   backend-owned f32 tensors (params, and for derivative-based
//!   optimizers the Adam m/v moments) that `run_in_place` mutates
//!   directly across steps, plus the session's [`Scratch`] activation
//!   arena.  `Literal`s are materialized from it only at checkpoint /
//!   eval boundaries.

use anyhow::{bail, ensure, Result};

use super::literal::{f32_tensor, Literal};
use super::manifest::ConfigInfo;
use super::native::model::Scratch;

/// The live parameter set of one model instance.
pub struct ModelState {
    /// Tensors in manifest order.
    pub tensors: Vec<Literal>,
    pub n_params: usize,
}

impl ModelState {
    /// Build from raw per-tensor f32 data (e.g. `init_params.bin`).
    pub fn from_raw(cfg: &ConfigInfo, raw: &[Vec<f32>]) -> Result<ModelState> {
        if raw.len() != cfg.params.len() {
            bail!("expected {} tensors, got {}", cfg.params.len(), raw.len());
        }
        let mut tensors = Vec::with_capacity(raw.len());
        for (spec, data) in cfg.params.iter().zip(raw) {
            if data.len() != spec.elements() {
                bail!("tensor {} has {} values, expected {}", spec.name,
                      data.len(), spec.elements());
            }
            tensors.push(f32_tensor(data, &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    /// All-zero state with the same shapes (Adam m/v initialization).
    pub fn zeros_like(cfg: &ConfigInfo) -> Result<ModelState> {
        let mut tensors = Vec::with_capacity(cfg.params.len());
        for spec in &cfg.params {
            tensors.push(f32_tensor(&vec![0f32; spec.elements()],
                                    &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Borrow every tensor (for building program input lists).
    pub fn refs(&self) -> Vec<&Literal> {
        self.tensors.iter().collect()
    }

    /// Replace all tensors (with the step program's outputs).
    pub fn replace(&mut self, tensors: Vec<Literal>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("replace: {} tensors, expected {}", tensors.len(),
                  self.tensors.len());
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Serialize to the checkpoint format: raw f32 LE in manifest order
    /// (identical to `init_params.bin`).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.n_params * 4);
        for t in &self.tensors {
            t.f32_slice()?; // params are f32 by contract
            out.extend(t.to_le_bytes());
        }
        Ok(out)
    }

    /// Restore from [`ModelState::to_bytes`] output.
    pub fn from_bytes(cfg: &ConfigInfo, bytes: &[u8]) -> Result<ModelState> {
        if bytes.len() != cfg.n_params * 4 {
            bail!("checkpoint is {} bytes, expected {}", bytes.len(),
                  cfg.n_params * 4);
        }
        let mut raw = Vec::with_capacity(cfg.params.len());
        let mut cursor = 0usize;
        for spec in &cfg.params {
            let n = spec.elements();
            let mut v = vec![0f32; n];
            for (i, c) in
                bytes[cursor..cursor + 4 * n].chunks_exact(4).enumerate()
            {
                v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            cursor += 4 * n;
            raw.push(v);
        }
        ModelState::from_raw(cfg, &raw)
    }

    /// L2 norm of all parameters (drift diagnostics in tests/telemetry).
    pub fn l2_norm(&self) -> Result<f64> {
        let mut acc = 0f64;
        for t in &self.tensors {
            for &v in t.f32_slice()? {
                acc += (v as f64) * (v as f64);
            }
        }
        Ok(acc.sqrt())
    }

    pub fn checkpoint_bytes(&self) -> u64 {
        (self.n_params * 4) as u64
    }
}

/// Backend-owned mutable tensors for the `run_in_place` donation path.
///
/// The aliasing contract (XLA-style input/output aliasing): the tensors
/// in `w` (and `m`/`v` for Adam programs) ARE the step program's
/// donated inputs and its outputs — the program mutates them in place,
/// and the caller must not read them concurrently with a
/// `run_in_place` call.  Between calls they always hold the post-step
/// values.  `scratch` is the activation arena the native backend draws
/// forward/backward buffers from; it carries no semantic state (only
/// capacity), so dropping or swapping it never changes results.
pub struct ExecState {
    cfg: ConfigInfo,
    /// Parameter tensors, manifest order.
    pub w: Vec<Vec<f32>>,
    /// Adam first-moment tensors (empty for derivative-free sessions).
    pub m: Vec<Vec<f32>>,
    /// Adam second-moment tensors (empty for derivative-free sessions).
    pub v: Vec<Vec<f32>>,
    /// Reusable activation arena for the native backend.
    pub scratch: Scratch,
}

impl ExecState {
    /// Build from raw per-tensor f32 data, taking ownership (no copy).
    pub fn from_raw(cfg: &ConfigInfo, raw: Vec<Vec<f32>>)
        -> Result<ExecState>
    {
        ensure!(raw.len() == cfg.params.len(),
                "expected {} tensors, got {}", cfg.params.len(),
                raw.len());
        for (spec, data) in cfg.params.iter().zip(&raw) {
            ensure!(data.len() == spec.elements(),
                    "tensor {} has {} values, expected {}", spec.name,
                    data.len(), spec.elements());
        }
        Ok(ExecState {
            cfg: cfg.clone(),
            w: raw,
            m: Vec::new(),
            v: Vec::new(),
            scratch: Scratch::new(),
        })
    }

    /// Build from a literal-based [`ModelState`] (one copy — a
    /// boundary crossing, not a per-step cost).
    pub fn from_model(cfg: &ConfigInfo, params: &ModelState)
        -> Result<ExecState>
    {
        let mut raw = Vec::with_capacity(params.len());
        for t in &params.tensors {
            raw.push(t.f32_vec()?);
        }
        ExecState::from_raw(cfg, raw)
    }

    /// Attach zero-initialized Adam m/v moment tensors.
    pub fn with_adam(mut self) -> ExecState {
        self.m = self
            .cfg
            .params
            .iter()
            .map(|s| vec![0f32; s.elements()])
            .collect();
        self.v = self.m.clone();
        self
    }

    pub fn has_adam(&self) -> bool {
        !self.m.is_empty()
    }

    /// Split-borrow every mutable part at once — the shape the native
    /// backend's `run_in_place` needs (tensors and scratch arena are
    /// used simultaneously).
    pub fn native_parts(
        &mut self,
    ) -> (
        &mut Vec<Vec<f32>>,
        &mut Vec<Vec<f32>>,
        &mut Vec<Vec<f32>>,
        &mut Scratch,
    ) {
        (&mut self.w, &mut self.m, &mut self.v, &mut self.scratch)
    }

    /// Total donated tensors a step program sees: params, plus m and v
    /// when present.
    pub fn tensor_count(&self) -> usize {
        self.w.len() + self.m.len() + self.v.len()
    }

    /// Materialize every donated tensor as a `Literal`, in calling-
    /// convention order (w, then m, then v).  This is the compatibility
    /// bridge for backends without a native `run_in_place` (PJRT).
    pub fn donated_literals(&self) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(self.tensor_count());
        for set in [&self.w, &self.m, &self.v] {
            for (spec, data) in self.cfg.params.iter().zip(set.iter()) {
                out.push(Literal::from_f32(data.clone(),
                                           spec.shape.clone())?);
            }
        }
        Ok(out)
    }

    /// Materialize ONLY the parameter tensors (eval programs take
    /// params but never optimizer state).
    pub fn param_literals(&self) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(self.w.len());
        for (spec, data) in self.cfg.params.iter().zip(self.w.iter()) {
            out.push(Literal::from_f32(data.clone(),
                                       spec.shape.clone())?);
        }
        Ok(out)
    }

    /// Write a `run()` output tuple (minus the trailing loss scalar)
    /// back into the donated tensors — the scatter half of the
    /// compatibility bridge.
    pub fn absorb(&mut self, outs: Vec<Literal>) -> Result<()> {
        ensure!(outs.len() == self.tensor_count(),
                "absorb: {} tensors, state holds {}", outs.len(),
                self.tensor_count());
        let mut it = outs.into_iter();
        for set in [&mut self.w, &mut self.m, &mut self.v] {
            for (spec, slot) in self.cfg.params.iter().zip(set.iter_mut())
            {
                let data = it.next().expect("length checked").into_f32()?;
                ensure!(data.len() == spec.elements(),
                        "absorb: tensor {} has {} values, expected {}",
                        spec.name, data.len(), spec.elements());
                *slot = data;
            }
        }
        Ok(())
    }

    /// Snapshot the parameters as a literal-based [`ModelState`]
    /// (checkpoint/eval boundary).
    pub fn params_model(&self) -> Result<ModelState> {
        ModelState::from_raw(&self.cfg, &self.w)
    }

    /// Snapshot the Adam moments (errors for derivative-free state).
    pub fn adam_model(&self) -> Result<(ModelState, ModelState)> {
        ensure!(self.has_adam(), "state carries no Adam moments");
        Ok((
            ModelState::from_raw(&self.cfg, &self.m)?,
            ModelState::from_raw(&self.cfg, &self.v)?,
        ))
    }

    /// Overwrite the parameters from a [`ModelState`] (checkpoint
    /// restore).
    pub fn load_params(&mut self, params: &ModelState) -> Result<()> {
        ensure!(params.len() == self.w.len(),
                "load_params: {} tensors, state holds {}", params.len(),
                self.w.len());
        for ((spec, slot), t) in self
            .cfg
            .params
            .iter()
            .zip(self.w.iter_mut())
            .zip(&params.tensors)
        {
            let data = t.f32_vec()?;
            ensure!(data.len() == spec.elements(),
                    "load_params: tensor {} has {} values, expected {}",
                    spec.name, data.len(), spec.elements());
            *slot = data;
        }
        Ok(())
    }

    /// Overwrite the Adam moments (checkpoint restore).
    pub fn load_adam(&mut self, m: &ModelState, v: &ModelState)
        -> Result<()>
    {
        ensure!(self.has_adam(), "state carries no Adam moments");
        ensure!(m.len() == self.m.len() && v.len() == self.v.len(),
                "load_adam: moment tensor count mismatch");
        for (slot, t) in self.m.iter_mut().zip(&m.tensors) {
            *slot = t.f32_vec()?;
        }
        for (slot, t) in self.v.iter_mut().zip(&v.tensors) {
            *slot = t.f32_vec()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 10,
            params: vec![
                ParamSpecInfo { name: "a".into(), shape: vec![2, 3], offset: 0 },
                ParamSpecInfo { name: "b".into(), shape: vec![4], offset: 6 },
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let cfg = tiny_cfg();
        let raw = vec![vec![1., 2., 3., 4., 5., 6.], vec![7., 8., 9., 10.]];
        let st = ModelState::from_raw(&cfg, &raw).unwrap();
        assert_eq!(st.len(), 2);
        let bytes = st.to_bytes().unwrap();
        assert_eq!(bytes.len(), 40);
        let st2 = ModelState::from_bytes(&cfg, &bytes).unwrap();
        assert_eq!(st2.tensors[1].f32_vec().unwrap(), raw[1]);
        assert_eq!(st2.tensors[0].shape(), &[2, 3]);
    }

    #[test]
    fn zeros_like_shapes() {
        let st = ModelState::zeros_like(&tiny_cfg()).unwrap();
        assert_eq!(st.tensors[0].f32_vec().unwrap(), vec![0.0; 6]);
        assert!(st.l2_norm().unwrap() == 0.0);
    }

    #[test]
    fn wrong_sizes_rejected() {
        let cfg = tiny_cfg();
        assert!(ModelState::from_raw(&cfg, &[vec![0.0; 5]]).is_err());
        assert!(ModelState::from_bytes(&cfg, &[0u8; 8]).is_err());
        let raw = vec![vec![0.; 6], vec![0.; 3]];
        assert!(ModelState::from_raw(&cfg, &raw).is_err());
    }

    #[test]
    fn exec_state_roundtrips_through_literals() {
        let cfg = tiny_cfg();
        let raw = vec![vec![1., 2., 3., 4., 5., 6.], vec![7., 8., 9., 10.]];
        let st = ExecState::from_raw(&cfg, raw.clone()).unwrap();
        assert_eq!(st.tensor_count(), 2);
        assert!(!st.has_adam());
        let lits = st.donated_literals().unwrap();
        assert_eq!(lits.len(), 2);
        assert_eq!(lits[0].shape(), &[2, 3]);
        assert_eq!(lits[1].f32_vec().unwrap(), raw[1]);
        // snapshot -> ModelState -> back
        let ms = st.params_model().unwrap();
        let st2 = ExecState::from_model(&cfg, &ms).unwrap();
        assert_eq!(st2.w, raw);
    }

    #[test]
    fn exec_state_adam_moments_and_absorb() {
        let cfg = tiny_cfg();
        let raw = vec![vec![0f32; 6], vec![0f32; 4]];
        let mut st = ExecState::from_raw(&cfg, raw).unwrap().with_adam();
        assert!(st.has_adam());
        assert_eq!(st.tensor_count(), 6);
        // absorb a full w/m/v tuple
        let mut outs = Vec::new();
        for i in 0..6u32 {
            let (len, shape): (usize, Vec<usize>) = if i % 2 == 0 {
                (6, vec![2, 3])
            } else {
                (4, vec![4])
            };
            outs.push(
                Literal::from_f32(vec![i as f32; len], shape).unwrap(),
            );
        }
        st.absorb(outs).unwrap();
        assert_eq!(st.w[0], vec![0f32; 6]);
        assert_eq!(st.m[1], vec![3f32; 4]);
        assert_eq!(st.v[0], vec![4f32; 6]);
        // wrong arity rejected
        assert!(st.absorb(Vec::new()).is_err());
        let (m, v) = st.adam_model().unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn exec_state_load_params_validates() {
        let cfg = tiny_cfg();
        let mut st =
            ExecState::from_raw(&cfg, vec![vec![0f32; 6], vec![0f32; 4]])
                .unwrap();
        let ms = ModelState::from_raw(
            &cfg,
            &[vec![9f32; 6], vec![8f32; 4]],
        )
        .unwrap();
        st.load_params(&ms).unwrap();
        assert_eq!(st.w[0], vec![9f32; 6]);
        assert!(st.adam_model().is_err());
        assert!(st.load_adam(&ms, &ms).is_err());
    }
}
