//! Model state: the tensors held between steps, in two forms.
//!
//! * [`ModelState`] — parameters as host `Literal`s in manifest order;
//!   the currency of checkpoints, init loading, and the literal-based
//!   `run()` compatibility path.
//! * [`ExecState`] — the buffer-donation form the hot loop uses: raw
//!   backend-owned f32 tensors (params, and for derivative-based
//!   optimizers the Adam m/v moments) that `run_in_place` mutates
//!   directly across steps, plus the session's [`Scratch`] activation
//!   arena.  `Literal`s are materialized from it only at checkpoint /
//!   eval boundaries.

use anyhow::{bail, ensure, Context, Result};

use super::literal::{f32_tensor, Literal};
use super::manifest::ConfigInfo;
use super::native::model::Scratch;
use super::native::SpsaPool;
use super::precision::Precision;

/// The live parameter set of one model instance.
pub struct ModelState {
    /// Tensors in manifest order.
    pub tensors: Vec<Literal>,
    pub n_params: usize,
}

impl ModelState {
    /// Build from raw per-tensor f32 data (e.g. `init_params.bin`).
    pub fn from_raw(cfg: &ConfigInfo, raw: &[Vec<f32>]) -> Result<ModelState> {
        if raw.len() != cfg.params.len() {
            bail!("expected {} tensors, got {}", cfg.params.len(), raw.len());
        }
        let mut tensors = Vec::with_capacity(raw.len());
        for (spec, data) in cfg.params.iter().zip(raw) {
            if data.len() != spec.elements() {
                bail!("tensor {} has {} values, expected {}", spec.name,
                      data.len(), spec.elements());
            }
            tensors.push(f32_tensor(data, &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    /// All-zero state with the same shapes (Adam m/v initialization).
    pub fn zeros_like(cfg: &ConfigInfo) -> Result<ModelState> {
        let mut tensors = Vec::with_capacity(cfg.params.len());
        for spec in &cfg.params {
            tensors.push(f32_tensor(&vec![0f32; spec.elements()],
                                    &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Borrow every tensor (for building program input lists).
    pub fn refs(&self) -> Vec<&Literal> {
        self.tensors.iter().collect()
    }

    /// Replace all tensors (with the step program's outputs).
    pub fn replace(&mut self, tensors: Vec<Literal>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("replace: {} tensors, expected {}", tensors.len(),
                  self.tensors.len());
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Serialize to the checkpoint format: raw f32 LE in manifest order
    /// (identical to `init_params.bin`).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.n_params * 4);
        for t in &self.tensors {
            t.f32_slice()?; // params are f32 by contract
            out.extend(t.to_le_bytes());
        }
        Ok(out)
    }

    /// Restore from [`ModelState::to_bytes`] output.
    pub fn from_bytes(cfg: &ConfigInfo, bytes: &[u8]) -> Result<ModelState> {
        if bytes.len() != cfg.n_params * 4 {
            bail!("checkpoint is {} bytes, expected {}", bytes.len(),
                  cfg.n_params * 4);
        }
        let mut raw = Vec::with_capacity(cfg.params.len());
        let mut cursor = 0usize;
        for spec in &cfg.params {
            let n = spec.elements();
            let mut v = vec![0f32; n];
            for (i, c) in
                bytes[cursor..cursor + 4 * n].chunks_exact(4).enumerate()
            {
                v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            cursor += 4 * n;
            raw.push(v);
        }
        ModelState::from_raw(cfg, &raw)
    }

    /// L2 norm of all parameters (drift diagnostics in tests/telemetry).
    pub fn l2_norm(&self) -> Result<f64> {
        let mut acc = 0f64;
        for t in &self.tensors {
            for &v in t.f32_slice()? {
                acc += (v as f64) * (v as f64);
            }
        }
        Ok(acc.sqrt())
    }

    pub fn checkpoint_bytes(&self) -> u64 {
        (self.n_params * 4) as u64
    }
}

/// Backend-owned mutable tensors for the `run_in_place` donation path.
///
/// The aliasing contract (XLA-style input/output aliasing): the tensors
/// in `w` (and `m`/`v` for Adam programs) ARE the step program's
/// donated inputs and its outputs — the program mutates them in place,
/// and the caller must not read them concurrently with a
/// `run_in_place` call.  Between calls they always hold the post-step
/// values.  `scratch` is the activation arena the native backend draws
/// forward/backward buffers from; it carries no semantic state (only
/// capacity), so dropping or swapping it never changes results.
///
/// ## Precision residency
///
/// The state is built at a [`Precision`]:
///
/// * `F32` — `w` holds the resident parameters directly (the
///   historical zero-copy layout; trajectories are bit-identical to
///   the pre-precision API).
/// * `F16` / `Int8` — `qw` holds the quantized resident tensors
///   *between* steps; `w` is empty then.  [`materialize`]
///   (ExecState::materialize) dequantizes into transient f32 working
///   buffers for compute, and [`writeback`](ExecState::writeback)
///   re-quantizes into the existing storage (in place) and frees
///   them — so between steps the parameters really occupy only their
///   quantized bytes.  The Adam `m`/`v` moments always stay f32
///   (standard mixed-precision practice — quantizing second moments
///   destroys the update direction).
pub struct ExecState {
    cfg: ConfigInfo,
    precision: Precision,
    /// Parameter tensors, manifest order.  For `Precision::F32` this
    /// is the residency itself; for quantized precisions it holds the
    /// dequantized working set only while materialized.
    pub w: Vec<Vec<f32>>,
    /// Quantized resident parameters (empty for `Precision::F32`).
    qw: Vec<Literal>,
    /// Adam first-moment tensors (empty for derivative-free sessions).
    pub m: Vec<Vec<f32>>,
    /// Adam second-moment tensors (empty for derivative-free sessions).
    pub v: Vec<Vec<f32>>,
    /// Reusable activation arena for the native backend.
    pub scratch: Scratch,
    /// Pooled k-query SPSA worker shadows (empty until the first
    /// `mezo_step_q{k}` step; released with the working set for
    /// quantized precisions).  Like `scratch`, pure capacity — never
    /// semantic state.
    pub spsa: SpsaPool,
}

impl ExecState {
    /// Build from raw per-tensor f32 data, taking ownership (no copy).
    pub fn from_raw(cfg: &ConfigInfo, raw: Vec<Vec<f32>>)
        -> Result<ExecState>
    {
        ExecState::from_raw_at(cfg, raw, Precision::F32)
    }

    /// Build from raw f32 data stored at an explicit precision; for
    /// reduced precisions the data is quantized once here and the f32
    /// source dropped.
    pub fn from_raw_at(
        cfg: &ConfigInfo,
        raw: Vec<Vec<f32>>,
        precision: Precision,
    ) -> Result<ExecState> {
        ensure!(raw.len() == cfg.params.len(),
                "expected {} tensors, got {}", cfg.params.len(),
                raw.len());
        for (spec, data) in cfg.params.iter().zip(&raw) {
            ensure!(data.len() == spec.elements(),
                    "tensor {} has {} values, expected {}", spec.name,
                    data.len(), spec.elements());
        }
        let (w, qw) = match precision {
            Precision::F32 => (raw, Vec::new()),
            _ => {
                let qw = cfg
                    .params
                    .iter()
                    .zip(&raw)
                    .map(|(spec, data)| {
                        Literal::quantize_from_f32(data, &spec.shape,
                                                   precision)
                    })
                    .collect::<Result<Vec<_>>>()?;
                (Vec::new(), qw)
            }
        };
        Ok(ExecState {
            cfg: cfg.clone(),
            precision,
            w,
            qw,
            m: Vec::new(),
            v: Vec::new(),
            scratch: Scratch::new(),
            spsa: SpsaPool::new(),
        })
    }

    /// The parameter-storage precision this state was built at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Whether the quantized working set is currently materialized.
    fn materialized(&self) -> bool {
        !self.qw.is_empty() && !self.w.is_empty()
    }

    /// Dequantize the resident parameters into TRANSIENT f32 working
    /// buffers.  Deliberately not drawn from (or returned to) the
    /// scratch arena: parking parameter-sized f32 buffers in the pool
    /// between steps would keep 4 B/param of host memory alive and
    /// silently erase the residency saving that is this API's whole
    /// point.  The working set is allocated here and freed at
    /// [`writeback`](ExecState::writeback) /
    /// [`discard_materialized`](ExecState::discard_materialized), so
    /// between steps only the quantized storage is resident — a
    /// quantized step pays one O(params) allocation, which is noise
    /// next to the step's O(params × tokens) compute (the F32 path
    /// keeps its zero-allocation steady state).  No-op for
    /// `Precision::F32` or when already materialized.
    pub fn materialize(&mut self) -> Result<()> {
        if self.qw.is_empty() || self.materialized() {
            return Ok(());
        }
        let mut w = Vec::with_capacity(self.qw.len());
        for (i, q) in self.qw.iter().enumerate() {
            let mut buf = vec![0f32; q.element_count()];
            q.dequantize_into(&mut buf).with_context(|| {
                format!("materializing quantized tensor {i}")
            })?;
            w.push(buf);
        }
        self.w = w;
        Ok(())
    }

    /// Re-quantize the working set into the resident tensors (in
    /// place — the storage is overwritten, never reallocated) and
    /// free the f32 working buffers.  No-op for `Precision::F32` or
    /// when not materialized.
    pub fn writeback(&mut self) -> Result<()> {
        if !self.materialized() {
            return Ok(());
        }
        for (i, (q, buf)) in
            self.qw.iter_mut().zip(self.w.drain(..)).enumerate()
        {
            q.requantize_from_f32(&buf).with_context(|| {
                format!("writing back quantized tensor {i}")
            })?;
        }
        // pooled SPSA shadows are full-size f32 parameter copies;
        // letting them outlive the transient working set would erase
        // quantized residency, so they are freed with it (the F32
        // path never reaches here and keeps its pool warm)
        self.spsa.release();
        Ok(())
    }

    /// Drop the working buffers WITHOUT re-quantizing — for read-only
    /// programs (`loss_eval`) where a writeback would needlessly
    /// re-scale int8 storage.  No-op for `Precision::F32`.
    pub fn discard_materialized(&mut self) {
        if !self.materialized() {
            return;
        }
        self.w.clear();
        self.spsa.release();
    }

    /// Actual host bytes of the *resident* parameter storage (what a
    /// phone would keep allocated between steps): 4 B/param for f32,
    /// 2 for f16, 1 (+4/tensor scale) for int8.
    pub fn resident_param_bytes(&self) -> u64 {
        if self.qw.is_empty() {
            self.w.iter().map(|t| 4 * t.len() as u64).sum()
        } else {
            self.qw.iter().map(|q| q.resident_bytes()).sum()
        }
    }

    /// Everything this state keeps allocated between steps: the
    /// resident parameter storage PLUS the pooled k-query SPSA worker
    /// shadows.  The pool is charged here — once, at its current
    /// (high-water) size — so fleet residency telemetry counts pooled
    /// shadows as standing state instead of re-attributing a per-step
    /// clone; for quantized precisions the pool is released with the
    /// working set and contributes zero here.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_param_bytes() + self.spsa.resident_bytes()
    }

    /// Number of parameter tensor slots (independent of whether the
    /// working set is materialized right now).
    fn param_slots(&self) -> usize {
        if self.qw.is_empty() { self.w.len() } else { self.qw.len() }
    }

    /// Clone the resident parameter storage *at its precision* — the
    /// durable form a session image records: f32 residency yields f32
    /// literals, quantized residency yields the quantized literals
    /// verbatim (never a dequantized copy).
    pub fn storage_literals(&self) -> Result<Vec<Literal>> {
        if self.qw.is_empty() {
            ensure!(self.w.len() == self.cfg.params.len(),
                    "f32 state holds {} tensors, config has {}",
                    self.w.len(), self.cfg.params.len());
            self.cfg
                .params
                .iter()
                .zip(&self.w)
                .map(|(spec, data)| {
                    Literal::from_f32(data.clone(), spec.shape.clone())
                })
                .collect()
        } else {
            Ok(self.qw.clone())
        }
    }

    /// Consume the state into its storage parts: the resident
    /// parameter literals (at their precision, moved — zero copy) plus
    /// the Adam moments (empty vecs for derivative-free state).  The
    /// hibernate boundary; errors if a quantized working set is still
    /// materialized (hibernating mid-step would lose the working set).
    pub fn into_storage(
        mut self,
    ) -> Result<(Vec<Literal>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        ensure!(!self.materialized(),
                "hibernate while a working set is materialized");
        let m = std::mem::take(&mut self.m);
        let v = std::mem::take(&mut self.v);
        let params = if self.qw.is_empty() {
            ensure!(self.w.len() == self.cfg.params.len(),
                    "f32 state holds {} tensors, config has {}",
                    self.w.len(), self.cfg.params.len());
            let shapes: Vec<Vec<usize>> = self
                .cfg
                .params
                .iter()
                .map(|s| s.shape.clone())
                .collect();
            std::mem::take(&mut self.w)
                .into_iter()
                .zip(shapes)
                .map(|(data, shape)| Literal::from_f32(data, shape))
                .collect::<Result<Vec<_>>>()?
        } else {
            std::mem::take(&mut self.qw)
        };
        Ok((params, m, v))
    }

    /// Rebuild a state from [`into_storage`](ExecState::into_storage)
    /// parts (the rehydrate boundary).  The storage literals are
    /// installed verbatim — no quantize/dequantize round trip — so a
    /// hibernate → rehydrate cycle is bit-identical at every
    /// precision.  Tensors may arrive flat (durable forms store no
    /// shapes); they are re-attached to the config's shapes here.
    pub fn from_storage(
        cfg: &ConfigInfo,
        precision: Precision,
        params: Vec<Literal>,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> Result<ExecState> {
        let shaped = Self::shape_storage(cfg, precision, params)?;
        ensure!(m.len() == v.len(),
                "adam moments disagree: {} m vs {} v tensors", m.len(),
                v.len());
        for set in [&m, &v] {
            ensure!(set.is_empty() || set.len() == cfg.params.len(),
                    "expected {} moment tensors, got {}",
                    cfg.params.len(), set.len());
            for (spec, t) in cfg.params.iter().zip(set.iter()) {
                ensure!(t.len() == spec.elements(),
                        "moment tensor {} has {} values, expected {}",
                        spec.name, t.len(), spec.elements());
            }
        }
        let (w, qw) = match precision {
            Precision::F32 => (
                shaped
                    .into_iter()
                    .map(|l| l.into_f32())
                    .collect::<Result<Vec<_>>>()?,
                Vec::new(),
            ),
            _ => (Vec::new(), shaped),
        };
        Ok(ExecState {
            cfg: cfg.clone(),
            precision,
            w,
            qw,
            m,
            v,
            scratch: Scratch::new(),
            spsa: SpsaPool::new(),
        })
    }

    /// Overwrite the resident parameter storage verbatim (precision
    /// must match — this is the lossless restore path for durable
    /// forms written at the session's own precision; cross-precision
    /// restores go through [`load_params`](ExecState::load_params)).
    pub fn install_storage(&mut self, params: Vec<Literal>)
        -> Result<()>
    {
        ensure!(!self.materialized(),
                "install_storage while a working set is materialized");
        let shaped =
            Self::shape_storage(&self.cfg, self.precision, params)?;
        if self.qw.is_empty() {
            self.w = shaped
                .into_iter()
                .map(|l| l.into_f32())
                .collect::<Result<Vec<_>>>()?;
        } else {
            self.qw = shaped;
        }
        Ok(())
    }

    /// Validate storage literals against the config (count, element
    /// counts, storage precision) and attach the manifest shapes.
    fn shape_storage(
        cfg: &ConfigInfo,
        precision: Precision,
        params: Vec<Literal>,
    ) -> Result<Vec<Literal>> {
        ensure!(params.len() == cfg.params.len(),
                "expected {} tensors, got {}", cfg.params.len(),
                params.len());
        let mut shaped = Vec::with_capacity(params.len());
        for (spec, lit) in cfg.params.iter().zip(params) {
            ensure!(lit.element_count() == spec.elements(),
                    "tensor {} has {} elements, expected {}", spec.name,
                    lit.element_count(), spec.elements());
            ensure!(lit.storage_precision() == Some(precision),
                    "tensor {} stored as {:?}, state is {}", spec.name,
                    lit.dtype(), precision);
            shaped.push(lit.reshaped(spec.shape.clone())?);
        }
        Ok(shaped)
    }

    /// A zero-tensor placeholder used to steal a real state out of a
    /// `Drop` type (`Session::hibernate`).  Never executable.
    pub(crate) fn hollow() -> ExecState {
        ExecState {
            cfg: ConfigInfo {
                name: String::new(),
                kind: "encoder".into(),
                vocab: 0,
                d_model: 0,
                n_layers: 0,
                n_heads: 0,
                d_ff: 0,
                max_seq: 0,
                n_classes: 0,
                use_pallas: false,
                n_params: 0,
                params: Vec::new(),
            },
            precision: Precision::F32,
            w: Vec::new(),
            qw: Vec::new(),
            m: Vec::new(),
            v: Vec::new(),
            scratch: Scratch::new(),
            spsa: SpsaPool::new(),
        }
    }

    /// Build from a literal-based [`ModelState`] (one copy — a
    /// boundary crossing, not a per-step cost).
    pub fn from_model(cfg: &ConfigInfo, params: &ModelState)
        -> Result<ExecState>
    {
        let mut raw = Vec::with_capacity(params.len());
        for t in &params.tensors {
            raw.push(t.f32_vec()?);
        }
        ExecState::from_raw(cfg, raw)
    }

    /// Attach zero-initialized Adam m/v moment tensors.
    pub fn with_adam(mut self) -> ExecState {
        self.m = self
            .cfg
            .params
            .iter()
            .map(|s| vec![0f32; s.elements()])
            .collect();
        self.v = self.m.clone();
        self
    }

    pub fn has_adam(&self) -> bool {
        !self.m.is_empty()
    }

    /// Split-borrow every mutable part at once — the shape the native
    /// backend's `run_in_place` needs (tensors, scratch arena, and the
    /// SPSA shadow pool are used simultaneously).  Quantized states
    /// must be [`materialize`](ExecState::materialize)d first.
    pub fn native_parts(
        &mut self,
    ) -> (
        &mut Vec<Vec<f32>>,
        &mut Vec<Vec<f32>>,
        &mut Vec<Vec<f32>>,
        &mut Scratch,
        &mut SpsaPool,
    ) {
        (&mut self.w, &mut self.m, &mut self.v, &mut self.scratch,
         &mut self.spsa)
    }

    /// Total donated tensors a step program sees: params, plus m and v
    /// when present.
    pub fn tensor_count(&self) -> usize {
        self.param_slots() + self.m.len() + self.v.len()
    }

    /// An f32 snapshot of every parameter tensor (dequantized for
    /// reduced-precision residency), in manifest order.
    fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        if self.qw.is_empty() || self.materialized() {
            Ok(self.w.clone())
        } else {
            self.qw
                .iter()
                .map(|q| {
                    let mut buf = vec![0f32; q.element_count()];
                    q.dequantize_into(&mut buf)?;
                    Ok(buf)
                })
                .collect()
        }
    }

    /// Materialize every donated tensor as an f32 `Literal`, in
    /// calling-convention order (w, then m, then v).  This is the
    /// compatibility bridge for backends without a native
    /// `run_in_place` (PJRT): programs always compute in f32, so
    /// quantized residency is dequantized here and re-quantized in
    /// [`absorb`](ExecState::absorb).
    pub fn donated_literals(&self) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(self.tensor_count());
        for (spec, data) in
            self.cfg.params.iter().zip(self.params_f32()?)
        {
            out.push(Literal::from_f32(data, spec.shape.clone())?);
        }
        for set in [&self.m, &self.v] {
            for (spec, data) in self.cfg.params.iter().zip(set.iter()) {
                out.push(Literal::from_f32(data.clone(),
                                           spec.shape.clone())?);
            }
        }
        Ok(out)
    }

    /// Materialize ONLY the parameter tensors (eval programs take
    /// params but never optimizer state), dequantized to f32.
    pub fn param_literals(&self) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(self.param_slots());
        for (spec, data) in
            self.cfg.params.iter().zip(self.params_f32()?)
        {
            out.push(Literal::from_f32(data, spec.shape.clone())?);
        }
        Ok(out)
    }

    /// Write a `run()` output tuple (minus the trailing loss scalar)
    /// back into the donated tensors — the scatter half of the
    /// compatibility bridge.  Quantized residency re-quantizes the
    /// parameter outputs (same rounding as the native writeback, so
    /// the two paths stay bit-identical).
    pub fn absorb(&mut self, outs: Vec<Literal>) -> Result<()> {
        ensure!(outs.len() == self.tensor_count(),
                "absorb: {} tensors, state holds {}", outs.len(),
                self.tensor_count());
        let mut it = outs.into_iter();
        if self.qw.is_empty() {
            for (spec, slot) in
                self.cfg.params.iter().zip(self.w.iter_mut())
            {
                // lint:allow(D004): count ensured above the loop
                let data = it.next().expect("length checked").into_f32()?;
                ensure!(data.len() == spec.elements(),
                        "absorb: tensor {} has {} values, expected {}",
                        spec.name, data.len(), spec.elements());
                *slot = data;
            }
        } else {
            ensure!(!self.materialized(),
                    "absorb while a working set is materialized");
            for (spec, q) in
                self.cfg.params.iter().zip(self.qw.iter_mut())
            {
                // lint:allow(D004): count ensured above the loop
                let data = it.next().expect("length checked").into_f32()?;
                ensure!(data.len() == spec.elements(),
                        "absorb: tensor {} has {} values, expected {}",
                        spec.name, data.len(), spec.elements());
                q.requantize_from_f32(&data)?;
            }
        }
        for set in [&mut self.m, &mut self.v] {
            for (spec, slot) in self.cfg.params.iter().zip(set.iter_mut())
            {
                // lint:allow(D004): count ensured above the loop
                let data = it.next().expect("length checked").into_f32()?;
                ensure!(data.len() == spec.elements(),
                        "absorb: tensor {} has {} values, expected {}",
                        spec.name, data.len(), spec.elements());
                *slot = data;
            }
        }
        Ok(())
    }

    /// Snapshot the parameters as a literal-based [`ModelState`]
    /// (checkpoint/eval boundary).  Quantized residency dequantizes —
    /// checkpoints stay f32, the durable interchange format.
    pub fn params_model(&self) -> Result<ModelState> {
        ModelState::from_raw(&self.cfg, &self.params_f32()?)
    }

    /// Snapshot the Adam moments (errors for derivative-free state).
    pub fn adam_model(&self) -> Result<(ModelState, ModelState)> {
        ensure!(self.has_adam(), "state carries no Adam moments");
        Ok((
            ModelState::from_raw(&self.cfg, &self.m)?,
            ModelState::from_raw(&self.cfg, &self.v)?,
        ))
    }

    /// Overwrite the parameters from a [`ModelState`] (checkpoint
    /// restore).  Quantized residency re-quantizes the incoming f32
    /// tensors; restoring a checkpoint that was *written* by the same
    /// precision is lossless (f16 decode is exact and re-encodes to
    /// the identical bits; int8 codes reproduce — see `precision`).
    pub fn load_params(&mut self, params: &ModelState) -> Result<()> {
        ensure!(!self.materialized(),
                "load_params while a working set is materialized");
        ensure!(params.len() == self.param_slots(),
                "load_params: {} tensors, state holds {}", params.len(),
                self.param_slots());
        for (i, (spec, t)) in self
            .cfg
            .params
            .iter()
            .zip(&params.tensors)
            .enumerate()
        {
            let data = t.f32_vec()?;
            ensure!(data.len() == spec.elements(),
                    "load_params: tensor {} has {} values, expected {}",
                    spec.name, data.len(), spec.elements());
            if self.qw.is_empty() {
                self.w[i] = data;
            } else {
                self.qw[i].requantize_from_f32(&data)?;
            }
        }
        Ok(())
    }

    /// Overwrite the Adam moments (checkpoint restore).
    pub fn load_adam(&mut self, m: &ModelState, v: &ModelState)
        -> Result<()>
    {
        ensure!(self.has_adam(), "state carries no Adam moments");
        ensure!(m.len() == self.m.len() && v.len() == self.v.len(),
                "load_adam: moment tensor count mismatch");
        for (slot, t) in self.m.iter_mut().zip(&m.tensors) {
            *slot = t.f32_vec()?;
        }
        for (slot, t) in self.v.iter_mut().zip(&v.tensors) {
            *slot = t.f32_vec()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 10,
            params: vec![
                ParamSpecInfo { name: "a".into(), shape: vec![2, 3], offset: 0 },
                ParamSpecInfo { name: "b".into(), shape: vec![4], offset: 6 },
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let cfg = tiny_cfg();
        let raw = vec![vec![1., 2., 3., 4., 5., 6.], vec![7., 8., 9., 10.]];
        let st = ModelState::from_raw(&cfg, &raw).unwrap();
        assert_eq!(st.len(), 2);
        let bytes = st.to_bytes().unwrap();
        assert_eq!(bytes.len(), 40);
        let st2 = ModelState::from_bytes(&cfg, &bytes).unwrap();
        assert_eq!(st2.tensors[1].f32_vec().unwrap(), raw[1]);
        assert_eq!(st2.tensors[0].shape(), &[2, 3]);
    }

    #[test]
    fn zeros_like_shapes() {
        let st = ModelState::zeros_like(&tiny_cfg()).unwrap();
        assert_eq!(st.tensors[0].f32_vec().unwrap(), vec![0.0; 6]);
        assert!(st.l2_norm().unwrap() == 0.0);
    }

    #[test]
    fn wrong_sizes_rejected() {
        let cfg = tiny_cfg();
        assert!(ModelState::from_raw(&cfg, &[vec![0.0; 5]]).is_err());
        assert!(ModelState::from_bytes(&cfg, &[0u8; 8]).is_err());
        let raw = vec![vec![0.; 6], vec![0.; 3]];
        assert!(ModelState::from_raw(&cfg, &raw).is_err());
    }

    #[test]
    fn exec_state_roundtrips_through_literals() {
        let cfg = tiny_cfg();
        let raw = vec![vec![1., 2., 3., 4., 5., 6.], vec![7., 8., 9., 10.]];
        let st = ExecState::from_raw(&cfg, raw.clone()).unwrap();
        assert_eq!(st.tensor_count(), 2);
        assert!(!st.has_adam());
        let lits = st.donated_literals().unwrap();
        assert_eq!(lits.len(), 2);
        assert_eq!(lits[0].shape(), &[2, 3]);
        assert_eq!(lits[1].f32_vec().unwrap(), raw[1]);
        // snapshot -> ModelState -> back
        let ms = st.params_model().unwrap();
        let st2 = ExecState::from_model(&cfg, &ms).unwrap();
        assert_eq!(st2.w, raw);
    }

    #[test]
    fn exec_state_adam_moments_and_absorb() {
        let cfg = tiny_cfg();
        let raw = vec![vec![0f32; 6], vec![0f32; 4]];
        let mut st = ExecState::from_raw(&cfg, raw).unwrap().with_adam();
        assert!(st.has_adam());
        assert_eq!(st.tensor_count(), 6);
        // absorb a full w/m/v tuple
        let mut outs = Vec::new();
        for i in 0..6u32 {
            let (len, shape): (usize, Vec<usize>) = if i % 2 == 0 {
                (6, vec![2, 3])
            } else {
                (4, vec![4])
            };
            outs.push(
                Literal::from_f32(vec![i as f32; len], shape).unwrap(),
            );
        }
        st.absorb(outs).unwrap();
        assert_eq!(st.w[0], vec![0f32; 6]);
        assert_eq!(st.m[1], vec![3f32; 4]);
        assert_eq!(st.v[0], vec![4f32; 6]);
        // wrong arity rejected
        assert!(st.absorb(Vec::new()).is_err());
        let (m, v) = st.adam_model().unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn quantized_state_residency_roundtrip() {
        let cfg = tiny_cfg();
        // every value exactly representable in f16
        let raw = vec![
            vec![0.5f32, -1.0, 0.25, 0.125, 0.75, -0.5],
            vec![1.0, 0.0, -0.25, 0.5],
        ];
        let mut st =
            ExecState::from_raw_at(&cfg, raw.clone(), Precision::F16)
                .unwrap();
        assert_eq!(st.precision(), Precision::F16);
        assert_eq!(st.tensor_count(), 2);
        assert!(st.w.is_empty(), "no f32 residency between steps");
        assert_eq!(st.resident_param_bytes(), 2 * 10);
        // the f32 snapshot is exact for f16-representable values
        let ms = st.params_model().unwrap();
        assert_eq!(ms.tensors[0].f32_vec().unwrap(), raw[0]);
        assert_eq!(st.donated_literals().unwrap()[1].f32_vec().unwrap(),
                   raw[1]);
        // materialize -> mutate -> writeback persists
        st.materialize().unwrap();
        assert_eq!(st.w.len(), 2);
        assert_eq!(st.w[0], raw[0]);
        st.w[0][0] = 0.375;
        st.writeback().unwrap();
        assert!(st.w.is_empty());
        assert_eq!(
            st.params_model().unwrap().tensors[0].f32_vec().unwrap()[0],
            0.375
        );
        // discard returns buffers without writing back
        st.materialize().unwrap();
        st.w[0][0] = 99.0;
        st.discard_materialized();
        assert_eq!(
            st.params_model().unwrap().tensors[0].f32_vec().unwrap()[0],
            0.375
        );
        // load_params re-quantizes
        let ms2 = ModelState::from_raw(&cfg, &raw).unwrap();
        st.load_params(&ms2).unwrap();
        assert_eq!(st.params_model().unwrap().tensors[0].f32_vec()
                       .unwrap(),
                   raw[0]);
    }

    #[test]
    fn resident_bytes_follow_precision() {
        let cfg = tiny_cfg();
        let raw = vec![vec![0.5f32; 6], vec![0.25f32; 4]];
        let b = |p: Precision| {
            ExecState::from_raw_at(&cfg, raw.clone(), p)
                .unwrap()
                .resident_param_bytes()
        };
        assert_eq!(b(Precision::F32), 40);
        assert_eq!(b(Precision::F16), 20, "f16 is exactly half");
        // int8: one byte per element + a 4-byte scale per tensor
        assert_eq!(b(Precision::Int8), 10 + 2 * 4);
    }

    #[test]
    fn quantized_absorb_requantizes() {
        let cfg = tiny_cfg();
        let raw = vec![vec![0.5f32; 6], vec![0.25f32; 4]];
        let mut st =
            ExecState::from_raw_at(&cfg, raw, Precision::F16).unwrap();
        let outs = vec![
            Literal::from_f32(vec![0.125f32; 6], vec![2, 3]).unwrap(),
            Literal::from_f32(vec![2.0f32; 4], vec![4]).unwrap(),
        ];
        st.absorb(outs).unwrap();
        let ms = st.params_model().unwrap();
        assert_eq!(ms.tensors[0].f32_vec().unwrap(), vec![0.125f32; 6]);
        assert_eq!(ms.tensors[1].f32_vec().unwrap(), vec![2.0f32; 4]);
    }

    #[test]
    fn storage_roundtrip_is_verbatim_for_every_precision() {
        let cfg = tiny_cfg();
        let raw = vec![
            vec![0.51f32, -1.03, 0.27, 0.13, 0.74, -0.56],
            vec![1.01, 0.0, -0.26, 0.47],
        ];
        for p in Precision::ALL {
            let st =
                ExecState::from_raw_at(&cfg, raw.clone(), p).unwrap();
            let before = st.storage_literals().unwrap();
            let bytes_before: Vec<Vec<u8>> =
                before.iter().map(|l| l.to_le_bytes()).collect();
            // consume -> rebuild -> identical storage bits
            let (params, m, v) = st.into_storage().unwrap();
            assert!(m.is_empty() && v.is_empty());
            let st2 =
                ExecState::from_storage(&cfg, p, params, m, v).unwrap();
            let after = st2.storage_literals().unwrap();
            let bytes_after: Vec<Vec<u8>> =
                after.iter().map(|l| l.to_le_bytes()).collect();
            assert_eq!(bytes_before, bytes_after, "{p}");
            assert_eq!(st2.precision(), p);
            assert_eq!(st2.tensor_count(), 2);
            // shapes re-attached from the config
            assert_eq!(after[0].shape(), &[2, 3]);
        }
    }

    #[test]
    fn storage_roundtrip_carries_adam_moments() {
        let cfg = tiny_cfg();
        let raw = vec![vec![0.5f32; 6], vec![0.25f32; 4]];
        let mut st = ExecState::from_raw(&cfg, raw).unwrap().with_adam();
        st.m[0][0] = 7.0;
        st.v[1][3] = 9.0;
        let (params, m, v) = st.into_storage().unwrap();
        assert_eq!(m.len(), 2);
        let st2 = ExecState::from_storage(&cfg, Precision::F32, params,
                                          m, v)
            .unwrap();
        assert!(st2.has_adam());
        assert_eq!(st2.m[0][0], 7.0);
        assert_eq!(st2.v[1][3], 9.0);
    }

    #[test]
    fn from_storage_validates_shape_count_and_precision() {
        let cfg = tiny_cfg();
        let ok = |p: Precision| -> Vec<Literal> {
            vec![
                Literal::quantize_from_f32(&[0.5; 6], &[6], p).unwrap(),
                Literal::quantize_from_f32(&[0.5; 4], &[4], p).unwrap(),
            ]
        };
        // flat shapes are fine (re-attached), but wrong counts are not
        let st = ExecState::from_storage(&cfg, Precision::F16,
                                         ok(Precision::F16),
                                         vec![], vec![])
            .unwrap();
        assert_eq!(st.storage_literals().unwrap()[0].shape(), &[2, 3]);
        assert!(ExecState::from_storage(&cfg, Precision::F16,
                                        ok(Precision::F32), vec![],
                                        vec![])
            .is_err(), "precision mismatch must be rejected");
        let mut short = ok(Precision::F16);
        short.pop();
        assert!(ExecState::from_storage(&cfg, Precision::F16, short,
                                        vec![], vec![])
            .is_err());
        // lopsided moments rejected
        assert!(ExecState::from_storage(&cfg, Precision::F16,
                                        ok(Precision::F16),
                                        vec![vec![0.0; 6]], vec![])
            .is_err());
        // install_storage is the in-place form of the same contract
        let mut st = ExecState::from_raw_at(
            &cfg,
            vec![vec![0f32; 6], vec![0f32; 4]],
            Precision::F16,
        )
        .unwrap();
        st.install_storage(ok(Precision::F16)).unwrap();
        assert!(st.install_storage(ok(Precision::F32)).is_err());
    }

    #[test]
    fn exec_state_load_params_validates() {
        let cfg = tiny_cfg();
        let mut st =
            ExecState::from_raw(&cfg, vec![vec![0f32; 6], vec![0f32; 4]])
                .unwrap();
        let ms = ModelState::from_raw(
            &cfg,
            &[vec![9f32; 6], vec![8f32; 4]],
        )
        .unwrap();
        st.load_params(&ms).unwrap();
        assert_eq!(st.w[0], vec![9f32; 6]);
        assert!(st.adam_model().is_err());
        assert!(st.load_adam(&ms, &ms).is_err());
    }
}
