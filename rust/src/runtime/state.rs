//! Model state: the parameter tensors held between steps, plus binary
//! (de)serialization for checkpoints.
//!
//! Parameters live as host `Literal`s in manifest order.  The step
//! programs take them by reference and return fresh ones, so the hot
//! loop is: build refs → execute → swap in outputs.  No reshaping or
//! copying happens on the Rust side.

use anyhow::{bail, Result};

use super::literal::{f32_tensor, Literal};
use super::manifest::ConfigInfo;

/// The live parameter set of one model instance.
pub struct ModelState {
    /// Tensors in manifest order.
    pub tensors: Vec<Literal>,
    pub n_params: usize,
}

impl ModelState {
    /// Build from raw per-tensor f32 data (e.g. `init_params.bin`).
    pub fn from_raw(cfg: &ConfigInfo, raw: &[Vec<f32>]) -> Result<ModelState> {
        if raw.len() != cfg.params.len() {
            bail!("expected {} tensors, got {}", cfg.params.len(), raw.len());
        }
        let mut tensors = Vec::with_capacity(raw.len());
        for (spec, data) in cfg.params.iter().zip(raw) {
            if data.len() != spec.elements() {
                bail!("tensor {} has {} values, expected {}", spec.name,
                      data.len(), spec.elements());
            }
            tensors.push(f32_tensor(data, &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    /// All-zero state with the same shapes (Adam m/v initialization).
    pub fn zeros_like(cfg: &ConfigInfo) -> Result<ModelState> {
        let mut tensors = Vec::with_capacity(cfg.params.len());
        for spec in &cfg.params {
            tensors.push(f32_tensor(&vec![0f32; spec.elements()],
                                    &spec.shape)?);
        }
        Ok(ModelState { tensors, n_params: cfg.n_params })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Borrow every tensor (for building program input lists).
    pub fn refs(&self) -> Vec<&Literal> {
        self.tensors.iter().collect()
    }

    /// Replace all tensors (with the step program's outputs).
    pub fn replace(&mut self, tensors: Vec<Literal>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("replace: {} tensors, expected {}", tensors.len(),
                  self.tensors.len());
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Serialize to the checkpoint format: raw f32 LE in manifest order
    /// (identical to `init_params.bin`).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.n_params * 4);
        for t in &self.tensors {
            t.f32_slice()?; // params are f32 by contract
            out.extend(t.to_le_bytes());
        }
        Ok(out)
    }

    /// Restore from [`ModelState::to_bytes`] output.
    pub fn from_bytes(cfg: &ConfigInfo, bytes: &[u8]) -> Result<ModelState> {
        if bytes.len() != cfg.n_params * 4 {
            bail!("checkpoint is {} bytes, expected {}", bytes.len(),
                  cfg.n_params * 4);
        }
        let mut raw = Vec::with_capacity(cfg.params.len());
        let mut cursor = 0usize;
        for spec in &cfg.params {
            let n = spec.elements();
            let mut v = vec![0f32; n];
            for (i, c) in
                bytes[cursor..cursor + 4 * n].chunks_exact(4).enumerate()
            {
                v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            cursor += 4 * n;
            raw.push(v);
        }
        ModelState::from_raw(cfg, &raw)
    }

    /// L2 norm of all parameters (drift diagnostics in tests/telemetry).
    pub fn l2_norm(&self) -> Result<f64> {
        let mut acc = 0f64;
        for t in &self.tensors {
            for &v in t.f32_slice()? {
                acc += (v as f64) * (v as f64);
            }
        }
        Ok(acc.sqrt())
    }

    pub fn checkpoint_bytes(&self) -> u64 {
        (self.n_params * 4) as u64
    }
}

// Tests for ModelState need a ConfigInfo; covered in the integration
// suite (rust/tests/integration.rs) against the real manifest, where
// from_raw/to_bytes/from_bytes round-trip over pocket-tiny.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 10,
            params: vec![
                ParamSpecInfo { name: "a".into(), shape: vec![2, 3], offset: 0 },
                ParamSpecInfo { name: "b".into(), shape: vec![4], offset: 6 },
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let cfg = tiny_cfg();
        let raw = vec![vec![1., 2., 3., 4., 5., 6.], vec![7., 8., 9., 10.]];
        let st = ModelState::from_raw(&cfg, &raw).unwrap();
        assert_eq!(st.len(), 2);
        let bytes = st.to_bytes().unwrap();
        assert_eq!(bytes.len(), 40);
        let st2 = ModelState::from_bytes(&cfg, &bytes).unwrap();
        assert_eq!(st2.tensors[1].f32_vec().unwrap(), raw[1]);
        assert_eq!(st2.tensors[0].shape(), &[2, 3]);
    }

    #[test]
    fn zeros_like_shapes() {
        let st = ModelState::zeros_like(&tiny_cfg()).unwrap();
        assert_eq!(st.tensors[0].f32_vec().unwrap(), vec![0.0; 6]);
        assert!(st.l2_norm().unwrap() == 0.0);
    }

    #[test]
    fn wrong_sizes_rejected() {
        let cfg = tiny_cfg();
        assert!(ModelState::from_raw(&cfg, &[vec![0.0; 5]]).is_err());
        assert!(ModelState::from_bytes(&cfg, &[0u8; 8]).is_err());
        let raw = vec![vec![0.; 6], vec![0.; 3]];
        assert!(ModelState::from_raw(&cfg, &raw).is_err());
    }
}
