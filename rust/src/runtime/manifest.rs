//! `manifest.json` — the calling convention emitted by `python/compile/
//! aot.py` and consumed here.  Everything the coordinator knows about a
//! model (tensor order, shapes, dtypes, scalar inputs, artifact files)
//! comes from this file; nothing is hard-coded on the Rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of a program input/output or resident tensor.
///
/// `F32`/`I32`/`U32` are the step-program calling-convention types;
/// `F16`/`I8` are parameter *storage* types (see
/// [`Precision`](super::Precision)) — programs still compute in f32,
/// but resident tensors and checkpoint-adjacent plumbing may carry
/// them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    I32,
    U32,
    /// IEEE binary16 (parameter storage).
    F16,
    /// Symmetric per-tensor int8 (parameter storage, + f32 scale).
    I8,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            "f16" => Ok(Dtype::F16),
            "i8" => Ok(Dtype::I8),
            other => bail!("unknown dtype {other}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
            Dtype::F16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// One program input or output tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model parameter tensor (name, shape, flat offset into the virtual
/// parameter vector — the MeZO z-stream coordinate).
#[derive(Debug, Clone)]
pub struct ParamSpecInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpecInfo {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model configuration (mirrors python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ConfigInfo {
    pub name: String,
    pub kind: String, // "encoder" | "decoder"
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_classes: usize,
    pub use_pallas: bool,
    pub n_params: usize,
    pub params: Vec<ParamSpecInfo>,
}

impl ConfigInfo {
    pub fn is_decoder(&self) -> bool {
        self.kind == "decoder"
    }

    /// The device-simulator dimensions for this config (fp32 storage).
    pub fn model_dims(&self) -> crate::device::ModelDims {
        self.model_dims_at(crate::runtime::Precision::F32)
    }

    /// Device-simulator dimensions with the parameter byte-width taken
    /// from an explicit storage precision, so the simulated ledger
    /// charges what the host actually keeps resident.
    pub fn model_dims_at(
        &self,
        precision: crate::runtime::Precision,
    ) -> crate::device::ModelDims {
        crate::device::ModelDims {
            name: self.name.clone(),
            vocab: self.vocab,
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            max_seq: self.max_seq,
            decoder: self.is_decoder(),
            param_bytes: precision.param_bytes(),
        }
    }
}

/// One AOT program entry.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub config: String,
    pub kind: String, // mezo_step | adam_step | eval | loss_eval
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigInfo>,
    pub programs: Vec<ProgramSpec>,
    /// True for the in-code manifest ([`Manifest::builtin`]): init
    /// params are generated natively instead of read from disk.
    pub builtin: bool,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("tensor spec list")?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name").as_str().context("tensor name")?.into(),
                shape: t
                    .get("shape")
                    .as_arr()
                    .context("tensor shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: Dtype::parse(
                    t.get("dtype").as_str().context("dtype")?,
                )?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let dir = path
            .parent()
            .context("manifest has no parent dir")?
            .to_path_buf();

        if root.get("format").as_u64() != Some(1) {
            bail!("unsupported manifest format");
        }

        let mut configs = BTreeMap::new();
        for (name, c) in root.get("configs").as_obj().context("configs")? {
            let params = c
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpecInfo {
                        name: p.get("name").as_str().context("pname")?.into(),
                        shape: p
                            .get("shape")
                            .as_arr()
                            .context("pshape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        offset: p.get("offset").as_usize().context("off")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let info = ConfigInfo {
                name: name.clone(),
                kind: c.get("kind").as_str().context("kind")?.into(),
                vocab: c.get("vocab").as_usize().context("vocab")?,
                d_model: c.get("d_model").as_usize().context("d_model")?,
                n_layers: c.get("n_layers").as_usize().context("n_layers")?,
                n_heads: c.get("n_heads").as_usize().context("n_heads")?,
                d_ff: c.get("d_ff").as_usize().context("d_ff")?,
                max_seq: c.get("max_seq").as_usize().context("max_seq")?,
                n_classes: c.get("n_classes").as_usize().context("n_classes")?,
                use_pallas: c.get("use_pallas").as_bool().unwrap_or(false),
                n_params: c.get("n_params").as_usize().context("n_params")?,
                params,
            };
            // validate: offsets contiguous, total matches n_params
            let mut off = 0usize;
            for p in &info.params {
                if p.offset != off {
                    bail!("config {name}: param {} offset mismatch", p.name);
                }
                off += p.elements();
            }
            if off != info.n_params {
                bail!("config {name}: n_params {} != sum {}", info.n_params,
                      off);
            }
            configs.insert(name.clone(), info);
        }

        let mut programs = Vec::new();
        for p in root.get("programs").as_arr().context("programs")? {
            programs.push(ProgramSpec {
                config: p.get("config").as_str().context("config")?.into(),
                kind: p.get("kind").as_str().context("kind")?.into(),
                batch: p.get("batch").as_usize().context("batch")?,
                file: p.get("file").as_str().context("file")?.into(),
                inputs: tensor_specs(p.get("inputs"))?,
                outputs: tensor_specs(p.get("outputs"))?,
            });
        }

        for prog in &programs {
            if !configs.contains_key(&prog.config) {
                bail!("program {} references unknown config {}", prog.file,
                      prog.config);
            }
        }

        Ok(Manifest { dir, configs, programs, builtin: false })
    }

    /// Load `<path>` if it exists, else fall back to the hermetic
    /// builtin manifest (native backend, generated init params).
    pub fn load_or_builtin(path: impl AsRef<Path>) -> Result<Manifest> {
        if path.as_ref().exists() {
            Manifest::load(path)
        } else {
            Ok(Manifest::builtin())
        }
    }

    /// The in-code manifest: the same configs and (config, kind, batch)
    /// program grid `python/compile/aot.py` lowers (its DEFAULT_PLAN),
    /// with no files behind it.  The native backend interprets these
    /// programs directly, so a fresh checkout trains hermetically; the
    /// PJRT backend needs a real artifact directory instead.
    pub fn builtin() -> Manifest {
        use crate::runtime::native::params::make_config;
        let tiny = make_config("pocket-tiny", "encoder", 512, 64, 2, 2,
                               128, 32, 2, true);
        let tiny_fast = make_config("pocket-tiny-fast", "encoder", 512, 64,
                                    2, 2, 128, 32, 2, false);
        let roberta = make_config("pocket-roberta", "encoder", 4096, 256,
                                  6, 8, 1024, 64, 2, false);
        let opt = make_config("pocket-opt", "decoder", 4096, 256, 6, 8,
                              1024, 64, 2, false);

        let mut programs = Vec::new();
        let plan: &[(&ConfigInfo, &[&str], &[usize])] = &[
            (&tiny, &["mezo_step", "split_step", "eval", "loss_eval"],
             &[4]),
            (&tiny_fast,
             &["mezo_step", "adam_step", "split_step", "eval",
               "loss_eval"], &[4]),
            (&roberta,
             &["mezo_step", "adam_step", "split_step", "eval",
               "loss_eval"], &[8, 64]),
            (&roberta, &["mezo_step_naive", "mezo_step_q4"], &[8]),
            // decoders have no pooled split boundary: no split_step
            (&opt, &["mezo_step", "adam_step", "eval", "loss_eval"], &[8]),
        ];
        for (cfg, kinds, batches) in plan {
            for kind in *kinds {
                for &batch in *batches {
                    programs.push(builtin_program(cfg, kind, batch));
                }
            }
        }

        let mut configs = BTreeMap::new();
        for cfg in [tiny, tiny_fast, roberta, opt] {
            configs.insert(cfg.name.clone(), cfg);
        }
        Manifest {
            dir: PathBuf::from("builtin"),
            configs,
            programs,
            builtin: true,
        }
    }

    pub fn config(&self, name: &str) -> Result<&ConfigInfo> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("unknown model config '{name}'; known: {:?}",
                                   self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn find_program(
        &self,
        config: &str,
        kind: &str,
        batch: usize,
    ) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .find(|p| p.config == config && p.kind == kind && p.batch == batch)
    }

    /// Batch sizes available for a (config, kind).
    pub fn batches_for(&self, config: &str, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .programs
            .iter()
            .filter(|p| p.config == config && p.kind == kind)
            .map(|p| p.batch)
            .collect();
        v.sort();
        v
    }

    /// Initial parameters for a config: `<config>/init_params.bin` for
    /// artifact-backed manifests, deterministic native init for the
    /// builtin one.
    pub fn load_init_params(&self, config: &str) -> Result<Vec<Vec<f32>>> {
        let info = self.config(config)?;
        if self.builtin {
            return Ok(crate::runtime::native::params::init_params(info));
        }
        let path = self.dir.join(config).join("init_params.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != info.n_params * 4 {
            bail!(
                "init_params.bin is {} bytes, expected {}",
                bytes.len(),
                info.n_params * 4
            );
        }
        let mut out = Vec::with_capacity(info.params.len());
        let mut cursor = 0usize;
        for p in &info.params {
            let n = p.elements();
            let mut v = vec![0f32; n];
            for (i, chunk) in bytes[cursor..cursor + 4 * n]
                .chunks_exact(4)
                .enumerate()
            {
                v[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2],
                                           chunk[3]]);
            }
            cursor += 4 * n;
            out.push(v);
        }
        Ok(out)
    }
}

/// One builtin [`ProgramSpec`], mirroring `aot.py::program_signature`'s
/// input/output calling convention exactly.
fn builtin_program(cfg: &ConfigInfo, kind: &str, batch: usize)
    -> ProgramSpec
{
    let s = cfg.max_seq;
    let t = |name: &str, shape: Vec<usize>, dtype: Dtype| TensorSpec {
        name: name.into(),
        shape,
        dtype,
    };
    let param_io = |suffix: &str| -> Vec<TensorSpec> {
        cfg.params
            .iter()
            .map(|p| TensorSpec {
                name: format!("{}{}", p.name, suffix),
                shape: p.shape.clone(),
                dtype: Dtype::F32,
            })
            .collect()
    };
    let data_io = || {
        vec![t("ids", vec![batch, s], Dtype::I32),
             t("mask", vec![batch, s], Dtype::F32)]
    };
    let labels_io = || {
        if cfg.is_decoder() {
            t("labels", vec![batch, s], Dtype::I32)
        } else {
            t("labels", vec![batch], Dtype::I32)
        }
    };

    let (inputs, outputs) = if kind == "adam_step" {
        let mut ins = param_io("");
        ins.extend(param_io(".m"));
        ins.extend(param_io(".v"));
        ins.extend(data_io());
        ins.push(labels_io());
        ins.push(t("t", vec![1], Dtype::F32));
        ins.push(t("lr", vec![1], Dtype::F32));
        let mut outs = param_io("");
        outs.extend(param_io(".m"));
        outs.extend(param_io(".v"));
        outs.push(t("loss", vec![], Dtype::F32));
        (ins, outs)
    } else if kind == "eval" {
        let mut ins = param_io("");
        ins.extend(data_io());
        let outs = if cfg.is_decoder() {
            vec![t("logits", vec![batch, s, cfg.vocab], Dtype::F32)]
        } else {
            vec![t("logits", vec![batch, cfg.n_classes], Dtype::F32)]
        };
        (ins, outs)
    } else if kind == "loss_eval" {
        let mut ins = param_io("");
        ins.extend(data_io());
        ins.push(labels_io());
        (ins, vec![t("loss", vec![], Dtype::F32)])
    } else if kind == "split_step" {
        // frozen-backbone forward + side-module SGD: no seed, no eps
        let mut ins = param_io("");
        ins.extend(data_io());
        ins.push(labels_io());
        ins.push(t("lr", vec![1], Dtype::F32));
        let mut outs = param_io("");
        outs.push(t("loss", vec![], Dtype::F32));
        (ins, outs)
    } else {
        // the mezo_step family shares one signature
        let mut ins = param_io("");
        ins.extend(data_io());
        ins.push(labels_io());
        ins.push(t("seed", vec![1], Dtype::U32));
        ins.push(t("lr", vec![1], Dtype::F32));
        ins.push(t("eps", vec![1], Dtype::F32));
        let mut outs = param_io("");
        outs.push(t("loss", vec![], Dtype::F32));
        (ins, outs)
    };

    ProgramSpec {
        config: cfg.name.clone(),
        kind: kind.into(),
        batch,
        file: format!("{}/{}_bs{}.hlo.txt", cfg.name, kind, batch),
        inputs,
        outputs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "configs": {
        "m": {"kind": "encoder", "vocab": 8, "d_model": 4, "n_layers": 1,
              "n_heads": 2, "d_ff": 8, "max_seq": 4, "n_classes": 2,
              "use_pallas": false, "n_params": 44,
              "params": [
                {"name": "a", "shape": [8, 4], "offset": 0},
                {"name": "b", "shape": [12], "offset": 32}
              ]}
      },
      "programs": [
        {"config": "m", "kind": "mezo_step", "batch": 4,
         "file": "m/mezo_step_bs4.hlo.txt",
         "inputs": [{"name": "a", "shape": [8, 4], "dtype": "f32"}],
         "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
      ]
    }"#;

    fn write_sample(dir: &std::path::Path) -> PathBuf {
        let p = dir.join("manifest.json");
        std::fs::write(&p, SAMPLE).unwrap();
        p
    }

    #[test]
    fn loads_and_validates() {
        let dir = std::env::temp_dir().join("pocketllm_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::load(write_sample(&dir)).unwrap();
        assert_eq!(m.configs.len(), 1);
        let c = m.config("m").unwrap();
        assert_eq!(c.n_params, 44);
        assert!(m.find_program("m", "mezo_step", 4).is_some());
        assert!(m.find_program("m", "mezo_step", 8).is_none());
        assert_eq!(m.batches_for("m", "mezo_step"), vec![4]);
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn builtin_covers_the_default_plan() {
        let m = Manifest::builtin();
        assert!(m.builtin);
        for name in ["pocket-tiny", "pocket-tiny-fast", "pocket-roberta",
                     "pocket-opt"] {
            assert!(m.configs.contains_key(name), "missing {name}");
            assert!(!m.batches_for(name, "mezo_step").is_empty());
        }
        // the kernel-path config has no adam program (MeZO needs no AD)
        assert!(m.batches_for("pocket-tiny", "adam_step").is_empty());
        assert_eq!(m.batches_for("pocket-roberta", "mezo_step"),
                   vec![8, 64]);
        assert!(m.find_program("pocket-roberta", "mezo_step_q4", 8)
            .is_some());
        // calling conventions: params + ids/mask/labels + 3 scalars
        let p = m.find_program("pocket-tiny", "mezo_step", 4).unwrap();
        let n = m.config("pocket-tiny").unwrap().params.len();
        assert_eq!(p.inputs.len(), n + 6);
        assert_eq!(p.outputs.len(), n + 1);
        let a = m.find_program("pocket-opt", "adam_step", 8).unwrap();
        let nd = m.config("pocket-opt").unwrap().params.len();
        assert_eq!(a.inputs.len(), 3 * nd + 5);
        assert_eq!(a.outputs.len(), 3 * nd + 1);
        // decoder labels are [B, S]
        assert_eq!(a.inputs[3 * nd + 2].shape, vec![8, 64]);
        // split_step: every encoder config has it, decoders never do
        let sp = m.find_program("pocket-tiny", "split_step", 4).unwrap();
        assert_eq!(sp.inputs.len(), n + 4);
        assert_eq!(sp.outputs.len(), n + 1);
        assert_eq!(m.batches_for("pocket-roberta", "split_step"),
                   vec![8, 64]);
        assert!(m.batches_for("pocket-opt", "split_step").is_empty());
    }

    #[test]
    fn builtin_init_params_are_deterministic_and_sized() {
        let m = Manifest::builtin();
        let raw = m.load_init_params("pocket-tiny").unwrap();
        let cfg = m.config("pocket-tiny").unwrap();
        assert_eq!(raw.len(), cfg.params.len());
        let total: usize = raw.iter().map(|t| t.len()).sum();
        assert_eq!(total, cfg.n_params);
        assert_eq!(m.load_init_params("pocket-tiny").unwrap()[0], raw[0]);
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let m =
            Manifest::load_or_builtin("/definitely/not/here/manifest.json")
                .unwrap();
        assert!(m.builtin);
    }

    #[test]
    fn rejects_bad_offsets() {
        let dir = std::env::temp_dir().join("pocketllm_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = SAMPLE.replace("\"offset\": 32", "\"offset\": 31");
        let p = dir.join("manifest.json");
        std::fs::write(&p, bad).unwrap();
        assert!(Manifest::load(&p).is_err());
    }
}
