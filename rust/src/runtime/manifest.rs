//! `manifest.json` — the calling convention emitted by `python/compile/
//! aot.py` and consumed here.  Everything the coordinator knows about a
//! model (tensor order, shapes, dtypes, scalar inputs, artifact files)
//! comes from this file; nothing is hard-coded on the Rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of a program input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => bail!("unknown dtype {other}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// One program input or output tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model parameter tensor (name, shape, flat offset into the virtual
/// parameter vector — the MeZO z-stream coordinate).
#[derive(Debug, Clone)]
pub struct ParamSpecInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpecInfo {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model configuration (mirrors python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ConfigInfo {
    pub name: String,
    pub kind: String, // "encoder" | "decoder"
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_classes: usize,
    pub use_pallas: bool,
    pub n_params: usize,
    pub params: Vec<ParamSpecInfo>,
}

impl ConfigInfo {
    pub fn is_decoder(&self) -> bool {
        self.kind == "decoder"
    }

    /// The device-simulator dimensions for this config (fp32 artifacts).
    pub fn model_dims(&self) -> crate::device::ModelDims {
        crate::device::ModelDims {
            name: self.name.clone(),
            vocab: self.vocab,
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            max_seq: self.max_seq,
            decoder: self.is_decoder(),
            param_bytes: 4,
        }
    }
}

/// One AOT program entry.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub config: String,
    pub kind: String, // mezo_step | adam_step | eval | loss_eval
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigInfo>,
    pub programs: Vec<ProgramSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("tensor spec list")?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name").as_str().context("tensor name")?.into(),
                shape: t
                    .get("shape")
                    .as_arr()
                    .context("tensor shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: Dtype::parse(
                    t.get("dtype").as_str().context("dtype")?,
                )?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let dir = path
            .parent()
            .context("manifest has no parent dir")?
            .to_path_buf();

        if root.get("format").as_u64() != Some(1) {
            bail!("unsupported manifest format");
        }

        let mut configs = BTreeMap::new();
        for (name, c) in root.get("configs").as_obj().context("configs")? {
            let params = c
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(ParamSpecInfo {
                        name: p.get("name").as_str().context("pname")?.into(),
                        shape: p
                            .get("shape")
                            .as_arr()
                            .context("pshape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        offset: p.get("offset").as_usize().context("off")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let info = ConfigInfo {
                name: name.clone(),
                kind: c.get("kind").as_str().context("kind")?.into(),
                vocab: c.get("vocab").as_usize().context("vocab")?,
                d_model: c.get("d_model").as_usize().context("d_model")?,
                n_layers: c.get("n_layers").as_usize().context("n_layers")?,
                n_heads: c.get("n_heads").as_usize().context("n_heads")?,
                d_ff: c.get("d_ff").as_usize().context("d_ff")?,
                max_seq: c.get("max_seq").as_usize().context("max_seq")?,
                n_classes: c.get("n_classes").as_usize().context("n_classes")?,
                use_pallas: c.get("use_pallas").as_bool().unwrap_or(false),
                n_params: c.get("n_params").as_usize().context("n_params")?,
                params,
            };
            // validate: offsets contiguous, total matches n_params
            let mut off = 0usize;
            for p in &info.params {
                if p.offset != off {
                    bail!("config {name}: param {} offset mismatch", p.name);
                }
                off += p.elements();
            }
            if off != info.n_params {
                bail!("config {name}: n_params {} != sum {}", info.n_params,
                      off);
            }
            configs.insert(name.clone(), info);
        }

        let mut programs = Vec::new();
        for p in root.get("programs").as_arr().context("programs")? {
            programs.push(ProgramSpec {
                config: p.get("config").as_str().context("config")?.into(),
                kind: p.get("kind").as_str().context("kind")?.into(),
                batch: p.get("batch").as_usize().context("batch")?,
                file: p.get("file").as_str().context("file")?.into(),
                inputs: tensor_specs(p.get("inputs"))?,
                outputs: tensor_specs(p.get("outputs"))?,
            });
        }

        for prog in &programs {
            if !configs.contains_key(&prog.config) {
                bail!("program {} references unknown config {}", prog.file,
                      prog.config);
            }
        }

        Ok(Manifest { dir, configs, programs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigInfo> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("unknown model config '{name}'; known: {:?}",
                                   self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn find_program(
        &self,
        config: &str,
        kind: &str,
        batch: usize,
    ) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .find(|p| p.config == config && p.kind == kind && p.batch == batch)
    }

    /// Batch sizes available for a (config, kind).
    pub fn batches_for(&self, config: &str, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .programs
            .iter()
            .filter(|p| p.config == config && p.kind == kind)
            .map(|p| p.batch)
            .collect();
        v.sort();
        v
    }

    /// Read `<config>/init_params.bin` and split per tensor.
    pub fn load_init_params(&self, config: &str) -> Result<Vec<Vec<f32>>> {
        let info = self.config(config)?;
        let path = self.dir.join(config).join("init_params.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != info.n_params * 4 {
            bail!(
                "init_params.bin is {} bytes, expected {}",
                bytes.len(),
                info.n_params * 4
            );
        }
        let mut out = Vec::with_capacity(info.params.len());
        let mut cursor = 0usize;
        for p in &info.params {
            let n = p.elements();
            let mut v = vec![0f32; n];
            for (i, chunk) in bytes[cursor..cursor + 4 * n]
                .chunks_exact(4)
                .enumerate()
            {
                v[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2],
                                           chunk[3]]);
            }
            cursor += 4 * n;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "configs": {
        "m": {"kind": "encoder", "vocab": 8, "d_model": 4, "n_layers": 1,
              "n_heads": 2, "d_ff": 8, "max_seq": 4, "n_classes": 2,
              "use_pallas": false, "n_params": 44,
              "params": [
                {"name": "a", "shape": [8, 4], "offset": 0},
                {"name": "b", "shape": [12], "offset": 32}
              ]}
      },
      "programs": [
        {"config": "m", "kind": "mezo_step", "batch": 4,
         "file": "m/mezo_step_bs4.hlo.txt",
         "inputs": [{"name": "a", "shape": [8, 4], "dtype": "f32"}],
         "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
      ]
    }"#;

    fn write_sample(dir: &std::path::Path) -> PathBuf {
        let p = dir.join("manifest.json");
        std::fs::write(&p, SAMPLE).unwrap();
        p
    }

    #[test]
    fn loads_and_validates() {
        let dir = std::env::temp_dir().join("pocketllm_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::load(write_sample(&dir)).unwrap();
        assert_eq!(m.configs.len(), 1);
        let c = m.config("m").unwrap();
        assert_eq!(c.n_params, 44);
        assert!(m.find_program("m", "mezo_step", 4).is_some());
        assert!(m.find_program("m", "mezo_step", 8).is_none());
        assert_eq!(m.batches_for("m", "mezo_step"), vec![4]);
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_bad_offsets() {
        let dir = std::env::temp_dir().join("pocketllm_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = SAMPLE.replace("\"offset\": 32", "\"offset\": 31");
        let p = dir.join("manifest.json");
        std::fs::write(&p, bad).unwrap();
        assert!(Manifest::load(&p).is_err());
    }
}
