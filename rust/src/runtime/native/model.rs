//! The tiny-transformer forward/backward pass the step programs fuse.
//!
//! Semantics mirror `python/compile/model.py` + `kernels/ref.py` (the
//! pure-jnp oracle the Pallas kernels are tested against): pre-LN
//! encoder/decoder blocks, tanh-GELU FFN, masked scaled-dot-product
//! attention, masked mean-pool + linear head (encoder) or tied-embedding
//! LM logits (decoder), and mean softmax-xent loss.  The backward pass
//! is a hand-derived reverse of exactly this forward (validated against
//! `jax.value_and_grad` — see `rust/tests/native_golden.rs`), which is
//! what lets the native backend run `adam_step` without any autodiff
//! dependency.
//!
//! Parameters arrive as the manifest's ordered flat tensor list; the
//! index layout is the canonical one from [`super::params::param_specs`]
//! and is validated once at program-compile time via [`check_layout`].
//!
//! All activation and gradient buffers are drawn from a [`Scratch`]
//! arena threaded through [`logits`]/[`loss`]/[`loss_and_grad`]: after
//! the first call through a given arena, subsequent forwards/backwards
//! of the same geometry run with zero heap allocation (the perf-pass
//! property `scratch_steady_state_allocates_nothing` pins).  Buffer
//! provenance never changes arithmetic order, so results are
//! bit-identical to the historical allocating implementation.

use anyhow::{bail, Result};

use crate::runtime::manifest::ConfigInfo;

use super::math::{col_sums_into, dgelu, dot, gelu, matmul_at_into,
                  matmul_bias_into, matmul_bt_into, matmul_into};
use super::params;

const LN_EPS: f32 = 1e-5;
const NEG: f32 = -1e30;

// Fixed tensor indices within one layer (see params::param_specs).
const EMBED_TOK: usize = 0;
const EMBED_POS: usize = 1;
const LN1_G: usize = 0;
const LN1_B: usize = 1;
const WQ: usize = 2;
const BQ: usize = 3;
const WK: usize = 4;
const BK: usize = 5;
const WV: usize = 6;
const BV: usize = 7;
const WO: usize = 8;
const BO: usize = 9;
const LN2_G: usize = 10;
const LN2_B: usize = 11;
const W1: usize = 12;
const B1: usize = 13;
const W2: usize = 14;
const B2: usize = 15;

#[inline]
fn li(layer: usize, t: usize) -> usize {
    2 + layer * 16 + t
}

fn final_ln_g(cfg: &ConfigInfo) -> usize {
    2 + cfg.n_layers * 16
}

fn head_w(cfg: &ConfigInfo) -> usize {
    final_ln_g(cfg) + 2
}

/// A size-bucketed free list of f32 buffers — the forward/backward
/// scratch arena.
///
/// `take`/`take_raw` hand out a buffer of the requested length, reusing
/// a previously [`give`](Scratch::give)n one when the length matches;
/// the step programs' buffer demand is identical every call, so after
/// one warm-up pass every request hits the pool.  The arena is plain
/// owned state (`&mut` threads it through the pass), so there is no
/// synchronization and each session/worker owns its own.
#[derive(Debug, Default)]
pub struct Scratch {
    /// (buffer length, stack of free buffers of that length).
    pools: Vec<(usize, Vec<Vec<f32>>)>,
    misses: usize,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A zeroed buffer of `n` elements (for accumulation targets).
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.take_raw(n);
        v.fill(0.0);
        v
    }

    /// A buffer of `n` elements with UNSPECIFIED contents — use only
    /// when every element is overwritten before being read.
    pub fn take_raw(&mut self, n: usize) -> Vec<f32> {
        for (sz, pool) in self.pools.iter_mut() {
            if *sz == n {
                if let Some(v) = pool.pop() {
                    return v;
                }
                break;
            }
        }
        self.misses += 1;
        vec![0f32; n]
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, v: Vec<f32>) {
        let n = v.len();
        if n == 0 {
            return;
        }
        for (sz, pool) in self.pools.iter_mut() {
            if *sz == n {
                pool.push(v);
                return;
            }
        }
        self.pools.push((n, vec![v]));
    }

    /// Requests the pool could not serve (i.e. fresh heap allocations).
    /// Flat across repeated same-geometry calls == steady state.
    pub fn miss_count(&self) -> usize {
        self.misses
    }

    /// Total f32 elements currently parked in the pool.
    pub fn pooled_elements(&self) -> usize {
        self.pools
            .iter()
            .map(|(sz, pool)| sz * pool.len())
            .sum()
    }
}

/// Verify that a manifest config follows the canonical parameter layout
/// the interpreter indexes by.  Called once per program compile.
pub fn check_layout(cfg: &ConfigInfo) -> Result<()> {
    let want = params::param_specs(
        cfg.is_decoder(),
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        cfg.d_ff,
        cfg.max_seq,
        cfg.n_classes,
    );
    if cfg.params.len() != want.len() {
        bail!(
            "config {}: {} param tensors, canonical layout has {}",
            cfg.name,
            cfg.params.len(),
            want.len()
        );
    }
    for (got, want) in cfg.params.iter().zip(&want) {
        if got.name != want.name
            || got.shape != want.shape
            || got.offset != want.offset
        {
            bail!(
                "config {}: param {} (shape {:?}, offset {}) deviates from \
                 the canonical layout ({} {:?} @{}); the native backend \
                 requires the model.py tensor order",
                cfg.name, got.name, got.shape, got.offset, want.name,
                want.shape, want.offset
            );
        }
    }
    if cfg.d_model % cfg.n_heads != 0 {
        bail!("config {}: d_model {} not divisible by n_heads {}",
              cfg.name, cfg.d_model, cfg.n_heads);
    }
    Ok(())
}

/// Row-wise LayerNorm; returns (out, xhat, rstd-per-row).
fn layernorm(sc: &mut Scratch, x: &[f32], g: &[f32], b: &[f32], d: usize)
    -> (Vec<f32>, Vec<f32>, Vec<f32>)
{
    let rows = x.len() / d;
    let mut out = sc.take_raw(x.len());
    let mut xhat = sc.take_raw(x.len());
    let mut rstd = sc.take_raw(rows);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let or = &mut out[r * d..(r + 1) * d];
        for j in 0..d {
            let h = (xr[j] - mu) * rs;
            xh[j] = h;
            or[j] = h * g[j] + b[j];
        }
    }
    (out, xhat, rstd)
}

/// dx, dgamma, dbeta for [`layernorm`].
///
/// The first sweep stages `dxhat = dy * g` into the `dx` buffer while
/// accumulating the two row means and dgamma/dbeta, so the second
/// sweep reads it back instead of recomputing the product.  The m1/m2
/// accumulators stay single sequential chains — reassociating them
/// would change f32 bits.
fn layernorm_bwd(
    sc: &mut Scratch,
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rows = dy.len() / d;
    let mut dx = sc.take_raw(dy.len());
    let mut dg = sc.take(d);
    let mut db = sc.take(d);
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        let dxr = &mut dx[r * d..(r + 1) * d];
        let mut m1 = 0f32; // mean(dxhat)
        let mut m2 = 0f32; // mean(dxhat * xhat)
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dxr[j] = dxh;
            m1 += dxh;
            m2 += dxh * xhr[j];
            dg[j] += dyr[j] * xhr[j];
            db[j] += dyr[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let rs = rstd[r];
        for j in 0..d {
            dxr[j] = rs * (dxr[j] - m1 - xhr[j] * m2);
        }
    }
    (dx, dg, db)
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Per-layer activations retained for the backward pass.
struct LayerCache {
    h1: Vec<f32>,
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// [b, h, i, j] softmax attention weights, flattened.
    probs: Vec<f32>,
    /// Attention context in [B*S, D] layout (pre-Wo).
    a: Vec<f32>,
    h2: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    /// FFN pre-activation.
    u: Vec<f32>,
    /// gelu(u).
    f1: Vec<f32>,
}

struct EncCache {
    layers: Vec<LayerCache>,
    xhatf: Vec<f32>,
    rstdf: Vec<f32>,
}

/// Gather one head's rows into a contiguous [S, Dh] scratch buffer.
fn gather_head(
    sc: &mut Scratch,
    x: &[f32],
    b: usize,
    h: usize,
    s: usize,
    d: usize,
    dh: usize,
) -> Vec<f32> {
    let mut out = sc.take_raw(s * dh);
    for i in 0..s {
        let src = &x[(b * s + i) * d + h * dh..(b * s + i) * d + (h + 1) * dh];
        out[i * dh..(i + 1) * dh].copy_from_slice(src);
    }
    out
}

/// Scatter a contiguous [S, Dh] head buffer back into [B*S, D].
fn scatter_head(
    dst: &mut [f32],
    src: &[f32],
    b: usize,
    h: usize,
    s: usize,
    d: usize,
    dh: usize,
) {
    for i in 0..s {
        let dstr = &mut dst
            [(b * s + i) * d + h * dh..(b * s + i) * d + (h + 1) * dh];
        dstr.copy_from_slice(&src[i * dh..(i + 1) * dh]);
    }
}

/// Shared transformer trunk: ids/mask [B, S] -> hidden y [B*S, D].
fn encode(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    bsz: usize,
    s: usize,
    keep: bool,
    sc: &mut Scratch,
) -> (Vec<f32>, Option<EncCache>) {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let ff = cfg.d_ff;
    let causal = cfg.is_decoder();
    let scale = 1.0 / (dh as f32).sqrt();
    let bs = bsz * s;

    // embeddings
    let tok = &p[EMBED_TOK];
    let pos = &p[EMBED_POS];
    let mut x = sc.take_raw(bs * d);
    for b in 0..bsz {
        for i in 0..s {
            let r = b * s + i;
            let id = ids[r].max(0) as usize % cfg.vocab;
            let xr = &mut x[r * d..(r + 1) * d];
            let er = &tok[id * d..(id + 1) * d];
            let pr = &pos[i * d..(i + 1) * d];
            for j in 0..d {
                xr[j] = er[j] + pr[j];
            }
        }
    }

    let mut layers = Vec::new();
    for l in 0..cfg.n_layers {
        // --- attention block (pre-LN) ---
        let (h1, xhat1, rstd1) =
            layernorm(sc, &x, &p[li(l, LN1_G)], &p[li(l, LN1_B)], d);
        let mut q = sc.take_raw(bs * d);
        matmul_bias_into(&h1, &p[li(l, WQ)], &p[li(l, BQ)], bs, d, d,
                         &mut q);
        let mut k = sc.take_raw(bs * d);
        matmul_bias_into(&h1, &p[li(l, WK)], &p[li(l, BK)], bs, d, d,
                         &mut k);
        let mut v = sc.take_raw(bs * d);
        matmul_bias_into(&h1, &p[li(l, WV)], &p[li(l, BV)], bs, d, d,
                         &mut v);

        let mut a = sc.take_raw(bs * d);
        let mut probs_all = if keep {
            sc.take_raw(bsz * heads * s * s)
        } else {
            Vec::new()
        };
        for b in 0..bsz {
            let mrow = &mask[b * s..(b + 1) * s];
            for h in 0..heads {
                let qh = gather_head(sc, &q, b, h, s, d, dh);
                let kh = gather_head(sc, &k, b, h, s, d, dh);
                let vh = gather_head(sc, &v, b, h, s, d, dh);
                // scores[i,j] = q_i . k_j * scale, masked
                let mut scores = sc.take_raw(s * s);
                matmul_bt_into(&qh, &kh, s, dh, s, &mut scores);
                for i in 0..s {
                    let row = &mut scores[i * s..(i + 1) * s];
                    for j in 0..s {
                        row[j] *= scale;
                        if mrow[j] <= 0.0 || (causal && j > i) {
                            row[j] = NEG;
                        }
                    }
                    // softmax in place
                    let mx = row.iter().cloned().fold(NEG, f32::max);
                    let mut z = 0f32;
                    for pv in row.iter_mut() {
                        *pv = (*pv - mx).exp();
                        z += *pv;
                    }
                    for pv in row.iter_mut() {
                        *pv /= z;
                    }
                }
                let mut ah = sc.take(s * dh);
                matmul_into(&scores, &vh, s, s, dh, &mut ah);
                scatter_head(&mut a, &ah, b, h, s, d, dh);
                if keep {
                    let base = (b * heads + h) * s * s;
                    probs_all[base..base + s * s]
                        .copy_from_slice(&scores);
                }
                sc.give(qh);
                sc.give(kh);
                sc.give(vh);
                sc.give(scores);
                sc.give(ah);
            }
        }
        let mut o = sc.take_raw(bs * d);
        matmul_bias_into(&a, &p[li(l, WO)], &p[li(l, BO)], bs, d, d,
                         &mut o);
        add_into(&mut x, &o);
        sc.give(o);

        // --- ffn block (pre-LN) ---
        let (h2, xhat2, rstd2) =
            layernorm(sc, &x, &p[li(l, LN2_G)], &p[li(l, LN2_B)], d);
        let mut u = sc.take_raw(bs * ff);
        matmul_bias_into(&h2, &p[li(l, W1)], &p[li(l, B1)], bs, d, ff,
                         &mut u);
        let mut f1 = sc.take_raw(bs * ff);
        for (f, &uv) in f1.iter_mut().zip(u.iter()) {
            *f = gelu(uv);
        }
        let mut f2 = sc.take_raw(bs * d);
        matmul_bias_into(&f1, &p[li(l, W2)], &p[li(l, B2)], bs, ff, d,
                         &mut f2);
        add_into(&mut x, &f2);
        sc.give(f2);

        if keep {
            layers.push(LayerCache {
                h1,
                xhat1,
                rstd1,
                q,
                k,
                v,
                probs: probs_all,
                a,
                h2,
                xhat2,
                rstd2,
                u,
                f1,
            });
        } else {
            sc.give(h1);
            sc.give(xhat1);
            sc.give(rstd1);
            sc.give(q);
            sc.give(k);
            sc.give(v);
            sc.give(a);
            sc.give(h2);
            sc.give(xhat2);
            sc.give(rstd2);
            sc.give(u);
            sc.give(f1);
        }
    }

    let fln = final_ln_g(cfg);
    let (y, xhatf, rstdf) = layernorm(sc, &x, &p[fln], &p[fln + 1], d);
    sc.give(x);
    let cache = if keep {
        Some(EncCache { layers, xhatf, rstdf })
    } else {
        sc.give(xhatf);
        sc.give(rstdf);
        None
    };
    (y, cache)
}

/// Masked mean-pool denominators per batch row, staged in a scratch
/// buffer (`give` it back) so steady-state steps stay allocation-free.
fn pool_denoms(
    sc: &mut Scratch,
    mask: &[f32],
    bsz: usize,
    s: usize,
) -> Vec<f32> {
    let mut denoms = sc.take_raw(bsz);
    for (b, dn) in denoms.iter_mut().enumerate() {
        let sum: f32 = mask[b * s..(b + 1) * s].iter().sum();
        *dn = sum.max(1.0);
    }
    denoms
}

/// Task logits: encoder [B, n_classes]; decoder [B, S, vocab] (tied
/// embedding).  Flattened row-major.  The returned buffer belongs to
/// the caller (pass it back via [`Scratch::give`] to keep steady-state
/// allocation at zero).
pub fn logits(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> Vec<f32> {
    let (y, _) = encode(cfg, p, ids, mask, bsz, s, false, sc);
    let lg = logits_from_y(cfg, p, &y, mask, bsz, s, sc);
    sc.give(y);
    lg
}

fn logits_from_y(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    y: &[f32],
    mask: &[f32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> Vec<f32> {
    let d = cfg.d_model;
    if cfg.is_decoder() {
        // [B*S, V] = y @ E^T
        let mut lg = sc.take_raw(bsz * s * cfg.vocab);
        matmul_bt_into(y, &p[EMBED_TOK], bsz * s, d, cfg.vocab, &mut lg);
        return lg;
    }
    let denoms = pool_denoms(sc, mask, bsz, s);
    let mut pooled = sc.take(bsz * d);
    for b in 0..bsz {
        let pr = &mut pooled[b * d..(b + 1) * d];
        for i in 0..s {
            let m = mask[b * s + i];
            if m > 0.0 {
                let yr = &y[(b * s + i) * d..(b * s + i + 1) * d];
                for j in 0..d {
                    pr[j] += yr[j] * m;
                }
            }
        }
        for v in pr.iter_mut() {
            *v /= denoms[b];
        }
    }
    let hw = head_w(cfg);
    let mut lg = sc.take_raw(bsz * cfg.n_classes);
    matmul_bias_into(&pooled, &p[hw], &p[hw + 1], bsz, d, cfg.n_classes,
                     &mut lg);
    sc.give(pooled);
    sc.give(denoms);
    lg
}

/// The (row, label, weight) view of the loss: encoder classifies each
/// batch row; decoder predicts token t+1 from position t with padding
/// masked out.  A callback instead of a materialized `Vec` so the
/// per-step loss passes allocate nothing; visit order is the row
/// order the old `Vec` had, which keeps every downstream f32
/// accumulation bit-identical.
fn for_each_loss_row(
    cfg: &ConfigInfo,
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    mut f: impl FnMut(usize, i32, f32),
) {
    if cfg.is_decoder() {
        for b in 0..bsz {
            for i in 0..s - 1 {
                let r = b * s + i;
                f(r, labels[r + 1], mask[r + 1] * mask[r]);
            }
        }
    } else {
        for b in 0..bsz {
            f(b, labels[b], 1.0);
        }
    }
}

fn nll_of_row(row: &[f32], label: i32) -> f32 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0f32;
    for &v in row {
        z += (v - mx).exp();
    }
    let lse = z.ln() + mx;
    lse - row[label.max(0) as usize % row.len()]
}

/// Scalar training loss (the loss_eval program body).
pub fn loss(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> f32 {
    let lg = logits(cfg, p, ids, mask, bsz, s, sc);
    let ncols = if cfg.is_decoder() { cfg.vocab } else { cfg.n_classes };
    let mut acc = 0f32;
    let mut msum = 0f32;
    for_each_loss_row(cfg, mask, labels, bsz, s, |r, label, w| {
        if w > 0.0 {
            acc += w * nll_of_row(&lg[r * ncols..(r + 1) * ncols], label);
        }
        msum += w;
    });
    sc.give(lg);
    acc / msum.max(1.0)
}

/// Frozen-backbone forward to the split boundary: the masked mean-pooled
/// hidden state h [B, D] that crosses the link in split tuning.  Encoder
/// only — a decoder's per-token LM head has no pooled boundary, so split
/// jobs never run on decoder configs.  The returned buffer belongs to
/// the caller (`give` it back).
pub fn pooled_hidden(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> Result<Vec<f32>> {
    if cfg.is_decoder() {
        bail!("config {}: split tuning requires an encoder (pooled \
               boundary); decoders have no split point", cfg.name);
    }
    let d = cfg.d_model;
    let (y, _) = encode(cfg, p, ids, mask, bsz, s, false, sc);
    let denoms = pool_denoms(sc, mask, bsz, s);
    let mut pooled = sc.take(bsz * d);
    for b in 0..bsz {
        let pr = &mut pooled[b * d..(b + 1) * d];
        for i in 0..s {
            let m = mask[b * s + i];
            if m > 0.0 {
                let yr = &y[(b * s + i) * d..(b * s + i + 1) * d];
                for j in 0..d {
                    pr[j] += yr[j] * m;
                }
            }
        }
        for v in pr.iter_mut() {
            *v /= denoms[b];
        }
    }
    sc.give(denoms);
    sc.give(y);
    Ok(pooled)
}

/// The server-side half of one split step: side-module (head) forward +
/// fused softmax-xent + head gradients, given the pooled activations
/// that crossed the link.  Arithmetic is element-for-element the
/// encoder branch of [`loss_and_grad`], so the returned loss and the
/// (dW, db) pair are bit-identical to that oracle's `grads[head_w]` /
/// `grads[head_w + 1]` — the equivalence `split_head_matches_full_
/// backward` pins.  Buffers come from `sc`; `give` them back.
pub fn split_head_backward_from(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    h: &[f32],
    labels: &[i32],
    bsz: usize,
    sc: &mut Scratch,
) -> (f32, Vec<f32>, Vec<f32>) {
    let d = cfg.d_model;
    let nc = cfg.n_classes;
    let hw = head_w(cfg);
    let mut lg = sc.take_raw(bsz * nc);
    matmul_bias_into(h, &p[hw], &p[hw + 1], bsz, d, nc, &mut lg);

    // fused softmax-xent, mirroring loss_and_grad's encoder rows
    // (weight 1.0 per batch row, msum = bsz)
    let msum = (bsz as f32).max(1.0);
    let mut acc = 0f32;
    let mut dlogits = sc.take(lg.len());
    for b in 0..bsz {
        let coeff = 1.0 / msum;
        let row = &lg[b * nc..(b + 1) * nc];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let drow = &mut dlogits[b * nc..(b + 1) * nc];
        let mut z = 0f32;
        for (dv, &v) in drow.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *dv = e;
            z += e;
        }
        let label = labels[b].max(0) as usize % nc;
        acc += z.ln() + mx - row[label];
        for dv in drow.iter_mut() {
            *dv = *dv / z * coeff;
        }
        drow[label] -= coeff;
    }
    let loss = acc / msum;
    sc.give(lg);

    let mut dw = sc.take(d * nc);
    matmul_at_into(h, &dlogits, bsz, d, nc, &mut dw);
    let mut db = sc.take(nc);
    col_sums_into(&dlogits, nc, &mut db);
    sc.give(dlogits);
    (loss, dw, db)
}

/// Loss + side-module gradients for one split step: device half
/// ([`pooled_hidden`]) piped into the server half
/// ([`split_head_backward_from`]).
pub fn split_head_backward(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> Result<(f32, Vec<f32>, Vec<f32>)> {
    let h = pooled_hidden(cfg, p, ids, mask, bsz, s, sc)?;
    let out = split_head_backward_from(cfg, p, &h, labels, bsz, sc);
    sc.give(h);
    Ok(out)
}

/// One full split step — the `split_step` program body: frozen-backbone
/// forward, side-module backward, plain-SGD update of the head weight
/// and bias.  Returns the pre-update loss.
pub fn split_head_step(
    cfg: &ConfigInfo,
    p: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    lr: f32,
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> Result<f32> {
    let (loss, dw, db) =
        split_head_backward(cfg, &*p, ids, mask, labels, bsz, s, sc)?;
    let hw = head_w(cfg);
    for (w, &g) in p[hw].iter_mut().zip(&dw) {
        *w -= lr * g;
    }
    for (w, &g) in p[hw + 1].iter_mut().zip(&db) {
        *w -= lr * g;
    }
    sc.give(dw);
    sc.give(db);
    Ok(loss)
}

/// Tensor index of the split side module's weight within the canonical
/// layout (the head weight; the bias follows at `+ 1`).  Public so the
/// session layer can size link transfers exactly.
pub fn side_module_index(cfg: &ConfigInfo) -> usize {
    head_w(cfg)
}

/// Loss + parameter gradients — the hand-derived reverse pass that lets
/// the native backend run `adam_step` without autodiff.  The gradient
/// buffers come from `sc`; the caller should `give` them back once
/// applied.
pub fn loss_and_grad(
    cfg: &ConfigInfo,
    p: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    sc: &mut Scratch,
) -> (f32, Vec<Vec<f32>>) {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let ff = cfg.d_ff;
    let bs = bsz * s;
    let scale = 1.0 / (dh as f32).sqrt();

    let (y, cache) = encode(cfg, p, ids, mask, bsz, s, true, sc);
    // lint:allow(D004): encode(keep=true) always returns Some
    let cache = cache.expect("keep=true retains the cache");
    let lg = logits_from_y(cfg, p, &y, mask, bsz, s, sc);

    let ncols = if cfg.is_decoder() { cfg.vocab } else { cfg.n_classes };
    let mut msum = 0f32;
    for_each_loss_row(cfg, mask, labels, bsz, s, |_, _, w| msum += w);
    let msum = msum.max(1.0);

    // Fused softmax-xent: one sweep computes the loss AND dlogits,
    // staging the exps directly in the dlogits row instead of a
    // per-row temporary.  The max fold, the sequential exp sum, and
    // the `e / z * coeff` scaling are arithmetic-for-arithmetic the
    // old two-pass form, so f32 results stay bit-identical.
    let mut acc = 0f32;
    let mut dlogits = sc.take(lg.len());
    for_each_loss_row(cfg, mask, labels, bsz, s, |r, label, w| {
        let coeff = w / msum;
        if w <= 0.0 && coeff == 0.0 {
            return; // row contributes nothing; dlogits row stays 0
        }
        let row = &lg[r * ncols..(r + 1) * ncols];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let drow = &mut dlogits[r * ncols..(r + 1) * ncols];
        let mut z = 0f32;
        for (dv, &v) in drow.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *dv = e;
            z += e;
        }
        if w > 0.0 {
            acc += w * (z.ln() + mx - row[label.max(0) as usize % ncols]);
        }
        if coeff == 0.0 {
            // A positive weight can still underflow to coeff == 0;
            // the staged exps must not leak into the gradient.
            for dv in drow.iter_mut() {
                *dv = 0.0;
            }
            return;
        }
        for dv in drow.iter_mut() {
            *dv = *dv / z * coeff;
        }
        drow[label.max(0) as usize % ncols] -= coeff;
    });
    let loss = acc / msum;
    sc.give(lg);

    let mut grads: Vec<Vec<f32>> = cfg
        .params
        .iter()
        .map(|spec| sc.take(spec.elements()))
        .collect();

    // task head backward -> dy [B*S, D]
    let mut dy;
    if cfg.is_decoder() {
        // logits = y @ E^T : dy = dlogits @ E ; dE += dlogits^T y
        dy = sc.take(bs * d);
        matmul_into(&dlogits, &p[EMBED_TOK], bs, cfg.vocab, d, &mut dy);
        matmul_at_into(&dlogits, &y, bs, cfg.vocab, d,
                       &mut grads[EMBED_TOK]);
    } else {
        let denoms = pool_denoms(sc, mask, bsz, s);
        let mut pooled = sc.take(bsz * d);
        for b in 0..bsz {
            let pr = &mut pooled[b * d..(b + 1) * d];
            for i in 0..s {
                let m = mask[b * s + i];
                if m > 0.0 {
                    let yr = &y[(b * s + i) * d..(b * s + i + 1) * d];
                    for j in 0..d {
                        pr[j] += yr[j] * m;
                    }
                }
            }
            for v in pr.iter_mut() {
                *v /= denoms[b];
            }
        }
        let hw = head_w(cfg);
        matmul_at_into(&pooled, &dlogits, bsz, d, cfg.n_classes,
                       &mut grads[hw]);
        col_sums_into(&dlogits, cfg.n_classes, &mut grads[hw + 1]);
        let mut dpooled = sc.take_raw(bsz * d);
        matmul_bt_into(&dlogits, &p[hw], bsz, cfg.n_classes, d,
                       &mut dpooled);
        dy = sc.take(bs * d);
        for b in 0..bsz {
            let dp = &dpooled[b * d..(b + 1) * d];
            for i in 0..s {
                let m = mask[b * s + i];
                if m > 0.0 {
                    let dyr =
                        &mut dy[(b * s + i) * d..(b * s + i + 1) * d];
                    let c = m / denoms[b];
                    for j in 0..d {
                        dyr[j] += dp[j] * c;
                    }
                }
            }
        }
        sc.give(pooled);
        sc.give(dpooled);
        sc.give(denoms);
    }
    sc.give(dlogits);
    sc.give(y);

    // final LN
    let EncCache { mut layers, xhatf, rstdf } = cache;
    let fln = final_ln_g(cfg);
    let (mut dx, dgf, dbf) =
        layernorm_bwd(sc, &dy, &xhatf, &rstdf, &p[fln], d);
    add_into(&mut grads[fln], &dgf);
    add_into(&mut grads[fln + 1], &dbf);
    sc.give(dgf);
    sc.give(dbf);
    sc.give(dy);
    sc.give(xhatf);
    sc.give(rstdf);

    let mut l = cfg.n_layers;
    while let Some(lc) = layers.pop() {
        l -= 1;
        // x_out = x_mid + f2
        let df2 = &dx;
        matmul_at_into(&lc.f1, df2, bs, ff, d, &mut grads[li(l, W2)]);
        col_sums_into(df2, d, &mut grads[li(l, B2)]);
        let mut df1 = sc.take_raw(bs * ff);
        matmul_bt_into(df2, &p[li(l, W2)], bs, d, ff, &mut df1);
        let mut du = sc.take_raw(bs * ff);
        for i in 0..bs * ff {
            du[i] = df1[i] * dgelu(lc.u[i]);
        }
        matmul_at_into(&lc.h2, &du, bs, d, ff, &mut grads[li(l, W1)]);
        col_sums_into(&du, ff, &mut grads[li(l, B1)]);
        let mut dh2 = sc.take_raw(bs * d);
        matmul_bt_into(&du, &p[li(l, W1)], bs, ff, d, &mut dh2);
        let (dxm, dg2, db2) =
            layernorm_bwd(sc, &dh2, &lc.xhat2, &lc.rstd2,
                          &p[li(l, LN2_G)], d);
        sc.give(std::mem::replace(&mut grads[li(l, LN2_G)], dg2));
        sc.give(std::mem::replace(&mut grads[li(l, LN2_B)], db2));
        // dx_mid = dx (residual) + dxm
        add_into(&mut dx, &dxm);
        sc.give(dxm);
        sc.give(df1);
        sc.give(du);
        sc.give(dh2);

        // x_mid = x_in + o ; o = a @ Wo + bo
        let do_ = &dx;
        matmul_at_into(&lc.a, do_, bs, d, d, &mut grads[li(l, WO)]);
        col_sums_into(do_, d, &mut grads[li(l, BO)]);
        let mut da = sc.take_raw(bs * d);
        matmul_bt_into(do_, &p[li(l, WO)], bs, d, d, &mut da);

        let mut dq = sc.take_raw(bs * d);
        let mut dk = sc.take_raw(bs * d);
        let mut dv = sc.take_raw(bs * d);
        for b in 0..bsz {
            for h in 0..heads {
                let qh = gather_head(sc, &lc.q, b, h, s, d, dh);
                let kh = gather_head(sc, &lc.k, b, h, s, d, dh);
                let vh = gather_head(sc, &lc.v, b, h, s, d, dh);
                let dah = gather_head(sc, &da, b, h, s, d, dh);
                let base = (b * heads + h) * s * s;
                let probs = &lc.probs[base..base + s * s];
                // dp = dah @ vh^T ; dvh = probs^T @ dah
                let mut dp = sc.take_raw(s * s);
                matmul_bt_into(&dah, &vh, s, dh, s, &mut dp);
                let mut dvh = sc.take(s * dh);
                matmul_at_into(probs, &dah, s, s, dh, &mut dvh);
                // softmax backward
                let mut dscores = sc.take_raw(s * s);
                for i in 0..s {
                    let pr = &probs[i * s..(i + 1) * s];
                    let dpr = &dp[i * s..(i + 1) * s];
                    let inner = dot(pr, dpr);
                    let dsr = &mut dscores[i * s..(i + 1) * s];
                    for j in 0..s {
                        dsr[j] = pr[j] * (dpr[j] - inner);
                    }
                }
                let mut dqh = sc.take(s * dh);
                matmul_into(&dscores, &kh, s, s, dh, &mut dqh);
                let mut dkh = sc.take(s * dh);
                matmul_at_into(&dscores, &qh, s, s, dh, &mut dkh);
                for v_ in dqh.iter_mut() {
                    *v_ *= scale;
                }
                for v_ in dkh.iter_mut() {
                    *v_ *= scale;
                }
                scatter_head(&mut dq, &dqh, b, h, s, d, dh);
                scatter_head(&mut dk, &dkh, b, h, s, d, dh);
                scatter_head(&mut dv, &dvh, b, h, s, d, dh);
                sc.give(qh);
                sc.give(kh);
                sc.give(vh);
                sc.give(dah);
                sc.give(dp);
                sc.give(dvh);
                sc.give(dscores);
                sc.give(dqh);
                sc.give(dkh);
            }
        }
        matmul_at_into(&lc.h1, &dq, bs, d, d, &mut grads[li(l, WQ)]);
        col_sums_into(&dq, d, &mut grads[li(l, BQ)]);
        matmul_at_into(&lc.h1, &dk, bs, d, d, &mut grads[li(l, WK)]);
        col_sums_into(&dk, d, &mut grads[li(l, BK)]);
        matmul_at_into(&lc.h1, &dv, bs, d, d, &mut grads[li(l, WV)]);
        col_sums_into(&dv, d, &mut grads[li(l, BV)]);
        let mut dh1 = sc.take_raw(bs * d);
        matmul_bt_into(&dq, &p[li(l, WQ)], bs, d, d, &mut dh1);
        let mut tmp = sc.take_raw(bs * d);
        matmul_bt_into(&dk, &p[li(l, WK)], bs, d, d, &mut tmp);
        add_into(&mut dh1, &tmp);
        matmul_bt_into(&dv, &p[li(l, WV)], bs, d, d, &mut tmp);
        add_into(&mut dh1, &tmp);
        sc.give(tmp);
        let (dxi, dg1, db1) =
            layernorm_bwd(sc, &dh1, &lc.xhat1, &lc.rstd1,
                          &p[li(l, LN1_G)], d);
        sc.give(std::mem::replace(&mut grads[li(l, LN1_G)], dg1));
        sc.give(std::mem::replace(&mut grads[li(l, LN1_B)], db1));
        add_into(&mut dx, &dxi);
        sc.give(dxi);
        sc.give(dh1);
        sc.give(da);
        sc.give(dq);
        sc.give(dk);
        sc.give(dv);
        sc.give(lc.h1);
        sc.give(lc.xhat1);
        sc.give(lc.rstd1);
        sc.give(lc.q);
        sc.give(lc.k);
        sc.give(lc.v);
        sc.give(lc.probs);
        sc.give(lc.a);
        sc.give(lc.h2);
        sc.give(lc.xhat2);
        sc.give(lc.rstd2);
        sc.give(lc.u);
        sc.give(lc.f1);
    }

    // embeddings
    for b in 0..bsz {
        for i in 0..s {
            let r = b * s + i;
            let id = ids[r].max(0) as usize % cfg.vocab;
            let dxr = &dx[r * d..(r + 1) * d];
            let er = &mut grads[EMBED_TOK][id * d..(id + 1) * d];
            for j in 0..d {
                er[j] += dxr[j];
            }
            let pr = &mut grads[EMBED_POS][i * d..(i + 1) * d];
            for j in 0..d {
                pr[j] += dxr[j];
            }
        }
    }
    sc.give(dx);

    (loss, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::params::make_config;
    use crate::runtime::native::rng::uniform01;

    fn tiny() -> ConfigInfo {
        make_config("t", "encoder", 13, 8, 1, 2, 16, 6, 3, false)
    }

    fn seeded_params(cfg: &ConfigInfo, seed: u32) -> Vec<Vec<f32>> {
        cfg.params
            .iter()
            .map(|spec| {
                (0..spec.elements())
                    .map(|i| {
                        uniform01(seed, (spec.offset + i) as u32) * 0.2
                            - 0.1
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn layout_check_accepts_canonical_rejects_mutant() {
        let cfg = tiny();
        assert!(check_layout(&cfg).is_ok());
        let mut bad = cfg.clone();
        bad.params.swap(0, 1);
        assert!(check_layout(&bad).is_err());
    }

    #[test]
    fn zero_head_gives_chance_loss() {
        let cfg = tiny();
        let init = crate::runtime::native::params::init_params(&cfg);
        let ids = vec![1i32; 2 * 6];
        let mask = vec![1f32; 2 * 6];
        let labels = vec![0i32, 2];
        let l = loss(&cfg, &init, &ids, &mask, &labels, 2, 6,
                     &mut Scratch::new());
        let chance = (cfg.n_classes as f32).ln();
        assert!((l - chance).abs() < 1e-4, "{l} vs ln(3)={chance}");
    }

    #[test]
    fn scratch_steady_state_allocates_nothing() {
        // after one warm-up pass, forward AND backward must run entirely
        // from the pool (the perf-pass property this PR establishes)
        let cfg = tiny();
        let params = seeded_params(&cfg, 42);
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut sc = Scratch::new();
        let l1 = loss(&cfg, &params, &ids, &mask, &labels, 2, 6, &mut sc);
        let (lg1, g1) =
            loss_and_grad(&cfg, &params, &ids, &mask, &labels, 2, 6,
                          &mut sc);
        for g in g1 {
            sc.give(g);
        }
        let warm = sc.miss_count();
        assert!(warm > 0, "warm-up must have allocated");
        let l2 = loss(&cfg, &params, &ids, &mask, &labels, 2, 6, &mut sc);
        let (lg2, g2) =
            loss_and_grad(&cfg, &params, &ids, &mask, &labels, 2, 6,
                          &mut sc);
        for g in g2 {
            sc.give(g);
        }
        assert_eq!(sc.miss_count(), warm,
                   "steady-state pass must not allocate");
        // and buffer reuse must not change a single bit
        assert_eq!(l1, l2);
        assert_eq!(lg1, lg2);
        // fresh-arena runs agree too
        let l3 = loss(&cfg, &params, &ids, &mask, &labels, 2, 6,
                      &mut Scratch::new());
        assert_eq!(l1, l3);
    }

    #[test]
    fn grads_match_finite_differences() {
        // spot-check the hand-derived backward against central
        // differences on a handful of parameters in different tensors
        let cfg = tiny();
        let params = seeded_params(&cfg, 77);
        let ids: Vec<i32> =
            vec![1, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask: Vec<f32> =
            vec![1., 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut sc = Scratch::new();
        let (_, grads) =
            loss_and_grad(&cfg, &params, &ids, &mask, &labels, 2, 6,
                          &mut sc);
        // probe: (tensor index, element index)
        let probes = [
            (0usize, 9usize),            // embed.tok (token 1 row)
            (1, 3),                      // embed.pos
            (li(0, WQ), 11),             // attn weight
            (li(0, W1), 5),              // ffn weight
            (li(0, LN1_G), 2),           // layernorm gain
            (head_w(&cfg), 4),           // classifier head
        ];
        for (t, e) in probes {
            let h = 1e-3f32;
            let mut pp = params.clone();
            pp[t][e] += h;
            let lp = loss(&cfg, &pp, &ids, &mask, &labels, 2, 6, &mut sc);
            pp[t][e] -= 2.0 * h;
            let lm = loss(&cfg, &pp, &ids, &mask, &labels, 2, 6, &mut sc);
            let fd = (lp - lm) / (2.0 * h);
            let an = grads[t][e];
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                "tensor {t} elem {e}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn decoder_grads_match_finite_differences() {
        let cfg = make_config("td", "decoder", 13, 8, 1, 2, 16, 6, 2,
                              false);
        let params = seeded_params(&cfg, 78);
        let ids: Vec<i32> =
            vec![1, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask: Vec<f32> =
            vec![1., 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = ids.clone();
        let mut sc = Scratch::new();
        let (_, grads) =
            loss_and_grad(&cfg, &params, &ids, &mask, &labels, 2, 6,
                          &mut sc);
        for (t, e) in [(0usize, 42usize), (li(0, WO), 20), (li(0, W2), 9)] {
            let h = 1e-3f32;
            let mut pp = params.clone();
            pp[t][e] += h;
            let lp = loss(&cfg, &pp, &ids, &mask, &labels, 2, 6, &mut sc);
            pp[t][e] -= 2.0 * h;
            let lm = loss(&cfg, &pp, &ids, &mask, &labels, 2, 6, &mut sc);
            let fd = (lp - lm) / (2.0 * h);
            let an = grads[t][e];
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                "tensor {t} elem {e}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn split_head_matches_full_backward() {
        // the split path recomputes exactly the encoder-branch
        // arithmetic of loss_and_grad, so loss and head grads must be
        // bit-identical to the full oracle
        let cfg = tiny();
        let params = seeded_params(&cfg, 91);
        let ids: Vec<i32> = vec![1, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask: Vec<f32> =
            vec![1., 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut sc = Scratch::new();
        let (l_full, grads) =
            loss_and_grad(&cfg, &params, &ids, &mask, &labels, 2, 6,
                          &mut sc);
        let (l_split, dw, db) =
            split_head_backward(&cfg, &params, &ids, &mask, &labels, 2,
                                6, &mut sc)
                .unwrap();
        let hw = head_w(&cfg);
        assert_eq!(l_split, l_full);
        assert_eq!(dw, grads[hw]);
        assert_eq!(db, grads[hw + 1]);
    }

    #[test]
    fn split_step_updates_only_the_head() {
        let cfg = tiny();
        let before = seeded_params(&cfg, 92);
        let mut params = before.clone();
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask: Vec<f32> =
            vec![1., 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut sc = Scratch::new();
        let mut losses = Vec::new();
        for _ in 0..25 {
            losses.push(split_head_step(&cfg, &mut params, &ids, &mask,
                                        &labels, 0.5, 2, 6, &mut sc)
                .unwrap());
        }
        let hw = head_w(&cfg);
        for (t, (b, a)) in before.iter().zip(&params).enumerate() {
            if t == hw || t == hw + 1 {
                assert_ne!(b, a, "head tensor {t} must train");
            } else {
                assert_eq!(b, a, "backbone tensor {t} must stay frozen");
            }
        }
        assert!(losses[losses.len() - 1] < losses[0],
                "head SGD must reduce the loss: {losses:?}");
    }

    #[test]
    fn split_rejects_decoder_configs() {
        let dec = make_config("td", "decoder", 13, 8, 1, 2, 16, 6, 2,
                              false);
        let params = seeded_params(&dec, 93);
        let ids = vec![1i32; 12];
        let mask = vec![1f32; 12];
        assert!(pooled_hidden(&dec, &params, &ids, &mask, 2, 6,
                              &mut Scratch::new())
            .is_err());
    }

    #[test]
    fn split_head_grads_match_finite_differences_ragged() {
        // golden-value check over a sweep of ragged geometries: every
        // head element's analytic gradient against central differences,
        // with masks that leave rows partially (never fully) empty
        for (case, (bsz, s, d, heads, ff, nc)) in
            [(1usize, 4usize, 8usize, 2usize, 16usize, 2usize),
             (2, 6, 8, 1, 12, 3),
             (3, 5, 12, 4, 24, 2),
             (4, 3, 4, 2, 8, 5)]
            .into_iter()
            .enumerate()
        {
            let cfg = make_config("t", "encoder", 17, d, 1, heads, ff,
                                  s, nc, false);
            let params = seeded_params(&cfg, 100 + case as u32);
            let mut ids = Vec::new();
            let mut mask = Vec::new();
            let mut labels = Vec::new();
            for b in 0..bsz {
                let live = 1 + (b + case) % s; // ragged row lengths
                for i in 0..s {
                    ids.push(((b * 7 + i * 3 + case) % 17) as i32);
                    mask.push(if i < live { 1.0 } else { 0.0 });
                }
                labels.push(((b + case) % nc) as i32);
            }
            let mut sc = Scratch::new();
            let (_, dw, db) =
                split_head_backward(&cfg, &params, &ids, &mask, &labels,
                                    bsz, s, &mut sc)
                    .unwrap();
            let hw = head_w(&cfg);
            let h = 1e-3f32;
            for t in [hw, hw + 1] {
                for e in 0..params[t].len() {
                    let mut pp = params.clone();
                    pp[t][e] += h;
                    let lp = loss(&cfg, &pp, &ids, &mask, &labels, bsz,
                                  s, &mut sc);
                    pp[t][e] -= 2.0 * h;
                    let lm = loss(&cfg, &pp, &ids, &mask, &labels, bsz,
                                  s, &mut sc);
                    let fd = (lp - lm) / (2.0 * h);
                    let an = if t == hw { dw[e] } else { db[e] };
                    assert!(
                        (fd - an).abs()
                            < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                        "case {case} tensor {t} elem {e}: fd {fd} vs \
                         analytic {an}"
                    );
                }
            }
        }
    }

    #[test]
    fn logits_shapes() {
        let cfg = tiny();
        let params = seeded_params(&cfg, 5);
        let ids = vec![1i32; 12];
        let mask = vec![1f32; 12];
        let mut sc = Scratch::new();
        let lg = logits(&cfg, &params, &ids, &mask, 2, 6, &mut sc);
        assert_eq!(lg.len(), 2 * 3);
        let dec = make_config("td", "decoder", 13, 8, 1, 2, 16, 6, 2,
                              false);
        let pd = seeded_params(&dec, 6);
        let lg = logits(&dec, &pd, &ids, &mask, 2, 6, &mut sc);
        assert_eq!(lg.len(), 2 * 6 * 13);
    }
}
