//! The pure-Rust native execution backend.
//!
//! Interprets the step-program semantics directly instead of executing
//! AOT-lowered HLO: the same fused `mezo_step` / `adam_step` / `eval` /
//! `loss_eval` contracts (input order, output order, scalar
//! conventions) as `python/compile/steps.py`, over the same counter-RNG
//! perturbation stream as `python/compile/kernels/rng.py`.  This is the
//! default backend: hermetic (no XLA, no artifacts, no Python), which
//! is what makes `cargo test` self-contained on any machine.
//!
//! Two execution paths exist per program:
//! * [`Executable::run`] — the literal-in/literal-out compatibility
//!   path (clones every parameter tensor in and out; what PJRT speaks).
//! * [`Executable::run_in_place`] — the buffer-donation hot path: the
//!   parameter (and Adam m/v) tensors live in a caller-owned
//!   [`ExecState`] and are mutated in place, and activations come from
//!   the state's [`Scratch`](model::Scratch) arena, so a steady-state
//!   step performs zero parameter copies and zero heap allocation.
//!
//! `mezo_step_q{k}` (k-query SPSA) runs its k independent two-point
//! queries on a `std::thread::scope` worker pool: every query is
//! evaluated at the exact base parameters from per-worker shadows
//! drawn out of a caller-owned [`SpsaPool`] (allocated once, reused
//! every step), and the projected gradients are reduced in fixed query
//! order — so the result is bit-identical for ANY worker count (pinned
//! against [`mezo_step_multi_reference`] in the tests).
//!
//! Submodules: [`rng`] (counter RNG), [`math`] (dense kernels),
//! [`model`] (forward/backward + scratch arena), [`params`] (canonical
//! layout + init).

pub mod math;
pub mod model;
pub mod params;
pub mod rng;

use anyhow::{bail, ensure, Context, Result};

use super::backend::{Backend, Executable};
use super::literal::Literal;
use super::manifest::{ConfigInfo, Manifest, ProgramSpec};
use super::state::ExecState;

/// The native CPU backend (stateless; all state lives per-program).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "cpu-native".into()
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn Executable>> {
        let cfg = manifest.config(&spec.config)?.clone();
        model::check_layout(&cfg)?;
        let kind = ProgramKind::parse(&spec.kind).with_context(|| {
            format!("native backend: program kind '{}'", spec.kind)
        })?;
        Ok(Box::new(NativeProgram { cfg, kind, spec: spec.clone() }))
    }
}

/// Which step-program semantics to interpret.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramKind {
    /// Fused MeZO step (restore+update folded into one axpy).
    Mezo,
    /// Unfused perf-ablation twin (two separate sweeps; same math).
    MezoNaive,
    /// k-query averaged SPSA (`mezo_step_q{k}`).
    MezoMulti(usize),
    Adam,
    /// Split-tuning step: frozen-backbone forward on device, side-module
    /// (head) SGD — the update that crosses the simulated link.
    SplitStep,
    Eval,
    LossEval,
}

impl ProgramKind {
    pub fn parse(kind: &str) -> Option<ProgramKind> {
        match kind {
            "mezo_step" => Some(ProgramKind::Mezo),
            "mezo_step_naive" => Some(ProgramKind::MezoNaive),
            "adam_step" => Some(ProgramKind::Adam),
            "split_step" => Some(ProgramKind::SplitStep),
            "eval" => Some(ProgramKind::Eval),
            "loss_eval" => Some(ProgramKind::LossEval),
            other => {
                let k = other.strip_prefix("mezo_step_q")?;
                let k: usize = k.parse().ok()?;
                if k >= 1 {
                    Some(ProgramKind::MezoMulti(k))
                } else {
                    None
                }
            }
        }
    }
}

struct NativeProgram {
    cfg: ConfigInfo,
    kind: ProgramKind,
    spec: ProgramSpec,
}

/// Pooled per-worker working sets for the k-query SPSA path.
///
/// Each slot owns one parameter shadow plus a scratch arena.  A shadow
/// only ever feeds [`two_point_at`], whose `perturb_from` sweeps
/// overwrite every element before any read — so a pooled slot needs
/// correct tensor *lengths*, never fresh contents, and reusing it
/// across steps cannot change results.  Pooling turns the per-step
/// cost of `mezo_step_q{k}` from one parameter-set clone (plus arena
/// warm-up) per worker into zero steady-state allocation.
///
/// Residency contract: shadows are full-size f32 parameter copies, so
/// a quantized [`ExecState`] calls [`release`](SpsaPool::release)
/// whenever it frees its transient f32 working set — pooled shadows
/// never outlive the step for reduced-precision sessions, while f32
/// sessions keep them warm indefinitely.
#[derive(Debug, Default)]
pub struct SpsaPool {
    slots: Vec<SpsaSlot>,
}

#[derive(Debug, Default)]
struct SpsaSlot {
    shadow: Vec<Vec<f32>>,
    scratch: model::Scratch,
}

impl SpsaPool {
    pub fn new() -> SpsaPool {
        SpsaPool::default()
    }

    /// Host bytes currently pinned by pooled parameter shadows (the
    /// figure session residency telemetry charges once, at high water,
    /// rather than per step).
    pub fn resident_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flat_map(|slot| slot.shadow.iter())
            .map(|t| 4 * t.len() as u64)
            .sum()
    }

    /// Drop every pooled shadow and arena.
    pub fn release(&mut self) {
        self.slots.clear();
    }

    /// Make the first `n` slots hold shadows with `base`'s tensor
    /// lengths (contents unspecified — every element is overwritten
    /// before it is read).  Existing allocations of the right size are
    /// kept as-is, so a steady-state call is length checks only.
    fn reserve(&mut self, n: usize, base: &[Vec<f32>]) {
        if self.slots.len() < n {
            self.slots.resize_with(n, SpsaSlot::default);
        }
        for slot in &mut self.slots[..n] {
            slot.shadow.resize_with(base.len(), Vec::new);
            for (dst, src) in slot.shadow.iter_mut().zip(base) {
                if dst.len() != src.len() {
                    dst.resize(src.len(), 0.0);
                }
            }
        }
    }
}

/// `w += scale * z(seed)` over every tensor, sharing one flat stream.
pub fn perturb_all(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    seed: u32,
    scale: f32,
) {
    for (spec, t) in cfg.params.iter().zip(w.iter_mut()) {
        rng::perturb(t, seed, spec.offset, scale);
    }
}

/// In-place two-point probe: perturbs `w` by +eps z then -2 eps z and
/// returns the two losses, leaving `w` at (w - eps z); the caller's
/// restore/update sweep follows (fused/naive single-query paths).
#[allow(clippy::too_many_arguments)]
fn two_point_inplace(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    sq: u32,
    eps: f32,
    sc: &mut model::Scratch,
) -> (f32, f32) {
    perturb_all(cfg, w, sq, eps);
    let lplus = model::loss(cfg, &*w, ids, mask, labels, bsz, s, sc);
    perturb_all(cfg, w, sq, -2.0 * eps);
    let lminus = model::loss(cfg, &*w, ids, mask, labels, bsz, s, sc);
    (lplus, lminus)
}

/// Shadow two-point probe for the k-query path: writes `base ± eps z`
/// into `shadow` (never touching `base`) and returns the two losses.
/// Both sides are computed FROM the base point, so the result depends
/// only on `(base, sq)` — not on which worker or in which order the
/// query ran.
#[allow(clippy::too_many_arguments)]
fn two_point_at(
    cfg: &ConfigInfo,
    base: &[Vec<f32>],
    shadow: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    sq: u32,
    eps: f32,
    sc: &mut model::Scratch,
) -> (f32, f32) {
    for (spec, (src, dst)) in
        cfg.params.iter().zip(base.iter().zip(shadow.iter_mut()))
    {
        rng::perturb_from(src, dst, sq, spec.offset, eps);
    }
    let lplus = model::loss(cfg, &*shadow, ids, mask, labels, bsz, s, sc);
    for (spec, (src, dst)) in
        cfg.params.iter().zip(base.iter().zip(shadow.iter_mut()))
    {
        rng::perturb_from(src, dst, sq, spec.offset, -eps);
    }
    let lminus = model::loss(cfg, &*shadow, ids, mask, labels, bsz, s, sc);
    (lplus, lminus)
}

/// Evaluate the k two-point query pairs at `base`, fanned out over at
/// most `workers` scoped threads.  Each worker borrows one slot of the
/// caller's [`SpsaPool`] — a parameter shadow plus a scratch arena —
/// so a steady-state step re-clones nothing (single-worker runs use
/// the caller's resident `sc` and only the pool's first shadow).
/// Query q's pair lands at `pairs[q]` regardless of scheduling, which
/// is what makes the reduction order (and therefore the step)
/// deterministic.
#[allow(clippy::too_many_arguments)]
fn spsa_pairs(
    cfg: &ConfigInfo,
    base: &[Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    q_seeds: &[u32],
    eps: f32,
    workers: usize,
    pool: &mut SpsaPool,
    sc: &mut model::Scratch,
) -> Vec<(f32, f32)> {
    let k = q_seeds.len();
    let mut pairs = vec![(0f32, 0f32); k];
    let workers = workers.clamp(1, k.max(1));
    if workers <= 1 {
        pool.reserve(1, base);
        let shadow = &mut pool.slots[0].shadow;
        for (q, pair) in pairs.iter_mut().enumerate() {
            *pair = two_point_at(cfg, base, shadow, ids, mask,
                                 labels, bsz, s, q_seeds[q], eps, sc);
        }
        return pairs;
    }
    let chunk = k.div_ceil(workers);
    pool.reserve(k.div_ceil(chunk), base);
    std::thread::scope(|scope| {
        for ((ci, out), slot) in
            pairs.chunks_mut(chunk).enumerate().zip(&mut pool.slots)
        {
            let lo = ci * chunk;
            scope.spawn(move || {
                for (j, pair) in out.iter_mut().enumerate() {
                    *pair = two_point_at(cfg, base, &mut slot.shadow,
                                         ids, mask, labels, bsz, s,
                                         q_seeds[lo + j], eps,
                                         &mut slot.scratch);
                }
            });
        }
    });
    pairs
}

/// The k-query step body shared by the production (parallel) path and
/// the sequential reference: probe pairs at the base point, then reduce
/// and apply the k update sweeps in fixed query order.
#[allow(clippy::too_many_arguments)]
fn mezo_multi_with_workers(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    seed: u32,
    lr: f32,
    eps: f32,
    k: usize,
    workers: usize,
    pool: &mut SpsaPool,
    sc: &mut model::Scratch,
) -> f32 {
    let q_seeds: Vec<u32> =
        (0..k).map(|q| rng::hash_u32(seed, q as u32 + 1)).collect();
    let pairs = spsa_pairs(cfg, &*w, ids, mask, labels, bsz, s,
                           &q_seeds, eps, workers, pool, sc);
    let mut gs = Vec::with_capacity(k);
    let mut losses = 0f32;
    for &(lplus, lminus) in &pairs {
        gs.push((lplus - lminus) / (2.0 * eps));
        losses += 0.5 * (lplus + lminus);
    }
    let scale = lr / k as f32;
    for (&sq, &g) in q_seeds.iter().zip(&gs) {
        perturb_all(cfg, w, sq, -scale * g);
    }
    losses / k as f32
}

/// Sequential oracle for the k-query step: identical semantics to the
/// parallel `mezo_step_q{k}` path with the worker pool pinned to one
/// thread.  Exists so tests/benches can assert (and measure) that
/// parallelism changes wall-time and nothing else.
#[allow(clippy::too_many_arguments)]
pub fn mezo_step_multi_reference(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    seed: u32,
    lr: f32,
    eps: f32,
    k: usize,
) -> Result<f32> {
    ensure!(k >= 1, "k-query step needs k >= 1");
    Ok(mezo_multi_with_workers(cfg, w, ids, mask, labels, bsz, s, seed,
                               lr, eps, k, 1, &mut SpsaPool::new(),
                               &mut model::Scratch::new()))
}

/// One fused MeZO-SGD step on `w` in place; returns the reported loss
/// (mean of the two perturbed evaluations).  Mirrors
/// `steps.mezo_step` / `mezo_step_naive` / `mezo_step_multi`.  `pool`
/// carries the k-query worker shadows across steps (only touched by
/// `MezoMulti`; pass a fresh pool for one-shot calls).
#[allow(clippy::too_many_arguments)]
pub fn mezo_step(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    seed: u32,
    lr: f32,
    eps: f32,
    kind: ProgramKind,
    pool: &mut SpsaPool,
    sc: &mut model::Scratch,
) -> Result<f32> {
    match kind {
        ProgramKind::Mezo => {
            let (lplus, lminus) = two_point_inplace(cfg, w, ids, mask,
                                                    labels, bsz, s,
                                                    seed, eps, sc);
            let g = (lplus - lminus) / (2.0 * eps);
            // restore (+eps z) and update (-lr g z) in ONE sweep
            perturb_all(cfg, w, seed, eps - lr * g);
            Ok(0.5 * (lplus + lminus))
        }
        ProgramKind::MezoNaive => {
            let (lplus, lminus) = two_point_inplace(cfg, w, ids, mask,
                                                    labels, bsz, s,
                                                    seed, eps, sc);
            let g = (lplus - lminus) / (2.0 * eps);
            perturb_all(cfg, w, seed, eps); // restore
            perturb_all(cfg, w, seed, -lr * g); // update
            Ok(0.5 * (lplus + lminus))
        }
        ProgramKind::MezoMulti(k) => {
            // k independent two-point estimates at the SAME point (the
            // paper's §6.3 data-parallel queries), then k averaged
            // update sweeps in fixed order
            Ok(mezo_multi_with_workers(cfg, w, ids, mask, labels, bsz,
                                       s, seed, lr, eps, k,
                                       math::n_threads(), pool, sc))
        }
        other => bail!("mezo_step called with {other:?}"),
    }
}

/// One Adam step on `(w, m, v)` in place; returns the loss.  Constants
/// match `kernels/ref.py::adam_update` (beta1 0.9, beta2 0.999, eps
/// 1e-8, no weight decay); `t` is the 1-based step count.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    t: f32,
    lr: f32,
    sc: &mut model::Scratch,
) -> Result<f32> {
    const BETA1: f32 = 0.9;
    const BETA2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let (loss, grads) =
        model::loss_and_grad(cfg, &*w, ids, mask, labels, bsz, s, sc);
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for ((wt, mt), (vt, gt)) in w
        .iter_mut()
        .zip(m.iter_mut())
        .zip(v.iter_mut().zip(grads.iter()))
    {
        for i in 0..wt.len() {
            let g = gt[i];
            let m2 = BETA1 * mt[i] + (1.0 - BETA1) * g;
            let v2 = BETA2 * vt[i] + (1.0 - BETA2) * g * g;
            mt[i] = m2;
            vt[i] = v2;
            let mhat = m2 / bc1;
            let vhat = v2 / bc2;
            wt[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
    for g in grads {
        sc.give(g);
    }
    Ok(loss)
}

/// Pull `count` consecutive f32 tensors (cloned) starting at `from`.
fn take_f32(inputs: &[&Literal], from: usize, count: usize)
    -> Result<Vec<Vec<f32>>>
{
    (from..from + count)
        .map(|i| inputs[i].f32_vec())
        .collect()
}

fn param_literals(
    cfg: &ConfigInfo,
    tensors: Vec<Vec<f32>>,
) -> Result<Vec<Literal>> {
    cfg.params
        .iter()
        .zip(tensors)
        .map(|(spec, data)| Literal::from_f32(data, spec.shape.clone()))
        .collect()
}

impl NativeProgram {
    /// (batch, seq) from the ids input literal.
    fn batch_dims(&self, ids: &Literal) -> Result<(usize, usize)> {
        match ids.shape() {
            [b, s] => Ok((*b, *s)),
            other => bail!("ids input has shape {other:?}, expected [B, S]"),
        }
    }
}

impl Executable for NativeProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let cfg = &self.cfg;
        let n = cfg.params.len();
        match self.kind {
            ProgramKind::Mezo
            | ProgramKind::MezoNaive
            | ProgramKind::MezoMulti(_) => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let mut w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let labels = inputs[n + 2].i32_slice()?;
                let seed = inputs[n + 3].u32_scalar()?;
                let lr = inputs[n + 4].f32_scalar()?;
                let eps = inputs[n + 5].f32_scalar()?;
                let loss = mezo_step(cfg, &mut w, ids, mask, labels, b, s,
                                     seed, lr, eps, self.kind,
                                     &mut SpsaPool::new(),
                                     &mut model::Scratch::new())?;
                let mut outs = param_literals(cfg, w)?;
                outs.push(Literal::from_f32(vec![loss], vec![])?);
                Ok(outs)
            }
            ProgramKind::Adam => {
                let (b, s) = self.batch_dims(inputs[3 * n])?;
                let mut w = take_f32(inputs, 0, n)?;
                let mut m = take_f32(inputs, n, n)?;
                let mut v = take_f32(inputs, 2 * n, n)?;
                let ids = inputs[3 * n].i32_slice()?;
                let mask = inputs[3 * n + 1].f32_slice()?;
                let labels = inputs[3 * n + 2].i32_slice()?;
                let t = inputs[3 * n + 3].f32_scalar()?;
                let lr = inputs[3 * n + 4].f32_scalar()?;
                let loss = adam_step(cfg, &mut w, &mut m, &mut v, ids,
                                     mask, labels, b, s, t, lr,
                                     &mut model::Scratch::new())?;
                let mut outs = param_literals(cfg, w)?;
                outs.extend(param_literals(cfg, m)?);
                outs.extend(param_literals(cfg, v)?);
                outs.push(Literal::from_f32(vec![loss], vec![])?);
                Ok(outs)
            }
            ProgramKind::SplitStep => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let mut w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let labels = inputs[n + 2].i32_slice()?;
                let lr = inputs[n + 3].f32_scalar()?;
                let loss = model::split_head_step(
                    cfg, &mut w, ids, mask, labels, lr, b, s,
                    &mut model::Scratch::new())?;
                let mut outs = param_literals(cfg, w)?;
                outs.push(Literal::from_f32(vec![loss], vec![])?);
                Ok(outs)
            }
            ProgramKind::Eval => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let lg = model::logits(cfg, &w, ids, mask, b, s,
                                       &mut model::Scratch::new());
                let shape = if cfg.is_decoder() {
                    vec![b, s, cfg.vocab]
                } else {
                    vec![b, cfg.n_classes]
                };
                Ok(vec![Literal::from_f32(lg, shape)?])
            }
            ProgramKind::LossEval => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let labels = inputs[n + 2].i32_slice()?;
                let loss = model::loss(cfg, &w, ids, mask, labels, b, s,
                                       &mut model::Scratch::new());
                Ok(vec![Literal::from_f32(vec![loss], vec![])?])
            }
        }
    }

    /// The buffer-donation hot path: parameters (and Adam m/v) are
    /// mutated inside `state` directly — no clone-in, no clone-out —
    /// and activations come from `state.scratch`.  `inputs` carries
    /// only the non-donated tensors, in the order they follow the
    /// donated block in the manifest calling convention.
    ///
    /// Precision residency: for a reduced-precision `ExecState` the
    /// parameters are dequantized into transient f32 working buffers
    /// before the step and re-quantized (then freed) on writeback —
    /// the step math itself is always f32, so an `F32` state keeps the
    /// historical bit-exact zero-copy behaviour, and between steps a
    /// quantized state keeps only its quantized bytes resident.
    /// `loss_eval` reads params without mutating them, so its working
    /// set is discarded instead of written back (an int8 re-scale
    /// would otherwise perturb storage).
    fn run_in_place(
        &self,
        state: &mut ExecState,
        inputs: &[&Literal],
    ) -> Result<f32> {
        let cfg = &self.cfg;
        state.materialize()?;
        ensure!(
            state.w.len() == cfg.params.len(),
            "ExecState holds {} param tensors, config {} has {}",
            state.w.len(),
            cfg.name,
            cfg.params.len()
        );
        let result = self.run_materialized(state, inputs);
        if matches!(self.kind, ProgramKind::LossEval) || result.is_err()
        {
            // read-only program (or a failed step whose partial
            // working set must not overwrite good residency)
            state.discard_materialized();
        } else {
            state.writeback()?;
        }
        result
    }
}

impl NativeProgram {
    /// The step body `run_in_place` wraps between materialize and
    /// writeback: operates on the f32 working set in `state.w`.
    fn run_materialized(
        &self,
        state: &mut ExecState,
        inputs: &[&Literal],
    ) -> Result<f32> {
        let cfg = &self.cfg;
        match self.kind {
            ProgramKind::Mezo
            | ProgramKind::MezoNaive
            | ProgramKind::MezoMulti(_) => {
                ensure!(inputs.len() == 6,
                        "mezo run_in_place takes (ids, mask, labels, \
                         seed, lr, eps); got {} inputs", inputs.len());
                let (b, s) = self.batch_dims(inputs[0])?;
                let ids = inputs[0].i32_slice()?;
                let mask = inputs[1].f32_slice()?;
                let labels = inputs[2].i32_slice()?;
                let seed = inputs[3].u32_scalar()?;
                let lr = inputs[4].f32_scalar()?;
                let eps = inputs[5].f32_scalar()?;
                let (w, _m, _v, scratch, pool) = state.native_parts();
                mezo_step(cfg, w, ids, mask, labels, b, s, seed, lr,
                          eps, self.kind, pool, scratch)
            }
            ProgramKind::Adam => {
                ensure!(inputs.len() == 5,
                        "adam run_in_place takes (ids, mask, labels, t, \
                         lr); got {} inputs", inputs.len());
                ensure!(state.has_adam(),
                        "adam run_in_place needs ExecState::with_adam \
                         (m/v tensors)");
                let (b, s) = self.batch_dims(inputs[0])?;
                let ids = inputs[0].i32_slice()?;
                let mask = inputs[1].f32_slice()?;
                let labels = inputs[2].i32_slice()?;
                let t = inputs[3].f32_scalar()?;
                let lr = inputs[4].f32_scalar()?;
                let (w, m, v, scratch, _pool) = state.native_parts();
                adam_step(cfg, w, m, v, ids, mask, labels, b, s, t, lr,
                          scratch)
            }
            ProgramKind::SplitStep => {
                ensure!(inputs.len() == 4,
                        "split_step run_in_place takes (ids, mask, \
                         labels, lr); got {} inputs", inputs.len());
                let (b, s) = self.batch_dims(inputs[0])?;
                let ids = inputs[0].i32_slice()?;
                let mask = inputs[1].f32_slice()?;
                let labels = inputs[2].i32_slice()?;
                let lr = inputs[3].f32_scalar()?;
                let (w, _m, _v, scratch, _pool) = state.native_parts();
                model::split_head_step(cfg, w, ids, mask, labels, lr,
                                       b, s, scratch)
            }
            ProgramKind::LossEval => {
                ensure!(inputs.len() == 3,
                        "loss_eval run_in_place takes (ids, mask, \
                         labels); got {} inputs", inputs.len());
                let (b, s) = self.batch_dims(inputs[0])?;
                let ids = inputs[0].i32_slice()?;
                let mask = inputs[1].f32_slice()?;
                let labels = inputs[2].i32_slice()?;
                let (w, _m, _v, scratch, _pool) = state.native_parts();
                Ok(model::loss(cfg, w, ids, mask, labels, b, s, scratch))
            }
            ProgramKind::Eval => bail!(
                "eval returns logits, not a scalar loss; use run()"
            ),
        }
    }
}

// `spec` is carried for error reporting/debugging parity with the PJRT
// path; silence the lint without dropping the field.
impl NativeProgram {
    #[allow(dead_code)]
    fn file(&self) -> &str {
        &self.spec.file
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_kind_parses() {
        assert_eq!(ProgramKind::parse("mezo_step"), Some(ProgramKind::Mezo));
        assert_eq!(ProgramKind::parse("mezo_step_naive"),
                   Some(ProgramKind::MezoNaive));
        assert_eq!(ProgramKind::parse("mezo_step_q4"),
                   Some(ProgramKind::MezoMulti(4)));
        assert_eq!(ProgramKind::parse("adam_step"), Some(ProgramKind::Adam));
        assert_eq!(ProgramKind::parse("split_step"),
                   Some(ProgramKind::SplitStep));
        assert_eq!(ProgramKind::parse("eval"), Some(ProgramKind::Eval));
        assert_eq!(ProgramKind::parse("loss_eval"),
                   Some(ProgramKind::LossEval));
        assert_eq!(ProgramKind::parse("mezo_step_q0"), None);
        assert_eq!(ProgramKind::parse("sgd_step"), None);
    }

    #[test]
    fn fused_and_naive_agree() {
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let init = params::init_params(&cfg);
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut fused = init.clone();
        let lf = mezo_step(&cfg, &mut fused, &ids, &mask, &labels, 2, 6,
                           99, 1e-2, 1e-3, ProgramKind::Mezo,
                           &mut SpsaPool::new(),
                           &mut model::Scratch::new())
            .unwrap();
        let mut naive = init.clone();
        let ln = mezo_step(&cfg, &mut naive, &ids, &mask, &labels, 2, 6,
                           99, 1e-2, 1e-3, ProgramKind::MezoNaive,
                           &mut SpsaPool::new(),
                           &mut model::Scratch::new())
            .unwrap();
        assert_eq!(lf, ln, "identical loss estimate");
        for (a, b) in fused.iter().zip(&naive) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn mezo_state_is_only_the_seed() {
        // two sessions with the same seed sequence produce bit-identical
        // parameters — no hidden state anywhere in the interpreter
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let ids = vec![1i32; 12];
        let mask = vec![1f32; 12];
        let labels = vec![0i32, 1];
        let run = || {
            let mut w = params::init_params(&cfg);
            let mut sc = model::Scratch::new();
            let mut pool = SpsaPool::new();
            for step in 0..3u32 {
                mezo_step(&cfg, &mut w, &ids, &mask, &labels, 2, 6,
                          1000 + step, 1e-3, 1e-3, ProgramKind::Mezo,
                          &mut pool, &mut sc)
                    .unwrap();
            }
            w
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_multi_query_matches_sequential_reference() {
        // the tentpole determinism pin: the threaded mezo_step_q{k}
        // path must produce bit-identical parameters AND loss to the
        // one-worker sequential oracle, for every k
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let init = params::init_params(&cfg);
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        for k in [1usize, 2, 4] {
            let mut par = init.clone();
            let lp = mezo_step(&cfg, &mut par, &ids, &mask, &labels, 2,
                               6, 321, 1e-2, 1e-3,
                               ProgramKind::MezoMulti(k),
                               &mut SpsaPool::new(),
                               &mut model::Scratch::new())
                .unwrap();
            let mut seq = init.clone();
            let ls = mezo_step_multi_reference(&cfg, &mut seq, &ids,
                                               &mask, &labels, 2, 6,
                                               321, 1e-2, 1e-3, k)
                .unwrap();
            assert_eq!(lp.to_bits(), ls.to_bits(),
                       "k={k}: loss must be bit-identical");
            assert_eq!(par, seq, "k={k}: params must be bit-identical");
        }
    }

    #[test]
    fn multi_query_moves_params_and_reports_finite_loss() {
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let init = params::init_params(&cfg);
        let mut w = init.clone();
        let ids = vec![1i32; 12];
        let mask = vec![1f32; 12];
        let labels = vec![0i32, 1];
        let l = mezo_step(&cfg, &mut w, &ids, &mask, &labels, 2, 6, 9,
                          1e-2, 1e-3, ProgramKind::MezoMulti(3),
                          &mut SpsaPool::new(),
                          &mut model::Scratch::new())
            .unwrap();
        assert!(l.is_finite());
        assert_ne!(w, init, "the averaged update must move the params");
    }

    #[test]
    fn pooled_shadows_reused_across_steps_change_nothing() {
        // the shadow pool is a pure allocation cache: a multi-step
        // q-run sharing ONE pool must be bit-identical to re-creating
        // the pool every step, and the pool must actually retain its
        // worker shadows between steps (that retention is the perf win)
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let init = params::init_params(&cfg);
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut pooled = init.clone();
        let mut pool = SpsaPool::new();
        let mut sc = model::Scratch::new();
        for step in 0..3u32 {
            mezo_step(&cfg, &mut pooled, &ids, &mask, &labels, 2, 6,
                      500 + step, 1e-2, 1e-3, ProgramKind::MezoMulti(4),
                      &mut pool, &mut sc)
                .unwrap();
        }
        let n_params: u64 =
            cfg.params.iter().map(|s| 4 * s.elements() as u64).sum();
        assert!(pool.resident_bytes() >= n_params,
                "pool retains at least one full shadow between steps");
        let mut fresh = init.clone();
        for step in 0..3u32 {
            mezo_step(&cfg, &mut fresh, &ids, &mask, &labels, 2, 6,
                      500 + step, 1e-2, 1e-3, ProgramKind::MezoMulti(4),
                      &mut SpsaPool::new(), &mut model::Scratch::new())
                .unwrap();
        }
        assert_eq!(pooled, fresh,
                   "pool reuse must be invisible to the trajectory");
    }

    #[test]
    fn adam_descends_on_tiny_problem() {
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      2, false);
        let mut w = params::init_params(&cfg);
        let mut m: Vec<Vec<f32>> =
            cfg.params.iter().map(|s| vec![0.0; s.elements()]).collect();
        let mut v = m.clone();
        let ids = vec![1i32, 5, 9, 3, 2, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 1., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![1i32, 0];
        let mut losses = Vec::new();
        let mut sc = model::Scratch::new();
        for t in 1..=25 {
            let l = adam_step(&cfg, &mut w, &mut m, &mut v, &ids, &mask,
                              &labels, 2, 6, t as f32, 5e-3, &mut sc)
                .unwrap();
            losses.push(l);
        }
        assert!(losses[24] < losses[0] * 0.5,
                "adam failed to descend: {losses:?}");
    }
}
