//! The pure-Rust native execution backend.
//!
//! Interprets the step-program semantics directly instead of executing
//! AOT-lowered HLO: the same fused `mezo_step` / `adam_step` / `eval` /
//! `loss_eval` contracts (input order, output order, scalar
//! conventions) as `python/compile/steps.py`, over the same counter-RNG
//! perturbation stream as `python/compile/kernels/rng.py`.  This is the
//! default backend: hermetic (no XLA, no artifacts, no Python), which
//! is what makes `cargo test` self-contained on any machine.
//!
//! Submodules: [`rng`] (counter RNG), [`math`] (dense kernels),
//! [`model`] (forward/backward), [`params`] (canonical layout + init).

pub mod math;
pub mod model;
pub mod params;
pub mod rng;

use anyhow::{bail, Context, Result};

use super::backend::{Backend, Executable};
use super::literal::Literal;
use super::manifest::{ConfigInfo, Manifest, ProgramSpec};

/// The native CPU backend (stateless; all state lives per-program).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "cpu-native".into()
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ProgramSpec,
    ) -> Result<Box<dyn Executable>> {
        let cfg = manifest.config(&spec.config)?.clone();
        model::check_layout(&cfg)?;
        let kind = ProgramKind::parse(&spec.kind).with_context(|| {
            format!("native backend: program kind '{}'", spec.kind)
        })?;
        Ok(Box::new(NativeProgram { cfg, kind, spec: spec.clone() }))
    }
}

/// Which step-program semantics to interpret.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramKind {
    /// Fused MeZO step (restore+update folded into one axpy).
    Mezo,
    /// Unfused perf-ablation twin (two separate sweeps; same math).
    MezoNaive,
    /// k-query averaged SPSA (`mezo_step_q{k}`).
    MezoMulti(usize),
    Adam,
    Eval,
    LossEval,
}

impl ProgramKind {
    pub fn parse(kind: &str) -> Option<ProgramKind> {
        match kind {
            "mezo_step" => Some(ProgramKind::Mezo),
            "mezo_step_naive" => Some(ProgramKind::MezoNaive),
            "adam_step" => Some(ProgramKind::Adam),
            "eval" => Some(ProgramKind::Eval),
            "loss_eval" => Some(ProgramKind::LossEval),
            other => {
                let k = other.strip_prefix("mezo_step_q")?;
                let k: usize = k.parse().ok()?;
                if k >= 1 {
                    Some(ProgramKind::MezoMulti(k))
                } else {
                    None
                }
            }
        }
    }
}

struct NativeProgram {
    cfg: ConfigInfo,
    kind: ProgramKind,
    spec: ProgramSpec,
}

/// `w += scale * z(seed)` over every tensor, sharing one flat stream.
pub fn perturb_all(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    seed: u32,
    scale: f32,
) {
    for (spec, t) in cfg.params.iter().zip(w.iter_mut()) {
        rng::perturb(t, seed, spec.offset, scale);
    }
}

/// One fused MeZO-SGD step on `w` in place; returns the reported loss
/// (mean of the two perturbed evaluations).  Mirrors
/// `steps.mezo_step` / `mezo_step_naive` / `mezo_step_multi`.
#[allow(clippy::too_many_arguments)]
pub fn mezo_step(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    seed: u32,
    lr: f32,
    eps: f32,
    kind: ProgramKind,
) -> Result<f32> {
    let two_point = |w: &mut [Vec<f32>], sq: u32| -> (f32, f32) {
        perturb_all(cfg, w, sq, eps);
        let lplus = model::loss(cfg, w, ids, mask, labels, bsz, s);
        perturb_all(cfg, w, sq, -2.0 * eps);
        let lminus = model::loss(cfg, w, ids, mask, labels, bsz, s);
        (lplus, lminus)
    };
    match kind {
        ProgramKind::Mezo => {
            let (lplus, lminus) = two_point(w, seed);
            let g = (lplus - lminus) / (2.0 * eps);
            // restore (+eps z) and update (-lr g z) in ONE sweep
            perturb_all(cfg, w, seed, eps - lr * g);
            Ok(0.5 * (lplus + lminus))
        }
        ProgramKind::MezoNaive => {
            let (lplus, lminus) = two_point(w, seed);
            let g = (lplus - lminus) / (2.0 * eps);
            perturb_all(cfg, w, seed, eps); // restore
            perturb_all(cfg, w, seed, -lr * g); // update
            Ok(0.5 * (lplus + lminus))
        }
        ProgramKind::MezoMulti(k) => {
            // k independent two-point estimates at the SAME point, then
            // k averaged update sweeps (steps.mezo_step_multi)
            let q_seeds: Vec<u32> =
                (0..k).map(|q| rng::hash_u32(seed, q as u32 + 1)).collect();
            let mut gs = Vec::with_capacity(k);
            let mut losses = 0f32;
            for &sq in &q_seeds {
                let (lplus, lminus) = two_point(w, sq);
                gs.push((lplus - lminus) / (2.0 * eps));
                losses += 0.5 * (lplus + lminus);
                perturb_all(cfg, w, sq, eps); // restore
            }
            let scale = lr / k as f32;
            for (&sq, &g) in q_seeds.iter().zip(&gs) {
                perturb_all(cfg, w, sq, -scale * g);
            }
            Ok(losses / k as f32)
        }
        other => bail!("mezo_step called with {other:?}"),
    }
}

/// One Adam step on `(w, m, v)` in place; returns the loss.  Constants
/// match `kernels/ref.py::adam_update` (beta1 0.9, beta2 0.999, eps
/// 1e-8, no weight decay); `t` is the 1-based step count.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    cfg: &ConfigInfo,
    w: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    ids: &[i32],
    mask: &[f32],
    labels: &[i32],
    bsz: usize,
    s: usize,
    t: f32,
    lr: f32,
) -> Result<f32> {
    const BETA1: f32 = 0.9;
    const BETA2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let (loss, grads) =
        model::loss_and_grad(cfg, w, ids, mask, labels, bsz, s);
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for ((wt, mt), (vt, gt)) in w
        .iter_mut()
        .zip(m.iter_mut())
        .zip(v.iter_mut().zip(grads.iter()))
    {
        for i in 0..wt.len() {
            let g = gt[i];
            let m2 = BETA1 * mt[i] + (1.0 - BETA1) * g;
            let v2 = BETA2 * vt[i] + (1.0 - BETA2) * g * g;
            mt[i] = m2;
            vt[i] = v2;
            let mhat = m2 / bc1;
            let vhat = v2 / bc2;
            wt[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }
    Ok(loss)
}

/// Pull `count` consecutive f32 tensors (cloned) starting at `from`.
fn take_f32(inputs: &[&Literal], from: usize, count: usize)
    -> Result<Vec<Vec<f32>>>
{
    (from..from + count)
        .map(|i| inputs[i].f32_vec())
        .collect()
}

fn param_literals(
    cfg: &ConfigInfo,
    tensors: Vec<Vec<f32>>,
) -> Result<Vec<Literal>> {
    cfg.params
        .iter()
        .zip(tensors)
        .map(|(spec, data)| Literal::from_f32(data, spec.shape.clone()))
        .collect()
}

impl NativeProgram {
    /// (batch, seq) from the ids input literal.
    fn batch_dims(&self, ids: &Literal) -> Result<(usize, usize)> {
        match ids.shape() {
            [b, s] => Ok((*b, *s)),
            other => bail!("ids input has shape {other:?}, expected [B, S]"),
        }
    }
}

impl Executable for NativeProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let cfg = &self.cfg;
        let n = cfg.params.len();
        match self.kind {
            ProgramKind::Mezo
            | ProgramKind::MezoNaive
            | ProgramKind::MezoMulti(_) => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let mut w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let labels = inputs[n + 2].i32_slice()?;
                let seed = inputs[n + 3].u32_scalar()?;
                let lr = inputs[n + 4].f32_scalar()?;
                let eps = inputs[n + 5].f32_scalar()?;
                let loss = mezo_step(cfg, &mut w, ids, mask, labels, b, s,
                                     seed, lr, eps, self.kind)?;
                let mut outs = param_literals(cfg, w)?;
                outs.push(Literal::from_f32(vec![loss], vec![])?);
                Ok(outs)
            }
            ProgramKind::Adam => {
                let (b, s) = self.batch_dims(inputs[3 * n])?;
                let mut w = take_f32(inputs, 0, n)?;
                let mut m = take_f32(inputs, n, n)?;
                let mut v = take_f32(inputs, 2 * n, n)?;
                let ids = inputs[3 * n].i32_slice()?;
                let mask = inputs[3 * n + 1].f32_slice()?;
                let labels = inputs[3 * n + 2].i32_slice()?;
                let t = inputs[3 * n + 3].f32_scalar()?;
                let lr = inputs[3 * n + 4].f32_scalar()?;
                let loss = adam_step(cfg, &mut w, &mut m, &mut v, ids,
                                     mask, labels, b, s, t, lr)?;
                let mut outs = param_literals(cfg, w)?;
                outs.extend(param_literals(cfg, m)?);
                outs.extend(param_literals(cfg, v)?);
                outs.push(Literal::from_f32(vec![loss], vec![])?);
                Ok(outs)
            }
            ProgramKind::Eval => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let lg = model::logits(cfg, &w, ids, mask, b, s);
                let shape = if cfg.is_decoder() {
                    vec![b, s, cfg.vocab]
                } else {
                    vec![b, cfg.n_classes]
                };
                Ok(vec![Literal::from_f32(lg, shape)?])
            }
            ProgramKind::LossEval => {
                let (b, s) = self.batch_dims(inputs[n])?;
                let w = take_f32(inputs, 0, n)?;
                let ids = inputs[n].i32_slice()?;
                let mask = inputs[n + 1].f32_slice()?;
                let labels = inputs[n + 2].i32_slice()?;
                let loss = model::loss(cfg, &w, ids, mask, labels, b, s);
                Ok(vec![Literal::from_f32(vec![loss], vec![])?])
            }
        }
    }
}

// `spec` is carried for error reporting/debugging parity with the PJRT
// path; silence the lint without dropping the field.
impl NativeProgram {
    #[allow(dead_code)]
    fn file(&self) -> &str {
        &self.spec.file
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_kind_parses() {
        assert_eq!(ProgramKind::parse("mezo_step"), Some(ProgramKind::Mezo));
        assert_eq!(ProgramKind::parse("mezo_step_naive"),
                   Some(ProgramKind::MezoNaive));
        assert_eq!(ProgramKind::parse("mezo_step_q4"),
                   Some(ProgramKind::MezoMulti(4)));
        assert_eq!(ProgramKind::parse("adam_step"), Some(ProgramKind::Adam));
        assert_eq!(ProgramKind::parse("eval"), Some(ProgramKind::Eval));
        assert_eq!(ProgramKind::parse("loss_eval"),
                   Some(ProgramKind::LossEval));
        assert_eq!(ProgramKind::parse("mezo_step_q0"), None);
        assert_eq!(ProgramKind::parse("sgd_step"), None);
    }

    #[test]
    fn fused_and_naive_agree() {
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let init = params::init_params(&cfg);
        let ids = vec![1i32, 5, 9, 3, 0, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 0., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![2i32, 0];
        let mut fused = init.clone();
        let lf = mezo_step(&cfg, &mut fused, &ids, &mask, &labels, 2, 6,
                           99, 1e-2, 1e-3, ProgramKind::Mezo)
            .unwrap();
        let mut naive = init.clone();
        let ln = mezo_step(&cfg, &mut naive, &ids, &mask, &labels, 2, 6,
                           99, 1e-2, 1e-3, ProgramKind::MezoNaive)
            .unwrap();
        assert_eq!(lf, ln, "identical loss estimate");
        for (a, b) in fused.iter().zip(&naive) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn mezo_state_is_only_the_seed() {
        // two sessions with the same seed sequence produce bit-identical
        // parameters — no hidden state anywhere in the interpreter
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      3, false);
        let ids = vec![1i32; 12];
        let mask = vec![1f32; 12];
        let labels = vec![0i32, 1];
        let run = || {
            let mut w = params::init_params(&cfg);
            for step in 0..3u32 {
                mezo_step(&cfg, &mut w, &ids, &mask, &labels, 2, 6,
                          1000 + step, 1e-3, 1e-3, ProgramKind::Mezo)
                    .unwrap();
            }
            w
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn adam_descends_on_tiny_problem() {
        let cfg = params::make_config("t", "encoder", 13, 8, 1, 2, 16, 6,
                                      2, false);
        let mut w = params::init_params(&cfg);
        let mut m: Vec<Vec<f32>> =
            cfg.params.iter().map(|s| vec![0.0; s.elements()]).collect();
        let mut v = m.clone();
        let ids = vec![1i32, 5, 9, 3, 2, 0, 1, 2, 2, 7, 11, 0];
        let mask =
            vec![1f32, 1., 1., 1., 1., 0., 1., 1., 1., 1., 1., 0.];
        let labels = vec![1i32, 0];
        let mut losses = Vec::new();
        for t in 1..=25 {
            let l = adam_step(&cfg, &mut w, &mut m, &mut v, &ids, &mask,
                              &labels, 2, 6, t as f32, 5e-3)
                .unwrap();
            losses.push(l);
        }
        assert!(losses[24] < losses[0] * 0.5,
                "adam failed to descend: {losses:?}");
    }
}
