//! Counter-based RNG — the Rust twin of `python/compile/kernels/rng.py`.
//!
//! MeZO's memory trick depends on *regenerating* the Gaussian
//! perturbation `z` from `(seed, flat element index)` instead of storing
//! a parameter-sized tensor.  For a native backend to interoperate with
//! the AOT artifacts (same seed → same perturbation → same trajectory),
//! this stream must be bit-compatible with the Python/Pallas one:
//! murmur3's fmix32 finalizer over `idx * GOLDEN + seed`, mapped to
//! N(0,1) via Box–Muller on the (2*idx, 2*idx+1) sub-streams.
//!
//! `hash_u32`/`uniform01` are bit-exact by construction (integer ops and
//! an exact power-of-two scale); `gaussian` matches to libm precision
//! (see `rust/tests/native_golden.rs` for the cross-language pin).

const TWO_PI: f32 = 6.283_185_307_179_586_f32;
/// 2^-32: multiplying a u32 by this gives a uniform in [0, 1).
const U32_INV: f32 = 2.328_306_436_538_696_3e-10_f32;

/// Stateless hash (seed, idx) -> u32: murmur3 fmix32 of idx*GOLDEN+seed.
#[inline]
pub fn hash_u32(seed: u32, idx: u32) -> u32 {
    let mut x = idx.wrapping_mul(0x9E37_79B9).wrapping_add(seed);
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

/// Uniform in [0, 1] as f32, from one hash evaluation.
///
/// Nominally [0, 1), but hashes >= 0xFFFFFF80 round up to 2^32 in the
/// u32→f32 cast, so exactly 1.0 occurs with probability ~2^-25.  The
/// Python reference (`hash.astype(float32) * 2**-32`) rounds the same
/// way; bit-compatibility wins over range purity here, and the only
/// in-crate consumer ([`gaussian`]) is total on [0, 1].
#[inline]
pub fn uniform01(seed: u32, idx: u32) -> f32 {
    hash_u32(seed, idx) as f32 * U32_INV
}

/// Standard-normal sample for element index `idx` under `seed`.
///
/// Box–Muller over two decorrelated hash streams (2*idx, 2*idx+1); a
/// tiny floor keeps ln() finite when u1 == 0.
#[inline]
pub fn gaussian(seed: u32, idx: u32) -> f32 {
    let u1 = uniform01(seed, idx.wrapping_mul(2)).max(1e-12);
    let u2 = uniform01(seed, idx.wrapping_mul(2).wrapping_add(1));
    let r = (-2.0f32 * u1.ln()).sqrt();
    r * (TWO_PI * u2).cos()
}

/// `w[i] += scale * z(seed, base_offset + i)` over a flat tensor slab.
///
/// `base_offset` situates the tensor inside the virtual flat parameter
/// vector, so streams never overlap across tensors — identical to
/// `rng.gaussian_block` + the fused axpy in the Pallas kernels.
pub fn perturb(w: &mut [f32], seed: u32, base_offset: usize, scale: f32) {
    let base = base_offset as u32;
    for (i, x) in w.iter_mut().enumerate() {
        let z = gaussian(seed, base.wrapping_add(i as u32));
        *x += scale * z;
    }
}

/// `dst[i] = src[i] + scale * z(seed, base_offset + i)` — the shadow
/// variant of [`perturb`]: reads the base point, writes the perturbed
/// copy, and leaves `src` untouched.  This is what lets the k-query
/// SPSA workers evaluate every query at the *exact* base parameters
/// from cloned-once shadows, independent of worker count.
pub fn perturb_from(
    src: &[f32],
    dst: &mut [f32],
    seed: u32,
    base_offset: usize,
    scale: f32,
) {
    debug_assert_eq!(src.len(), dst.len());
    let base = base_offset as u32;
    for (i, (d, &x)) in dst.iter_mut().zip(src).enumerate() {
        let z = gaussian(seed, base.wrapping_add(i as u32));
        *d = x + scale * z;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_reference_values() {
        // pinned against python/compile/kernels/rng.py (see the
        // cross-language golden suite for the full set)
        assert_eq!(hash_u32(0, 0), 0x0000_0000);
        assert_eq!(hash_u32(0, 1), 0x92CA_2F0E);
        assert_eq!(hash_u32(1, 0), 0x514E_28B7);
        assert_eq!(hash_u32(42, 7), 0x21A2_7BDB);
    }

    #[test]
    fn uniform_in_range_and_deterministic() {
        for idx in 0..1000 {
            let u = uniform01(99, idx);
            // closed upper bound: see the doc comment on uniform01
            assert!((0.0..=1.0).contains(&u));
            assert_eq!(u, uniform01(99, idx));
        }
        // the rounding edge itself: a hash of u32::MAX rounds to 1.0
        assert_eq!(u32::MAX as f32 * 2.328_306_436_538_696_3e-10, 1.0);
    }

    #[test]
    fn gaussian_moments() {
        let n = 100_000u32;
        let xs: Vec<f64> =
            (0..n).map(|i| gaussian(7, i) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn perturb_restores_exactly() {
        // +eps then -eps is a bitwise no-op when the regenerated z
        // stream is identical — the property the fused step relies on
        let orig: Vec<f32> = (0..257).map(|i| (i as f32).sin()).collect();
        let mut w = orig.clone();
        perturb(&mut w, 0xC0FFEE, 1000, 1e-3);
        assert_ne!(w, orig);
        // float caveat: a + s*z - s*z == a only when the intermediate
        // is exact; instead check proximity element-wise
        perturb(&mut w, 0xC0FFEE, 1000, -1e-3);
        for (a, b) in w.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn perturb_from_matches_in_place_bitwise() {
        // writing base + scale*z into a shadow must equal perturbing a
        // copy of the base in place, bit for bit
        let base: Vec<f32> = (0..97).map(|i| (i as f32).cos()).collect();
        let orig = base.clone();
        let mut shadow = vec![0f32; 97];
        perturb_from(&base, &mut shadow, 0xBEEF, 500, 1e-3);
        let mut inplace = base.clone();
        perturb(&mut inplace, 0xBEEF, 500, 1e-3);
        assert_eq!(shadow, inplace);
        assert_eq!(base, orig, "the base point must be untouched");
    }

    #[test]
    fn streams_disjoint_across_offsets() {
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        perturb(&mut a, 5, 0, 1.0);
        perturb(&mut b, 5, 8, 1.0);
        assert_ne!(a, b);
        // offset 8 slab == tail of a longer slab at offset 0
        let mut c = vec![0.0f32; 16];
        perturb(&mut c, 5, 0, 1.0);
        assert_eq!(&c[8..], &b[..]);
    }
}
