//! Dense f32 kernels for the native interpreter.
//!
//! Plain safe Rust, written so LLVM autovectorizes the inner loops.
//! The serial cores are cache-blocked: `matmul` tiles N and K around a
//! packed B panel ([`KC`]×[`NC`], stack-resident, reused across every
//! row of the block) with a 4-deep K strip so each output row segment
//! is loaded and stored once per four rank-1 updates instead of once
//! per update; `matmul_at` strips its reduction rows the same way;
//! `matmul_bt` walks 8×8 output tiles so both operands' rows stay in
//! L1 across the tile.  Innermost loops are unit-stride over slices of
//! compiler-visible length.  Large kernels additionally split output
//! rows across a `std::thread::scope`.
//!
//! **f32 bit-identity contract**: every output element is reduced in
//! the exact per-element order of the naive kernels in [`reference`] —
//! K strictly ascending with one rounding per update (`matmul`,
//! `matmul_at`, `col_sums`: the blocking/strip-mining resequences
//! *which element* is updated next, never the adds within one
//! element), and `matmul_bt` computes each element with the same
//! 8-accumulator [`dot`].  Results are therefore bit-identical across
//! block sizes, thread counts, and the unblocked references — pinned
//! by the proptests in `rust/tests/proptests.rs`.
//!
//! Every kernel comes in two forms: an allocating wrapper (`matmul`,
//! `matmul_bias`, ...) and an `_into` variant that writes a
//! caller-provided buffer — the form the scratch-arena forward pass
//! ([`super::model::Scratch`]) uses so steady-state steps allocate
//! nothing.  The `_into` contract per kernel: `matmul_into` /
//! `matmul_at_into` ACCUMULATE (the buffer must arrive zeroed);
//! `matmul_bias_into` / `matmul_bt_into` overwrite every element.

use std::sync::atomic::{AtomicUsize, Ordering};

/// How many pool workers (fleet threads driving sessions) are
/// registered right now; the per-kernel budget divides by this.  0
/// outside fleet runs (treated as 1).
static ACTIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// The host's undivided kernel thread budget (cached after first
/// query).
pub fn host_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let v = CACHED.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let t = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
        .max(1);
    CACHED.store(t, Ordering::Relaxed);
    t
}

/// RAII registration of pool workers: holds `n` slots of the shared
/// compute budget and releases them on drop — panic- and
/// overlap-safe, unlike a swap/restore (two concurrent fleets simply
/// sum their worker counts, and an unwinding worker still releases).
pub struct PoolWorkers {
    n: usize,
}

impl Drop for PoolWorkers {
    fn drop(&mut self) {
        ACTIVE_WORKERS.fetch_sub(self.n, Ordering::Relaxed);
    }
}

/// Register `n` pool workers that will concurrently drive kernels
/// (the fleet scheduler holds this guard for the duration of its run;
/// see `coordinator::fleet`).  While any guards are live, each kernel
/// invocation (and SPSA pool) gets `host_threads / total` threads —
/// W workers above `PAR_FLOPS` used to request W×budget threads and
/// oversubscribe the host.  Thread counts never change kernel
/// *results* (pinned by the `*_matches_serial` tests), only how many
/// cores one kernel may occupy.
pub fn register_pool_workers(n: usize) -> PoolWorkers {
    ACTIVE_WORKERS.fetch_add(n, Ordering::Relaxed);
    PoolWorkers { n }
}

/// The currently registered pool-worker count (min 1).
pub fn active_workers() -> usize {
    ACTIVE_WORKERS.load(Ordering::Relaxed).max(1)
}

/// Worker threads available to ONE kernel invocation (and to the
/// k-query SPSA pool): the host budget divided by the active pool
/// workers, floored at 1.
pub fn n_threads() -> usize {
    (host_threads() / active_workers()).max(1)
}

/// Flop threshold below which threading costs more than it saves.
const PAR_FLOPS: usize = 1 << 21;

/// K-tile depth of the packed B panel (rows of B per pack).
const KC: usize = 64;
/// N-tile width of the packed B panel (columns of B per pack).
/// KC*NC f32 = 16 KiB — the panel lives on the stack and stays
/// L1-resident while every row of the block streams through it.
const NC: usize = 64;

/// One register tile of the blocked matmul:
/// `orow[j] += sum_kk arow[kk] * panel[kk*nb + j]`, K rows applied in
/// ascending order with the adds sequenced per element (the f32
/// bit-identity contract).  The 4-deep strip lets each `orow[j]` be
/// loaded and stored once per four updates.
#[inline]
fn mm_tile(arow: &[f32], panel: &[f32], nb: usize, orow: &mut [f32]) {
    let kb = arow.len();
    debug_assert!(panel.len() >= kb * nb);
    debug_assert_eq!(orow.len(), nb);
    let mut kk = 0;
    while kk + 4 <= kb {
        let (a0, a1, a2, a3) =
            (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
        let p0 = &panel[kk * nb..][..nb];
        let p1 = &panel[(kk + 1) * nb..][..nb];
        let p2 = &panel[(kk + 2) * nb..][..nb];
        let p3 = &panel[(kk + 3) * nb..][..nb];
        for (j, o) in orow.iter_mut().enumerate() {
            let mut v = *o;
            v += a0 * p0[j];
            v += a1 * p1[j];
            v += a2 * p2[j];
            v += a3 * p3[j];
            *o = v;
        }
        kk += 4;
    }
    while kk < kb {
        let av = arow[kk];
        let prow = &panel[kk * nb..][..nb];
        for (o, &pv) in orow.iter_mut().zip(prow) {
            *o += av * pv;
        }
        kk += 1;
    }
}

/// Serial cache-blocked matmul over a row range:
/// out[r, :] += a[r, :] @ b.  Tiles N and K; B tiles narrower than a
/// full stripe are packed into a stack panel (contiguous, L1-resident,
/// reused across every row of the block).  Bit-identical to
/// [`reference::matmul_into`] for any block size.
fn mm_rows(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    if n == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / n;
    if n <= NC {
        // a full-width stripe of B is already contiguous: the slice
        // b[kc*n ..] IS the panel, so skip the pack
        for kc in (0..k).step_by(KC) {
            let kb = KC.min(k - kc);
            let bsub = &b[kc * n..(kc + kb) * n];
            for i in 0..rows {
                mm_tile(&a[i * k + kc..i * k + kc + kb], bsub, n,
                        &mut out[i * n..(i + 1) * n]);
            }
        }
        return;
    }
    let mut panel = [0f32; KC * NC];
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for kc in (0..k).step_by(KC) {
            let kb = KC.min(k - kc);
            for kk in 0..kb {
                let src = (kc + kk) * n + jc;
                panel[kk * nb..(kk + 1) * nb]
                    .copy_from_slice(&b[src..src + nb]);
            }
            let p = &panel[..kb * nb];
            for i in 0..rows {
                mm_tile(&a[i * k + kc..i * k + kc + kb], p, nb,
                        &mut out[i * n + jc..i * n + jc + nb]);
            }
        }
    }
}

/// `out += a [m,k] @ b [k,n]`; `out` must arrive zeroed for a plain
/// product.  Row-parallel above [`PAR_FLOPS`], bit-deterministic.
pub fn matmul_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_rows(a, b, k, n, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * k..lo * k + (ochunk.len() / n) * k];
            sc.spawn(move || mm_rows(a, b, k, n, ochunk));
        }
    });
}

/// `a [m,k] @ b [k,n] -> [m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// `out = a [m,k] @ b [k,n] + bias [n]` — overwrites `out` (each row is
/// seeded with the bias, then accumulated over).
pub fn matmul_bias_into(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    if n == 0 {
        return;
    }
    for row in out.chunks_mut(n) {
        row.copy_from_slice(bias);
    }
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_rows(a, b, k, n, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * k..lo * k + (ochunk.len() / n) * k];
            sc.spawn(move || mm_rows(a, b, k, n, ochunk));
        }
    });
}

/// `a [m,k] @ b [k,n] + bias [n] -> [m,n]`.
pub fn matmul_bias(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    matmul_bias_into(a, b, bias, m, k, n, &mut out);
    out
}

/// Output-row tile height for the blocked a^T@b kernel: B streams
/// through once per TI_AT output rows (instead of once per row) while
/// the TI_AT×n output block stays L1-resident.
const TI_AT: usize = 8;

/// Serial cache-blocked a^T@b over an output-row (i.e. k-index) range
/// starting at `k_lo`.  Accumulation over `mm` runs in increasing
/// order for every output element — the full `0..m` sweep happens
/// inside each output-row tile, and the 4-deep strip sequences its
/// adds per element — so results are bit-identical for any tile
/// height and any split of the k range
/// ([`reference::matmul_at_into`] is the oracle).
fn mm_at_cols(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    k_lo: usize,
    out: &mut [f32],
) {
    if n == 0 || out.is_empty() {
        return;
    }
    let krange = out.len() / n;
    for kic in (0..krange).step_by(TI_AT) {
        let kib = TI_AT.min(krange - kic);
        let oblock = &mut out[kic * n..(kic + kib) * n];
        let mut mm = 0;
        while mm + 4 <= m {
            let b0 = &b[mm * n..][..n];
            let b1 = &b[(mm + 1) * n..][..n];
            let b2 = &b[(mm + 2) * n..][..n];
            let b3 = &b[(mm + 3) * n..][..n];
            for (kio, orow) in
                oblock.chunks_exact_mut(n).enumerate()
            {
                let col = k_lo + kic + kio;
                let a0 = a[mm * k + col];
                let a1 = a[(mm + 1) * k + col];
                let a2 = a[(mm + 2) * k + col];
                let a3 = a[(mm + 3) * k + col];
                for (j, o) in orow.iter_mut().enumerate() {
                    let mut v = *o;
                    v += a0 * b0[j];
                    v += a1 * b1[j];
                    v += a2 * b2[j];
                    v += a3 * b3[j];
                    *o = v;
                }
            }
            mm += 4;
        }
        while mm < m {
            let brow = &b[mm * n..][..n];
            for (kio, orow) in oblock.chunks_exact_mut(n).enumerate()
            {
                let col = k_lo + kic + kio;
                let av = a[mm * k + col];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            mm += 1;
        }
    }
}

/// `out += a^T [k,m] @ b [m,n]` (a stored as [m,k]; dW = x^T dy); `out`
/// must arrive zeroed for a plain product.  Parallel across output-row
/// (k-index) chunks above [`PAR_FLOPS`]; the per-element reduction over
/// `m` stays in sequential order, so results are bit-identical to the
/// serial path.
pub fn matmul_at_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let threads = n_threads();
    if threads <= 1 || k < 2 || m * k * n < PAR_FLOPS {
        mm_at_cols(a, b, m, k, n, 0, out);
        return;
    }
    let rows_per = k.div_ceil(threads);
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let k_lo = ci * rows_per;
            sc.spawn(move || mm_at_cols(a, b, m, k, n, k_lo, ochunk));
        }
    });
}

/// `a^T [k,m] @ b [m,n] -> [k,n]`  (a stored as [m,k]; dW = x^T dy).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; k * n];
    matmul_at_into(a, b, m, k, n, &mut out);
    out
}

/// 8-accumulator dot product (vectorizes without fp reassociation).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let ac = &a[c * 8..c * 8 + 8];
        let bc = &b[c * 8..c * 8 + 8];
        for j in 0..8 {
            acc[j] += ac[j] * bc[j];
        }
    }
    let mut tail = 0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    let mut s = tail;
    for v in acc {
        s += v;
    }
    s
}

/// Output tile edge for the blocked `a @ b^T` kernel: within one
/// TB×TB tile, TB rows of `a` and TB rows of `b` (≤ 2·TB·n bytes)
/// stay cache-hot and are reused TB times each.
const TB: usize = 8;

/// Serial row range of `a @ b^T` (overwrites).  Walks TB×TB output
/// tiles for locality; every element is still the same 8-accumulator
/// [`dot`] of the same two rows, so tiling cannot change results
/// ([`reference::matmul_bt_into`] is the oracle).
fn mm_bt_rows(a: &[f32], b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    if k == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / k;
    for i0 in (0..rows).step_by(TB) {
        let ib = TB.min(rows - i0);
        for j0 in (0..k).step_by(TB) {
            let jb = TB.min(k - j0);
            for i in i0..i0 + ib {
                let arow = &a[i * n..(i + 1) * n];
                let orow = &mut out[i * k..(i + 1) * k];
                for j in j0..j0 + jb {
                    orow[j] = dot(arow, &b[j * n..(j + 1) * n]);
                }
            }
        }
    }
}

/// `out = a [m,n] @ b [k,n]^T` — overwrites every element of `out`.
pub fn matmul_bt_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_bt_rows(a, b, n, k, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * k).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * n..lo * n + (ochunk.len() / k) * n];
            sc.spawn(move || mm_bt_rows(a, b, n, k, ochunk));
        }
    });
}

/// `a [m,n] @ b [k,n]^T -> [m,k]`  (dx = dy @ W^T; decoder tied logits).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; m * k];
    matmul_bt_into(a, b, m, n, k, &mut out);
    out
}

/// `out[j] += sum_rows a[., j]` — column sums of an [rows, n] matrix,
/// accumulated row-by-row in order (the bias-gradient kernel).  Rows
/// are strip-mined four at a time with the adds sequenced per column,
/// so each `out[j]` is loaded/stored once per four rows while the
/// per-element reduction order stays exactly row-ascending
/// ([`reference::col_sums_into`] is the oracle).
pub fn col_sums_into(a: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    if n == 0 {
        return;
    }
    let mut strips = a.chunks_exact(4 * n);
    for strip in &mut strips {
        let r0 = &strip[..n];
        let r1 = &strip[n..2 * n];
        let r2 = &strip[2 * n..3 * n];
        let r3 = &strip[3 * n..4 * n];
        for (j, o) in out.iter_mut().enumerate() {
            let mut v = *o;
            v += r0[j];
            v += r1[j];
            v += r2[j];
            v += r3[j];
            *o = v;
        }
    }
    for row in strips.remainder().chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Analytic cost of one dense-kernel call — the single source of the
/// flop/byte formulas shared by `benches/hotpath.rs` (measured
/// GFLOP/s) and the trace layer's per-step kernel profile
/// (`telemetry::trace::step_kernel_profile`), so the bench harness
/// and `pocketllm trace` can never disagree about what a call costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCost {
    /// Floating-point operations (multiply-adds counted as 2).
    pub flops: u64,
    /// Minimum f32 bytes moved: every operand read once, every
    /// output written once.
    pub bytes: u64,
}

/// Cost of one `[m,k] @ [k,n]` matmul call — also the model for the
/// `_bias`, `_at`, and `_bt` variants, whose flop counts and minimum
/// traffic match on their own (m, k, n).
pub fn matmul_cost(m: usize, k: usize, n: usize) -> KernelCost {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    KernelCost {
        flops: 2u64.saturating_mul(m).saturating_mul(k)
            .saturating_mul(n),
        bytes: 4u64.saturating_mul(
            m.saturating_mul(k)
                .saturating_add(k.saturating_mul(n))
                .saturating_add(m.saturating_mul(n)),
        ),
    }
}

/// Cost of one `[rows,n]` column-sum call (bias-gradient kernel): one
/// add per element, matrix read once plus output written once.
pub fn col_sums_cost(rows: usize, n: usize) -> KernelCost {
    let (rows, n) = (rows as u64, n as u64);
    KernelCost {
        flops: rows.saturating_mul(n),
        bytes: 4u64.saturating_mul(
            rows.saturating_mul(n).saturating_add(n),
        ),
    }
}

/// tanh-approximation GELU (matches the kernels exactly).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f32; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu(x) / dx for the backward pass.
#[inline]
pub fn dgelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f32;
    let t = (C * (x + 0.044715 * x * x * x)).tanh();
    0.5 * (1.0 + t)
        + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Naive, unblocked oracles for the blocked kernels above.
///
/// Each computes every output element with the exact per-element f32
/// reduction order the blocked kernels preserve (K strictly
/// ascending, one rounding per update; `matmul_bt` via the same
/// 8-accumulator [`dot`]), so tests pin *bit-identity* against them —
/// not approximate closeness.  They are kept `pub` as the oracle for
/// `rust/tests/proptests.rs` and the bench-smoke canary in
/// `benches/hotpath.rs`; never call them from a hot path.
pub mod reference {
    use super::dot;

    /// `out += a [m,k] @ b [k,n]`, element-at-a-time.
    pub fn matmul_into(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = out[i * n + j];
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
    }

    /// `out = a @ b + bias` (overwrites).
    pub fn matmul_bias_into(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), m * n);
        if n == 0 {
            return;
        }
        for row in out.chunks_mut(n) {
            row.copy_from_slice(bias);
        }
        matmul_into(a, b, m, k, n, out);
    }

    /// `out += a^T [k,m] @ b [m,n]` (a stored as [m,k]),
    /// element-at-a-time with `mm` ascending.
    pub fn matmul_at_into(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), k * n);
        for ki in 0..k {
            for j in 0..n {
                let mut acc = out[ki * n + j];
                for mm in 0..m {
                    acc += a[mm * k + ki] * b[mm * n + j];
                }
                out[ki * n + j] = acc;
            }
        }
    }

    /// `out = a [m,n] @ b [k,n]^T` (overwrites), one [`dot`] per
    /// element.
    pub fn matmul_bt_into(
        a: &[f32],
        b: &[f32],
        m: usize,
        n: usize,
        k: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), m * k);
        for i in 0..m {
            for j in 0..k {
                out[i * k + j] =
                    dot(&a[i * n..(i + 1) * n], &b[j * n..(j + 1) * n]);
            }
        }
    }

    /// `out[j] += sum_rows a[., j]`, row-ascending.
    pub fn col_sums_into(a: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), n);
        if n == 0 {
            return;
        }
        for row in a.chunks_exact(n) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
        -> Vec<f32>
    {
        let mut out = vec![0f32; m * n];
        reference::matmul_into(a, b, m, k, n, &mut out);
        out
    }

    fn randv(n: usize, seed: u32) -> Vec<f32> {
        (0..n)
            .map(|i| super::super::rng::uniform01(seed, i as u32) - 0.5)
            .collect()
    }

    #[test]
    fn matmul_matches_naive() {
        // bit-identical, not approximately equal: the blocked kernel
        // preserves the reference's per-element reduction order
        let (m, k, n) = (7, 5, 9);
        let a = randv(m * k, 1);
        let b = randv(k * n, 2);
        assert_eq!(matmul(&a, &b, m, k, n), naive(&a, &b, m, k, n));
    }

    #[test]
    fn blocked_kernels_bit_match_references_on_ragged_shapes() {
        // shapes straddling the KC/NC/TB/TI_AT block edges, plus
        // degenerate 1×N / M×1 / empty dims — every kernel must be
        // bit-identical to its naive oracle (the contract the
        // proptests in rust/tests/proptests.rs hammer at volume)
        let shapes: &[(usize, usize, usize)] = &[
            (1, 1, 1),
            (1, 130, 1),
            (65, 1, 67),
            (64, 64, 64),
            (65, 63, 129),
            (3, 200, 70),
            (0, 5, 4),
            (5, 0, 4),
            (5, 4, 0),
        ];
        for &(m, k, n) in shapes {
            let a = randv(m * k, 31);
            let b = randv(k * n, 32);
            let bias = randv(n, 33);
            let mut got = randv(m * n, 34);
            let mut want = got.clone();
            matmul_into(&a, &b, m, k, n, &mut got);
            reference::matmul_into(&a, &b, m, k, n, &mut want);
            assert_eq!(got, want, "matmul {m}x{k}x{n}");

            let mut got = vec![7f32; m * n];
            let mut want = vec![8f32; m * n];
            matmul_bias_into(&a, &b, &bias, m, k, n, &mut got);
            reference::matmul_bias_into(&a, &b, &bias, m, k, n,
                                        &mut want);
            assert_eq!(got, want, "matmul_bias {m}x{k}x{n}");

            // a^T @ b: a [m,k], b [m,n] -> [k,n]
            let b2 = randv(m * n, 35);
            let mut got = randv(k * n, 36);
            let mut want = got.clone();
            matmul_at_into(&a, &b2, m, k, n, &mut got);
            reference::matmul_at_into(&a, &b2, m, k, n, &mut want);
            assert_eq!(got, want, "matmul_at {m}x{k}x{n}");

            // a @ c^T: a [m,k], c [n,k] -> [m,n]
            let c = randv(n * k, 37);
            let mut got = vec![9f32; m * n];
            let mut want = vec![10f32; m * n];
            matmul_bt_into(&a, &c, m, k, n, &mut got);
            reference::matmul_bt_into(&a, &c, m, k, n, &mut want);
            assert_eq!(got, want, "matmul_bt {m}x{k}x{n}");

            // column sums of a [m,k]
            let mut got = randv(k, 38);
            let mut want = got.clone();
            col_sums_into(&a, k, &mut got);
            reference::col_sums_into(&a, k, &mut want);
            assert_eq!(got, want, "col_sums {m}x{k}");
        }
    }

    #[test]
    fn kernel_budget_divides_by_active_workers() {
        // the fleet's compute-budget contract: W registered workers
        // shrink the per-kernel budget to host/W (floor 1), guards
        // stack additively and release on drop, and the division
        // changes scheduling only, never results.  (This test is the
        // only writer in the lib test binary; fleet runs live in
        // separate integration-test processes.)
        let host = host_threads();
        assert_eq!(n_threads(), host);
        {
            let _two = register_pool_workers(2);
            assert_eq!(n_threads(), (host / 2).max(1));
            {
                // keep this window tiny: while it is open, every
                // concurrent test's kernels fall to 1 thread
                let _more = register_pool_workers(62);
                assert_eq!(active_workers(), 64, "guards stack");
                assert_eq!(n_threads(), 1, "budget floors at one");
            }
            assert_eq!(active_workers(), 2, "inner guard released");
            // a PAR_FLOPS-crossing matmul under a divided (but still
            // multi-thread on CI hosts) budget is bit-identical to
            // the serial kernel
            let (m, k, n) = (128, 64, 300);
            let a = randv(m * k, 21);
            let b = randv(k * n, 22);
            let divided = matmul(&a, &b, m, k, n);
            let mut serial = vec![0f32; m * n];
            mm_rows(&a, &b, k, n, &mut serial);
            assert_eq!(divided, serial);
        }
        assert_eq!(n_threads(), host, "all guards released");
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        // large enough to cross the PAR_FLOPS threshold
        let (m, k, n) = (128, 64, 300);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let got = matmul(&a, &b, m, k, n);
        let mut serial = vec![0f32; m * n];
        mm_rows(&a, &b, k, n, &mut serial);
        assert_eq!(got, serial, "threading must not change results");
    }

    #[test]
    fn parallel_matmul_at_matches_serial() {
        // dW = x^T dy at a size that crosses the PAR_FLOPS threshold
        let (m, k, n) = (96, 128, 200);
        let a = randv(m * k, 10);
        let b = randv(m * n, 11);
        let got = matmul_at(&a, &b, m, k, n);
        let mut serial = vec![0f32; k * n];
        mm_at_cols(&a, &b, m, k, n, 0, &mut serial);
        assert_eq!(got, serial, "threading must not change dW results");
    }

    #[test]
    fn transposed_variants() {
        let (m, k, n) = (6, 4, 5);
        let a = randv(m * k, 5);
        let b = randv(m * n, 6);
        // a^T @ b == naive(transpose(a), b)
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let got = matmul_at(&a, &b, m, k, n);
        let want = naive(&at, &b, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
        // a @ c^T
        let c = randv(n * k, 7); // [k2=n rows? keep simple: b2 [j,k]]
        let got = matmul_bt(&a, &c, m, k, n);
        // naive: out[i, j] = sum_q a[i,q] * c[j,q], a [m,k], c [n,k]
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for q in 0..k {
                    acc += a[i * k + q] * c[j * k + q];
                }
                want[i * n + j] = acc;
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let (m, k, n) = (6, 4, 5);
        let a = randv(m * k, 12);
        let b = randv(k * n, 13);
        let bias = randv(n, 14);
        let mut out = vec![0f32; m * n];
        matmul_into(&a, &b, m, k, n, &mut out);
        assert_eq!(out, matmul(&a, &b, m, k, n));
        // bias form overwrites stale contents
        let mut out = vec![7f32; m * n];
        matmul_bias_into(&a, &b, &bias, m, k, n, &mut out);
        assert_eq!(out, matmul_bias(&a, &b, &bias, m, k, n));
        let c = randv(n * k, 15);
        let mut out = vec![9f32; m * n];
        matmul_bt_into(&a, &c, m, k, n, &mut out);
        assert_eq!(out, matmul_bt(&a, &c, m, k, n));
        let b2 = randv(m * n, 16);
        let mut out = vec![0f32; k * n];
        matmul_at_into(&a, &b2, m, k, n, &mut out);
        assert_eq!(out, matmul_at(&a, &b2, m, k, n));
    }

    #[test]
    fn col_sums_accumulate() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0f32; 2];
        col_sums_into(&a, 2, &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
    }

    #[test]
    fn bias_and_gelu() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 3.0, 4.0, 5.0];
        let out = matmul_bias(&a, &b, &[10.0, 20.0], 2, 2, 2);
        assert_eq!(out, vec![12.0, 23.0, 14.0, 25.0]);
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(3.0) - 2.9963627).abs() < 1e-4);
        // dgelu matches finite difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((dgelu(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn dot_matches_sequential() {
        let a = randv(103, 8);
        let b = randv(103, 9);
        let seq: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - seq).abs() < 1e-4);
    }
}
