//! Dense f32 kernels for the native interpreter.
//!
//! Plain safe Rust, written so LLVM autovectorizes the inner loops:
//! matmuls use the i-k-j order (unit-stride writes, no horizontal
//! reductions) and dot products keep 8 independent accumulators.  Large
//! matmuls split output rows across a `std::thread::scope` — results
//! stay bit-deterministic because each output element is always reduced
//! in the same sequential order regardless of the thread count.
//!
//! Every kernel comes in two forms: an allocating wrapper (`matmul`,
//! `matmul_bias`, ...) and an `_into` variant that writes a
//! caller-provided buffer — the form the scratch-arena forward pass
//! ([`super::model::Scratch`]) uses so steady-state steps allocate
//! nothing.  The `_into` contract per kernel: `matmul_into` /
//! `matmul_at_into` ACCUMULATE (the buffer must arrive zeroed);
//! `matmul_bias_into` / `matmul_bt_into` overwrite every element.

use std::sync::atomic::{AtomicUsize, Ordering};

/// How many pool workers (fleet threads driving sessions) are
/// registered right now; the per-kernel budget divides by this.  0
/// outside fleet runs (treated as 1).
static ACTIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// The host's undivided kernel thread budget (cached after first
/// query).
pub fn host_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let v = CACHED.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let t = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
        .max(1);
    CACHED.store(t, Ordering::Relaxed);
    t
}

/// RAII registration of pool workers: holds `n` slots of the shared
/// compute budget and releases them on drop — panic- and
/// overlap-safe, unlike a swap/restore (two concurrent fleets simply
/// sum their worker counts, and an unwinding worker still releases).
pub struct PoolWorkers {
    n: usize,
}

impl Drop for PoolWorkers {
    fn drop(&mut self) {
        ACTIVE_WORKERS.fetch_sub(self.n, Ordering::Relaxed);
    }
}

/// Register `n` pool workers that will concurrently drive kernels
/// (the fleet scheduler holds this guard for the duration of its run;
/// see `coordinator::fleet`).  While any guards are live, each kernel
/// invocation (and SPSA pool) gets `host_threads / total` threads —
/// W workers above `PAR_FLOPS` used to request W×budget threads and
/// oversubscribe the host.  Thread counts never change kernel
/// *results* (pinned by the `*_matches_serial` tests), only how many
/// cores one kernel may occupy.
pub fn register_pool_workers(n: usize) -> PoolWorkers {
    ACTIVE_WORKERS.fetch_add(n, Ordering::Relaxed);
    PoolWorkers { n }
}

/// The currently registered pool-worker count (min 1).
pub fn active_workers() -> usize {
    ACTIVE_WORKERS.load(Ordering::Relaxed).max(1)
}

/// Worker threads available to ONE kernel invocation (and to the
/// k-query SPSA pool): the host budget divided by the active pool
/// workers, floored at 1.
pub fn n_threads() -> usize {
    (host_threads() / active_workers()).max(1)
}

/// Flop threshold below which threading costs more than it saves.
const PAR_FLOPS: usize = 1 << 21;

/// Serial i-k-j matmul over a row range: out[r, :] += a[r, :] @ b.
fn mm_rows(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out += a [m,k] @ b [k,n]`; `out` must arrive zeroed for a plain
/// product.  Row-parallel above [`PAR_FLOPS`], bit-deterministic.
pub fn matmul_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_rows(a, b, k, n, out);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * k..lo * k + (ochunk.len() / n) * k];
            sc.spawn(move || mm_rows(a, b, k, n, ochunk));
        }
    });
}

/// `a [m,k] @ b [k,n] -> [m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// `out = a [m,k] @ b [k,n] + bias [n]` — overwrites `out` (each row is
/// seeded with the bias, then accumulated over).
pub fn matmul_bias_into(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for row in out.chunks_mut(n) {
        row.copy_from_slice(bias);
    }
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_rows(a, b, k, n, out);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * k..lo * k + (ochunk.len() / n) * k];
            sc.spawn(move || mm_rows(a, b, k, n, ochunk));
        }
    });
}

/// `a [m,k] @ b [k,n] + bias [n] -> [m,n]`.
pub fn matmul_bias(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    matmul_bias_into(a, b, bias, m, k, n, &mut out);
    out
}

/// Serial a^T@b over an output-row (i.e. k-index) range starting at
/// `k_lo`.  Accumulation over `mm` runs in increasing order for every
/// output element, independent of how the k range is split.
fn mm_at_cols(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    k_lo: usize,
    out: &mut [f32],
) {
    for mm in 0..m {
        let arow = &a[mm * k..(mm + 1) * k];
        let brow = &b[mm * n..(mm + 1) * n];
        for (ki, orow) in out.chunks_exact_mut(n).enumerate() {
            let av = arow[k_lo + ki];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out += a^T [k,m] @ b [m,n]` (a stored as [m,k]; dW = x^T dy); `out`
/// must arrive zeroed for a plain product.  Parallel across output-row
/// (k-index) chunks above [`PAR_FLOPS`]; the per-element reduction over
/// `m` stays in sequential order, so results are bit-identical to the
/// serial path.
pub fn matmul_at_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let threads = n_threads();
    if threads <= 1 || k < 2 || m * k * n < PAR_FLOPS {
        mm_at_cols(a, b, m, k, n, 0, out);
        return;
    }
    let rows_per = (k + threads - 1) / threads;
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let k_lo = ci * rows_per;
            sc.spawn(move || mm_at_cols(a, b, m, k, n, k_lo, ochunk));
        }
    });
}

/// `a^T [k,m] @ b [m,n] -> [k,n]`  (a stored as [m,k]; dW = x^T dy).
pub fn matmul_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; k * n];
    matmul_at_into(a, b, m, k, n, &mut out);
    out
}

/// 8-accumulator dot product (vectorizes without fp reassociation).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let ac = &a[c * 8..c * 8 + 8];
        let bc = &b[c * 8..c * 8 + 8];
        for j in 0..8 {
            acc[j] += ac[j] * bc[j];
        }
    }
    let mut tail = 0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    let mut s = tail;
    for v in acc {
        s += v;
    }
    s
}

/// Serial row range of `a @ b^T` (overwrites).
fn mm_bt_rows(a: &[f32], b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    let rows = out.len() / k;
    for i in 0..rows {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (kk, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[kk * n..(kk + 1) * n]);
        }
    }
}

/// `out = a [m,n] @ b [k,n]^T` — overwrites every element of `out`.
pub fn matmul_bt_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    let threads = n_threads();
    if threads <= 1 || m < 2 || m * k * n < PAR_FLOPS {
        mm_bt_rows(a, b, n, k, out);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|sc| {
        for (ci, ochunk) in out.chunks_mut(rows_per * k).enumerate() {
            let lo = ci * rows_per;
            let a = &a[lo * n..lo * n + (ochunk.len() / k) * n];
            sc.spawn(move || mm_bt_rows(a, b, n, k, ochunk));
        }
    });
}

/// `a [m,n] @ b [k,n]^T -> [m,k]`  (dx = dy @ W^T; decoder tied logits).
pub fn matmul_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize)
    -> Vec<f32>
{
    let mut out = vec![0f32; m * k];
    matmul_bt_into(a, b, m, n, k, &mut out);
    out
}

/// `out[j] += sum_rows a[., j]` — column sums of an [rows, n] matrix,
/// accumulated row-by-row in order (the bias-gradient kernel).
pub fn col_sums_into(a: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    for row in a.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// tanh-approximation GELU (matches the kernels exactly).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f32; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu(x) / dx for the backward pass.
#[inline]
pub fn dgelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_560_802_865_4_f32;
    let t = (C * (x + 0.044715 * x * x * x)).tanh();
    0.5 * (1.0 + t)
        + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * 0.044715 * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
        -> Vec<f32>
    {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn randv(n: usize, seed: u32) -> Vec<f32> {
        (0..n)
            .map(|i| super::super::rng::uniform01(seed, i as u32) - 0.5)
            .collect()
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, k, n) = (7, 5, 9);
        let a = randv(m * k, 1);
        let b = randv(k * n, 2);
        let got = matmul(&a, &b, m, k, n);
        let want = naive(&a, &b, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn kernel_budget_divides_by_active_workers() {
        // the fleet's compute-budget contract: W registered workers
        // shrink the per-kernel budget to host/W (floor 1), guards
        // stack additively and release on drop, and the division
        // changes scheduling only, never results.  (This test is the
        // only writer in the lib test binary; fleet runs live in
        // separate integration-test processes.)
        let host = host_threads();
        assert_eq!(n_threads(), host);
        {
            let _two = register_pool_workers(2);
            assert_eq!(n_threads(), (host / 2).max(1));
            {
                // keep this window tiny: while it is open, every
                // concurrent test's kernels fall to 1 thread
                let _more = register_pool_workers(62);
                assert_eq!(active_workers(), 64, "guards stack");
                assert_eq!(n_threads(), 1, "budget floors at one");
            }
            assert_eq!(active_workers(), 2, "inner guard released");
            // a PAR_FLOPS-crossing matmul under a divided (but still
            // multi-thread on CI hosts) budget is bit-identical to
            // the serial kernel
            let (m, k, n) = (128, 64, 300);
            let a = randv(m * k, 21);
            let b = randv(k * n, 22);
            let divided = matmul(&a, &b, m, k, n);
            let mut serial = vec![0f32; m * n];
            mm_rows(&a, &b, k, n, &mut serial);
            assert_eq!(divided, serial);
        }
        assert_eq!(n_threads(), host, "all guards released");
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        // large enough to cross the PAR_FLOPS threshold
        let (m, k, n) = (128, 64, 300);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let got = matmul(&a, &b, m, k, n);
        let mut serial = vec![0f32; m * n];
        mm_rows(&a, &b, k, n, &mut serial);
        assert_eq!(got, serial, "threading must not change results");
    }

    #[test]
    fn parallel_matmul_at_matches_serial() {
        // dW = x^T dy at a size that crosses the PAR_FLOPS threshold
        let (m, k, n) = (96, 128, 200);
        let a = randv(m * k, 10);
        let b = randv(m * n, 11);
        let got = matmul_at(&a, &b, m, k, n);
        let mut serial = vec![0f32; k * n];
        mm_at_cols(&a, &b, m, k, n, 0, &mut serial);
        assert_eq!(got, serial, "threading must not change dW results");
    }

    #[test]
    fn transposed_variants() {
        let (m, k, n) = (6, 4, 5);
        let a = randv(m * k, 5);
        let b = randv(m * n, 6);
        // a^T @ b == naive(transpose(a), b)
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let got = matmul_at(&a, &b, m, k, n);
        let want = naive(&at, &b, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
        // a @ c^T
        let c = randv(n * k, 7); // [k2=n rows? keep simple: b2 [j,k]]
        let got = matmul_bt(&a, &c, m, k, n);
        // naive: out[i, j] = sum_q a[i,q] * c[j,q], a [m,k], c [n,k]
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for q in 0..k {
                    acc += a[i * k + q] * c[j * k + q];
                }
                want[i * n + j] = acc;
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let (m, k, n) = (6, 4, 5);
        let a = randv(m * k, 12);
        let b = randv(k * n, 13);
        let bias = randv(n, 14);
        let mut out = vec![0f32; m * n];
        matmul_into(&a, &b, m, k, n, &mut out);
        assert_eq!(out, matmul(&a, &b, m, k, n));
        // bias form overwrites stale contents
        let mut out = vec![7f32; m * n];
        matmul_bias_into(&a, &b, &bias, m, k, n, &mut out);
        assert_eq!(out, matmul_bias(&a, &b, &bias, m, k, n));
        let c = randv(n * k, 15);
        let mut out = vec![9f32; m * n];
        matmul_bt_into(&a, &c, m, k, n, &mut out);
        assert_eq!(out, matmul_bt(&a, &c, m, k, n));
        let b2 = randv(m * n, 16);
        let mut out = vec![0f32; k * n];
        matmul_at_into(&a, &b2, m, k, n, &mut out);
        assert_eq!(out, matmul_at(&a, &b2, m, k, n));
    }

    #[test]
    fn col_sums_accumulate() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0f32; 2];
        col_sums_into(&a, 2, &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
    }

    #[test]
    fn bias_and_gelu() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 3.0, 4.0, 5.0];
        let out = matmul_bias(&a, &b, &[10.0, 20.0], 2, 2, 2);
        assert_eq!(out, vec![12.0, 23.0, 14.0, 25.0]);
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(3.0) - 2.9963627).abs() < 1e-4);
        // dgelu matches finite difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((dgelu(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn dot_matches_sequential() {
        let a = randv(103, 8);
        let b = randv(103, 9);
        let seq: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - seq).abs() < 1e-4);
    }
}
