//! Canonical parameter layout + deterministic init for the native path.
//!
//! Mirrors `python/compile/model.py::param_specs` exactly: the order IS
//! the step-program calling convention, and the flat `offset` situates
//! each tensor in the shared MeZO z-stream.  The cross-language
//! invariant is pinned by `ModelDims::n_params` (device model) agreeing
//! with these specs for every config — tested in the integration suite.
//!
//! Init differs from the Python artifacts' `init_params.bin` only in the
//! random draws (numpy's Philox vs our SplitMix64): same structural
//! rules (zero biases/head, unit LN gains, 0.02 embeddings, 1/sqrt(fan
//! in) projections), so hermetic native runs behave like artifact runs
//! without needing `make artifacts`.

use crate::runtime::manifest::{ConfigInfo, ParamSpecInfo};
use crate::util::rng::Rng;

/// Canonical ordered parameter list for one architecture.
pub fn param_specs(
    decoder: bool,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    d_ff: usize,
    max_seq: usize,
    n_classes: usize,
) -> Vec<ParamSpecInfo> {
    let d = d_model;
    let mut shapes: Vec<(String, Vec<usize>)> = Vec::new();
    shapes.push(("embed.tok".into(), vec![vocab, d]));
    shapes.push(("embed.pos".into(), vec![max_seq, d]));
    for i in 0..n_layers {
        let p = format!("layer{i}.");
        let mut push = |suffix: &str, shape: Vec<usize>| {
            shapes.push((format!("{p}{suffix}"), shape));
        };
        push("ln1.g", vec![d]);
        push("ln1.b", vec![d]);
        push("attn.wq", vec![d, d]);
        push("attn.bq", vec![d]);
        push("attn.wk", vec![d, d]);
        push("attn.bk", vec![d]);
        push("attn.wv", vec![d, d]);
        push("attn.bv", vec![d]);
        push("attn.wo", vec![d, d]);
        push("attn.bo", vec![d]);
        push("ln2.g", vec![d]);
        push("ln2.b", vec![d]);
        push("ffn.w1", vec![d, d_ff]);
        push("ffn.b1", vec![d_ff]);
        push("ffn.w2", vec![d_ff, d]);
        push("ffn.b2", vec![d]);
    }
    shapes.push(("final_ln.g".into(), vec![d]));
    shapes.push(("final_ln.b".into(), vec![d]));
    if !decoder {
        shapes.push(("head.w".into(), vec![d, n_classes]));
        shapes.push(("head.b".into(), vec![n_classes]));
    }
    // decoder ties the output projection to embed.tok — no extra tensors

    let mut specs = Vec::with_capacity(shapes.len());
    let mut off = 0usize;
    for (name, shape) in shapes {
        let n: usize = shape.iter().product();
        specs.push(ParamSpecInfo { name, shape, offset: off });
        off += n;
    }
    specs
}

/// Build a full [`ConfigInfo`] (specs + n_params) from architecture dims.
#[allow(clippy::too_many_arguments)]
pub fn make_config(
    name: &str,
    kind: &str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    max_seq: usize,
    n_classes: usize,
    use_pallas: bool,
) -> ConfigInfo {
    let params = param_specs(kind == "decoder", vocab, d_model, n_layers,
                             d_ff, max_seq, n_classes);
    let n_params = params
        .last()
        .map(|p| p.offset + p.elements())
        .unwrap_or(0);
    ConfigInfo {
        name: name.into(),
        kind: kind.into(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        max_seq,
        n_classes,
        use_pallas,
        n_params,
        params,
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Deterministic init matching the structural rules of
/// `model.init_params` (per-tensor independent SplitMix64 streams).
pub fn init_params(cfg: &ConfigInfo) -> Vec<Vec<f32>> {
    let cfg_salt = fnv1a(&cfg.name);
    cfg.params
        .iter()
        .map(|spec| {
            let n = spec.elements();
            let bias = spec.name.ends_with(".b")
                || spec.name.ends_with(".bq")
                || spec.name.ends_with(".bk")
                || spec.name.ends_with(".bv")
                || spec.name.ends_with(".bo")
                || spec.name.ends_with(".b1")
                || spec.name.ends_with(".b2");
            if bias || spec.name == "head.w" {
                // zero-init biases and the classifier head: training
                // starts at exactly ln(n_classes) for every batch
                return vec![0f32; n];
            }
            if spec.name.ends_with(".g") {
                return vec![1f32; n];
            }
            let scale = if spec.name.starts_with("embed.") {
                0.02
            } else {
                1.0 / (spec.shape[0] as f64).sqrt()
            };
            let mut rng = Rng::new(cfg_salt ^ fnv1a(&spec.name));
            (0..n).map(|_| (rng.gaussian() * scale) as f32).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_contiguous_and_total_matches_device_formula() {
        let cfg = make_config("t", "encoder", 512, 64, 2, 2, 128, 32, 2,
                              true);
        let mut off = 0;
        for p in &cfg.params {
            assert_eq!(p.offset, off, "{}", p.name);
            off += p.elements();
        }
        assert_eq!(off, cfg.n_params);
        // the device model's closed-form count must agree
        assert_eq!(cfg.model_dims().n_params(), cfg.n_params as u64);

        let dec = make_config("d", "decoder", 4096, 256, 6, 8, 1024, 64, 2,
                              false);
        assert_eq!(dec.model_dims().n_params(), dec.n_params as u64);
        // decoder has no head tensors
        assert!(dec.params.iter().all(|p| !p.name.starts_with("head.")));
    }

    #[test]
    fn init_rules() {
        let cfg = make_config("t", "encoder", 64, 8, 1, 2, 16, 8, 2, false);
        let init = init_params(&cfg);
        assert_eq!(init.len(), cfg.params.len());
        for (spec, w) in cfg.params.iter().zip(&init) {
            assert_eq!(w.len(), spec.elements());
            if spec.name.ends_with(".g") {
                assert!(w.iter().all(|&v| v == 1.0), "{}", spec.name);
            }
            if spec.name == "head.w" || spec.name.ends_with(".b1") {
                assert!(w.iter().all(|&v| v == 0.0), "{}", spec.name);
            }
            if spec.name == "embed.tok" {
                let mx = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
                assert!(mx > 0.0 && mx < 0.2, "embed scale {mx}");
            }
        }
        // deterministic across calls
        assert_eq!(init_params(&cfg)[0], init[0]);
    }
}
