//! MeZO driver: host-side orchestration of the fused mezo_step artifact.
//!
//! The remarkable property this module makes concrete: **the optimizer
//! state is two integers.**  `(master_seed, step)` deterministically
//! yields the per-step perturbation seed; the z tensor, the projected
//! gradient, and the update all live transiently inside one HLO program
//! execution.  Checkpointing MeZO therefore costs 16 bytes beyond the
//! parameters, versus 2x parameters for Adam — the paper's Table 1, in
//! struct form.

use anyhow::Result;

use super::schedule::Schedule;
use crate::runtime::literal::{f32_1, u32_1, Literal};
use crate::util::rng::mezo_step_seed;

/// Hyper-parameters of a MeZO run.
#[derive(Debug, Clone)]
pub struct MezoConfig {
    pub lr: Schedule,
    /// SPSA perturbation scale (the paper/MeZO default: 1e-3).
    pub eps: f64,
    /// Master seed for the per-step seed schedule.
    pub master_seed: u64,
}

impl Default for MezoConfig {
    fn default() -> Self {
        MezoConfig {
            lr: Schedule::Constant(1e-3),
            eps: 1e-3,
            master_seed: 0x9E3779B9,
        }
    }
}

/// Live driver; owns nothing but the step counter.
#[derive(Debug, Clone)]
pub struct MezoDriver {
    pub cfg: MezoConfig,
    pub step: u64,
}

impl MezoDriver {
    pub fn new(cfg: MezoConfig) -> Self {
        MezoDriver { cfg, step: 0 }
    }

    /// Seed fed to the artifact at the current step.
    pub fn current_seed(&self) -> u32 {
        mezo_step_seed(self.cfg.master_seed, self.step)
    }

    pub fn current_lr(&self) -> f64 {
        self.cfg.lr.at(self.step)
    }

    /// The three scalar literals appended after (params, ids, mask,
    /// labels) in the mezo_step calling convention: seed, lr, eps.
    pub fn scalar_inputs(&self) -> Result<[Literal; 3]> {
        Ok([
            u32_1(self.current_seed())?,
            f32_1(self.current_lr() as f32)?,
            f32_1(self.cfg.eps as f32)?,
        ])
    }

    pub fn advance(&mut self) {
        self.step += 1;
    }

    /// Resume from a checkpoint: state is literally (master_seed, step).
    pub fn resume(cfg: MezoConfig, step: u64) -> Self {
        MezoDriver { cfg, step }
    }

    /// Bytes of optimizer state this driver adds to a checkpoint:
    /// `(master_seed: u64, step: u64)` — exactly what
    /// `tuner::checkpoint` persists and [`MezoDriver::resume`] consumes.
    pub const STATE_BYTES: u64 = 16;

    /// Extra parameter-sized tensors MeZO carries (none — the point).
    pub const EXTRA_PARAM_SETS: usize = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_sequence_deterministic_and_resumable() {
        let cfg = MezoConfig::default();
        let mut a = MezoDriver::new(cfg.clone());
        let seeds: Vec<u32> = (0..5)
            .map(|_| {
                let s = a.current_seed();
                a.advance();
                s
            })
            .collect();
        // resume at step 3 reproduces the tail of the sequence
        let b = MezoDriver::resume(cfg, 3);
        assert_eq!(b.current_seed(), seeds[3]);
        // all seeds distinct (whp)
        let mut uniq = seeds.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }

    #[test]
    fn lr_schedule_applies() {
        let cfg = MezoConfig {
            lr: Schedule::Linear { start: 1.0, end: 0.0, steps: 10 },
            ..Default::default()
        };
        let mut d = MezoDriver::new(cfg);
        assert_eq!(d.current_lr(), 1.0);
        for _ in 0..5 {
            d.advance();
        }
        assert!((d.current_lr() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn scalar_inputs_shapes() {
        let d = MezoDriver::new(MezoConfig::default());
        let [seed, lr, eps] = d.scalar_inputs().unwrap();
        assert_eq!(seed.element_count(), 1);
        assert_eq!(seed.u32_scalar().unwrap(), d.current_seed());
        assert_eq!(lr.element_count(), 1);
        assert_eq!(eps.element_count(), 1);
    }

    #[test]
    fn zero_extra_state() {
        assert_eq!(MezoDriver::EXTRA_PARAM_SETS, 0);
        // the durable optimizer state is exactly (master_seed, step)
        assert_eq!(
            MezoDriver::STATE_BYTES,
            (std::mem::size_of::<u64>() * 2) as u64
        );
    }
}
