//! Learning-rate schedules.  MeZO typically wants a constant or gently
//! decaying rate (the SPSA estimate is noisy; aggressive decay stalls
//! it); Adam commonly uses linear warmup+decay for fine-tuning.

/// A learning-rate schedule: maps step -> lr.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Constant(f64),
    /// Linear from `start` to `end` over `steps`, then flat at `end`.
    Linear { start: f64, end: f64, steps: u64 },
    /// Linear warmup to `peak` over `warmup` steps, then cosine decay
    /// to `floor` by `total` steps.
    WarmupCosine { peak: f64, floor: f64, warmup: u64, total: u64 },
}

impl Schedule {
    pub fn at(&self, step: u64) -> f64 {
        match *self {
            Schedule::Constant(lr) => lr,
            Schedule::Linear { start, end, steps } => {
                if steps == 0 || step >= steps {
                    end
                } else {
                    start + (end - start) * step as f64 / steps as f64
                }
            }
            Schedule::WarmupCosine { peak, floor, warmup, total } => {
                if warmup > 0 && step < warmup {
                    peak * (step as f64 + 1.0) / warmup as f64
                } else if step >= total {
                    floor
                } else {
                    let span = (total - warmup).max(1) as f64;
                    let p = (step - warmup) as f64 / span;
                    floor
                        + 0.5 * (peak - floor)
                            * (1.0 + (std::f64::consts::PI * p).cos())
                }
            }
        }
    }

    /// Parse "const:1e-3", "linear:1e-3:1e-5:1000",
    /// "cosine:1e-3:1e-6:100:1000".
    pub fn parse(s: &str) -> Option<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["const", lr] => Some(Schedule::Constant(lr.parse().ok()?)),
            ["linear", a, b, n] => Some(Schedule::Linear {
                start: a.parse().ok()?,
                end: b.parse().ok()?,
                steps: n.parse().ok()?,
            }),
            ["cosine", p, f, w, t] => Some(Schedule::WarmupCosine {
                peak: p.parse().ok()?,
                floor: f.parse().ok()?,
                warmup: w.parse().ok()?,
                total: t.parse().ok()?,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        assert_eq!(Schedule::Constant(0.1).at(0), 0.1);
        assert_eq!(Schedule::Constant(0.1).at(10_000), 0.1);
    }

    #[test]
    fn linear_interpolates_and_clamps() {
        let s = Schedule::Linear { start: 1.0, end: 0.0, steps: 10 };
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(5) - 0.5).abs() < 1e-12);
        assert_eq!(s.at(10), 0.0);
        assert_eq!(s.at(99), 0.0);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine {
            peak: 1.0, floor: 0.0, warmup: 10, total: 110,
        };
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(60) < 1.0 && s.at(60) > 0.0);
        assert_eq!(s.at(110), 0.0);
        // monotone decreasing after warmup
        assert!(s.at(20) > s.at(50));
        assert!(s.at(50) > s.at(100));
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Schedule::parse("const:0.5"),
                   Some(Schedule::Constant(0.5)));
        assert_eq!(
            Schedule::parse("linear:1:0:5"),
            Some(Schedule::Linear { start: 1.0, end: 0.0, steps: 5 })
        );
        assert!(Schedule::parse("cosine:1:0:10:100").is_some());
        assert_eq!(Schedule::parse("bogus"), None);
    }
}
