//! Optimizer orchestration — the host-side half of MeZO and Adam.
//!
//! The numerical updates happen inside the AOT step programs; what lives
//! here is everything the paper's system needs *around* them:
//!
//! * [`mezo`] — the seed schedule (the entire "optimizer state" of MeZO
//!   is a `(master_seed, step)` pair!), eps/lr handling, and the
//!   projected-gradient bookkeeping,
//! * [`adam`] — the bias-correction step counter and scalar plumbing
//!   (the m/v moment tensors live in the session's
//!   `runtime::ExecState`, updated in place by the step program),
//! * [`schedule`] — learning-rate schedules shared by both.

pub mod adam;
pub mod mezo;
pub mod schedule;

pub use adam::AdamDriver;
pub use mezo::MezoDriver;
pub use schedule::Schedule;

use crate::device::OptimizerFamily;

/// User-facing optimizer selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptimizerKind {
    MeZo,
    Adam,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s.to_ascii_lowercase().as_str() {
            "mezo" | "zo" | "derivative-free" => Some(OptimizerKind::MeZo),
            "adam" | "derivative-based" => Some(OptimizerKind::Adam),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            OptimizerKind::MeZo => "mezo",
            OptimizerKind::Adam => "adam",
        }
    }

    /// Which artifact kind this optimizer executes.
    pub fn program_kind(&self) -> &'static str {
        match self {
            OptimizerKind::MeZo => "mezo_step",
            OptimizerKind::Adam => "adam_step",
        }
    }

    pub fn family(&self) -> OptimizerFamily {
        match self {
            OptimizerKind::MeZo => OptimizerFamily::DerivativeFree,
            OptimizerKind::Adam => OptimizerFamily::DerivativeBased,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(OptimizerKind::parse("MeZo"), Some(OptimizerKind::MeZo));
        assert_eq!(OptimizerKind::parse("zo"), Some(OptimizerKind::MeZo));
        assert_eq!(OptimizerKind::parse("adam"), Some(OptimizerKind::Adam));
        assert_eq!(OptimizerKind::parse("sgd"), None);
    }

    #[test]
    fn families() {
        assert_eq!(OptimizerKind::MeZo.family(),
                   OptimizerFamily::DerivativeFree);
        assert_eq!(OptimizerKind::Adam.family(),
                   OptimizerFamily::DerivativeBased);
    }
}
