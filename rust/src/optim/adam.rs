//! Adam driver: the derivative-based comparator's host-side state.
//!
//! Carries the two parameter-sized moment tensors (m, v) between steps —
//! exactly the memory the paper's Table 1 charges Adam for.  The
//! adam_step artifact consumes and returns them alongside the params.

use anyhow::Result;

use super::schedule::Schedule;
use crate::runtime::literal::{f32_1, Literal};
use crate::runtime::manifest::ConfigInfo;
use crate::runtime::state::ModelState;

#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: Schedule,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: Schedule::Constant(1e-3) }
    }
}

/// Live Adam driver: step counter + m/v state tensors.
pub struct AdamDriver {
    pub cfg: AdamConfig,
    /// 1-based inside the artifact (bias correction); `step` counts
    /// completed steps.
    pub step: u64,
    pub m: ModelState,
    pub v: ModelState,
}

impl AdamDriver {
    pub fn new(cfg: AdamConfig, model_cfg: &ConfigInfo) -> Result<Self> {
        Ok(AdamDriver {
            cfg,
            step: 0,
            m: ModelState::zeros_like(model_cfg)?,
            v: ModelState::zeros_like(model_cfg)?,
        })
    }

    pub fn current_lr(&self) -> f64 {
        self.cfg.lr.at(self.step)
    }

    /// Scalars appended after (params, m, v, ids, mask, labels): t, lr.
    pub fn scalar_inputs(&self) -> Result<[Literal; 2]> {
        Ok([
            f32_1((self.step + 1) as f32)?, // 1-based t
            f32_1(self.current_lr() as f32)?,
        ])
    }

    /// Consume the artifact's returned m/v tensors.
    pub fn replace_state(
        &mut self,
        m: Vec<Literal>,
        v: Vec<Literal>,
    ) -> Result<()> {
        self.m.replace(m)?;
        self.v.replace(v)?;
        Ok(())
    }

    pub fn advance(&mut self) {
        self.step += 1;
    }

    /// Parameter-sized tensor sets carried beyond the params themselves.
    pub const EXTRA_PARAM_SETS: usize = 2;

    /// Checkpoint cost of the optimizer state in bytes.
    pub fn state_bytes(&self) -> u64 {
        self.m.checkpoint_bytes() + self.v.checkpoint_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 6,
            params: vec![ParamSpecInfo {
                name: "w".into(),
                shape: vec![2, 3],
                offset: 0,
            }],
        }
    }

    #[test]
    fn init_state_is_zero_and_sized() {
        let d = AdamDriver::new(AdamConfig::default(), &tiny_cfg()).unwrap();
        assert_eq!(d.m.l2_norm().unwrap(), 0.0);
        assert_eq!(d.v.l2_norm().unwrap(), 0.0);
        assert_eq!(d.state_bytes(), 2 * 6 * 4);
        assert_eq!(AdamDriver::EXTRA_PARAM_SETS, 2);
    }

    #[test]
    fn t_is_one_based() {
        let mut d = AdamDriver::new(AdamConfig::default(), &tiny_cfg()).unwrap();
        let [t, _lr] = d.scalar_inputs().unwrap();
        assert_eq!(t.f32_scalar().unwrap(), 1.0);
        d.advance();
        let [t, _lr] = d.scalar_inputs().unwrap();
        assert_eq!(t.f32_scalar().unwrap(), 2.0);
    }
}
