//! Adam driver: the derivative-based comparator's host-side clock.
//!
//! The two parameter-sized moment tensors (m, v) — exactly the memory
//! the paper's Table 1 charges Adam for — live in the session's
//! `runtime::ExecState` (created via `ExecState::with_adam`), where the
//! adam_step program mutates them in place alongside the params.  The
//! driver itself carries only the schedule and the step counter, and
//! produces the scalar literals of the adam_step calling convention.

use anyhow::Result;

use super::schedule::Schedule;
use crate::runtime::literal::{f32_1, Literal};

#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: Schedule,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: Schedule::Constant(1e-3) }
    }
}

/// Live Adam driver: schedule + step counter (moments live in the
/// session's ExecState).
#[derive(Debug, Clone)]
pub struct AdamDriver {
    pub cfg: AdamConfig,
    /// 1-based inside the artifact (bias correction); `step` counts
    /// completed steps.
    pub step: u64,
}

impl AdamDriver {
    pub fn new(cfg: AdamConfig) -> Self {
        AdamDriver { cfg, step: 0 }
    }

    pub fn current_lr(&self) -> f64 {
        self.cfg.lr.at(self.step)
    }

    /// Scalars appended after (params, m, v, ids, mask, labels): t, lr.
    pub fn scalar_inputs(&self) -> Result<[Literal; 2]> {
        Ok([
            f32_1((self.step + 1) as f32)?, // 1-based t
            f32_1(self.current_lr() as f32)?,
        ])
    }

    pub fn advance(&mut self) {
        self.step += 1;
    }

    /// Parameter-sized tensor sets carried beyond the params themselves.
    pub const EXTRA_PARAM_SETS: usize = 2;

    /// Checkpoint cost of the optimizer state in bytes for a model of
    /// `n_params` parameters (m + v at f32).
    pub fn state_bytes(n_params: usize) -> u64 {
        (Self::EXTRA_PARAM_SETS * n_params * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_cost_is_two_param_sets() {
        assert_eq!(AdamDriver::EXTRA_PARAM_SETS, 2);
        assert_eq!(AdamDriver::state_bytes(6), 2 * 6 * 4);
    }

    #[test]
    fn t_is_one_based() {
        let mut d = AdamDriver::new(AdamConfig::default());
        let [t, _lr] = d.scalar_inputs().unwrap();
        assert_eq!(t.f32_scalar().unwrap(), 1.0);
        d.advance();
        let [t, _lr] = d.scalar_inputs().unwrap();
        assert_eq!(t.f32_scalar().unwrap(), 2.0);
    }
}
