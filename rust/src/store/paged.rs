//! The paged single-file store engine: crash-safe updates by shadow
//! paging, CRC-protected ledgers, in-place compaction.
//!
//! ## On-disk layout (little-endian throughout)
//!
//! ```text
//!   page 0           two 4 KiB ROOT SLOTS (A at 0, B at 4096); the
//!                    valid slot with the higher epoch is the root
//!   pages 1..n       64 KiB data pages; each blob occupies one
//!                    CONTIGUOUS page segment (last page may be
//!                    partially filled)
//!
//!   root slot:  magic "PLPGROOT", version u32, epoch u64,
//!               n_pages u64, ledger {start u64, pages u32, len u64,
//!               crc u32}, slot crc32
//!   ledger blob: n_entries u32,
//!                entries:  key (u32 len + bytes), start u64,
//!                          pages u32, len u64, blob crc32
//!                n_free u32, free segments: {start u64, pages u64}
//! ```
//!
//! ## Shadow-page commit
//!
//! A `put`/`remove` never overwrites a page the committed root can
//! reach.  It (1) writes the new blob into pages that are FREE under
//! the committed root (extending the file if none fit), (2) writes a
//! new ledger blob — also into committed-free pages — whose free list
//! already accounts for the pages this commit releases, (3) fsyncs,
//! (4) writes the *alternate* root slot with `epoch + 1` and fsyncs
//! again.  A kill at any byte offset therefore leaves a valid root:
//! either the old one (the new slot is torn or stale) or the new one —
//! never a torn image.  `fsck` classifies a torn inactive slot as a
//! warning, not corruption.
//!
//! ## Compaction
//!
//! [`PagedEngine::compact`] repeatedly moves the highest-addressed
//! live blob into the lowest free gap that fits (each move is itself
//! a shadow commit), then commits a shrunken `n_pages` root *before*
//! truncating the file — a kill between the two leaves an oversized
//! file behind a correct root, which the next compaction reclaims.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use super::crc32;
use super::engine::{EngineKind, EngineStats, StoreEngine};

pub const PAGE_SIZE: u64 = 64 * 1024;
pub const ROOT_MAGIC: &[u8; 8] = b"PLPGROOT";
pub const VERSION: u32 = 1;
/// Reserved bytes per root slot (two slots fit well inside page 0).
const SLOT_SIZE: u64 = 4096;
/// Serialized root slot bytes (magic..ledger crc) before the slot crc.
const SLOT_BODY: usize = 8 + 4 + 8 + 8 + 8 + 4 + 8 + 4;

/// A contiguous page segment holding one blob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Segment {
    start: u64,
    pages: u32,
    /// Exact byte length of the blob inside the segment.
    len: u64,
    crc: u32,
}

/// The committed (root-reachable) state of the store.
#[derive(Debug, Clone)]
struct Committed {
    epoch: u64,
    n_pages: u64,
    /// Which slot (0/1) holds the committed root.
    active_slot: u8,
    ledger: Option<Segment>,
    entries: BTreeMap<String, Segment>,
    /// Free segments `(start, pages)`, sorted by start, coalesced.
    free: Vec<(u64, u64)>,
}

struct Inner {
    file: File,
    committed: Committed,
    stats: EngineStats,
}

/// The paged store engine (thread-safe; one lock, I/O inside it).
pub struct PagedEngine {
    path: PathBuf,
    inner: Mutex<Inner>,
}

fn pages_for(bytes: u64) -> u64 {
    // not u64::div_ceil: the workspace MSRV (1.70) predates it
    (bytes / PAGE_SIZE + u64::from(bytes % PAGE_SIZE != 0)).max(1)
}

fn coalesce(mut free: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    free.retain(|&(_, p)| p > 0);
    free.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(free.len());
    for (s, p) in free {
        match out.last_mut() {
            Some((ls, lp)) if *ls + *lp == s => *lp += p,
            _ => out.push((s, p)),
        }
    }
    out
}

/// First-fit allocation from `free` (committed-free pages only),
/// extending the file when no gap fits.
fn alloc(free: &mut Vec<(u64, u64)>, n_pages: &mut u64, want: u64)
    -> u64
{
    if let Some(i) = free.iter().position(|&(_, p)| p >= want) {
        let (s, p) = free[i];
        if p == want {
            free.remove(i);
        } else {
            free[i] = (s + want, p - want);
        }
        return s;
    }
    let s = *n_pages;
    *n_pages += want;
    s
}

fn encode_slot(c: &Committed) -> Vec<u8> {
    let mut out = Vec::with_capacity(SLOT_BODY + 4);
    out.extend_from_slice(ROOT_MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&c.epoch.to_le_bytes());
    out.extend_from_slice(&c.n_pages.to_le_bytes());
    // lint:allow(D004): Committed is only constructed with a ledger
    let l = c.ledger.expect("committed state always has a ledger");
    out.extend_from_slice(&l.start.to_le_bytes());
    out.extend_from_slice(&l.pages.to_le_bytes());
    out.extend_from_slice(&l.len.to_le_bytes());
    out.extend_from_slice(&l.crc.to_le_bytes());
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn decode_slot(buf: &[u8]) -> Option<(u64, u64, Segment)> {
    if buf.len() < SLOT_BODY + 4 || &buf[..8] != ROOT_MAGIC {
        return None;
    }
    let body = &buf[..SLOT_BODY];
    // lint:allow(D004): length checked on entry; 4-byte slice is exact
    let tail: [u8; 4] = buf[SLOT_BODY..SLOT_BODY + 4].try_into().unwrap();
    let stored = u32::from_le_bytes(tail);
    if crc32(body) != stored {
        return None;
    }
    let u32_at = |o: usize| {
        // lint:allow(D004): fixed-width slice of the length-checked buf
        u32::from_le_bytes(buf[o..o + 4].try_into().unwrap())
    };
    let u64_at = |o: usize| {
        // lint:allow(D004): fixed-width slice of the length-checked buf
        u64::from_le_bytes(buf[o..o + 8].try_into().unwrap())
    };
    if u32_at(8) != VERSION {
        return None;
    }
    let epoch = u64_at(12);
    let n_pages = u64_at(20);
    let ledger = Segment {
        start: u64_at(28),
        pages: u32_at(36),
        len: u64_at(40),
        crc: u32_at(48),
    };
    Some((epoch, n_pages, ledger))
}

fn encode_ledger(
    entries: &BTreeMap<String, Segment>,
    free: &[(u64, u64)],
) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (key, seg) in entries {
        out.extend_from_slice(&(key.len() as u32).to_le_bytes());
        out.extend_from_slice(key.as_bytes());
        out.extend_from_slice(&seg.start.to_le_bytes());
        out.extend_from_slice(&seg.pages.to_le_bytes());
        out.extend_from_slice(&seg.len.to_le_bytes());
        out.extend_from_slice(&seg.crc.to_le_bytes());
    }
    out.extend_from_slice(&(free.len() as u32).to_le_bytes());
    for &(s, p) in free {
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

fn decode_ledger(
    bytes: &[u8],
) -> Result<(BTreeMap<String, Segment>, Vec<(u64, u64)>)> {
    let mut pos = 0usize;
    let mut need = |n: usize| -> Result<usize> {
        ensure!(bytes.len() - pos >= n, "ledger blob truncated");
        let at = pos;
        pos += n;
        Ok(at)
    };
    let rd_u32 = |at: usize| {
        // lint:allow(D004): `need` bounds-checked the slice already
        u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
    };
    let rd_u64 = |at: usize| {
        // lint:allow(D004): `need` bounds-checked the slice already
        u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
    };
    let n_entries = rd_u32(need(4)?) as usize;
    ensure!(n_entries <= 1 << 24, "implausible ledger entry count");
    let mut entries = BTreeMap::new();
    for _ in 0..n_entries {
        let klen = rd_u32(need(4)?) as usize;
        ensure!(klen <= 4096, "implausible ledger key length {klen}");
        let kat = need(klen)?;
        let key = String::from_utf8(bytes[kat..kat + klen].to_vec())
            .map_err(|_| anyhow::anyhow!("non-UTF-8 ledger key"))?;
        let seg = Segment {
            start: rd_u64(need(8)?),
            pages: rd_u32(need(4)?),
            len: rd_u64(need(8)?),
            crc: rd_u32(need(4)?),
        };
        entries.insert(key, seg);
    }
    let n_free = rd_u32(need(4)?) as usize;
    ensure!(n_free <= 1 << 24, "implausible free-segment count");
    let mut free = Vec::with_capacity(n_free);
    for _ in 0..n_free {
        let s = rd_u64(need(8)?);
        let p = rd_u64(need(8)?);
        free.push((s, p));
    }
    ensure!(pos == bytes.len(), "ledger blob has trailing bytes");
    Ok((entries, free))
}

impl PagedEngine {
    /// Open (creating and initializing if absent) a paged store file.
    pub fn open(path: impl AsRef<Path>) -> Result<PagedEngine> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| {
                format!("opening paged store {}", path.display())
            })?;
        let len = file.metadata()?.len();
        let committed = if len == 0 {
            // bootstrap: an empty ledger at page 1 under epoch 1
            let mut c = Committed {
                epoch: 0,
                n_pages: 1,
                active_slot: 1, // so the first commit targets slot 0
                ledger: None,
                entries: BTreeMap::new(),
                free: Vec::new(),
            };
            let ledger = encode_ledger(&c.entries, &c.free);
            let lseg = Segment {
                start: 1,
                pages: pages_for(ledger.len() as u64) as u32,
                len: ledger.len() as u64,
                crc: crc32(&ledger),
            };
            c.n_pages = 1 + lseg.pages as u64;
            file.seek(SeekFrom::Start(lseg.start * PAGE_SIZE))?;
            file.write_all(&ledger)?;
            file.set_len(c.n_pages * PAGE_SIZE)?;
            file.sync_all()?;
            c.epoch = 1;
            c.active_slot = 0;
            c.ledger = Some(lseg);
            file.seek(SeekFrom::Start(0))?;
            file.write_all(&encode_slot(&c))?;
            file.sync_all()?;
            c
        } else {
            Self::read_committed(&mut file)
                .with_context(|| {
                    format!("recovering paged store {}", path.display())
                })?
                .0
        };
        Ok(PagedEngine {
            path,
            inner: Mutex::new(Inner {
                file,
                committed,
                stats: EngineStats::default(),
            }),
        })
    }

    /// Parse both root slots and the winning ledger.  Also returns
    /// per-slot validity for fsck (`None` = unreadable/torn).
    fn read_committed(
        file: &mut File,
    ) -> Result<(Committed, [Option<u64>; 2])> {
        let mut head = vec![0u8; (2 * SLOT_SIZE) as usize];
        file.seek(SeekFrom::Start(0))?;
        let got = read_up_to(file, &mut head)?;
        head.truncate(got);
        let slot_at = |i: usize| -> Option<(u64, u64, Segment)> {
            let off = i * SLOT_SIZE as usize;
            if head.len() < off + SLOT_BODY + 4 {
                return None;
            }
            decode_slot(&head[off..off + SLOT_BODY + 4])
        };
        let slots = [slot_at(0), slot_at(1)];
        let epochs = [
            slots[0].map(|(e, ..)| e),
            slots[1].map(|(e, ..)| e),
        ];
        let winner = match (slots[0], slots[1]) {
            (Some(a), Some(b)) => {
                if a.0 >= b.0 {
                    (0u8, a)
                } else {
                    (1, b)
                }
            }
            (Some(a), None) => (0, a),
            (None, Some(b)) => (1, b),
            (None, None) => bail!(
                "no valid root slot — not a paged store, or corrupt \
                 beyond recovery"
            ),
        };
        let (active_slot, (epoch, n_pages, lseg)) = winner;
        let mut ledger_bytes = vec![0u8; lseg.len as usize];
        file.seek(SeekFrom::Start(lseg.start * PAGE_SIZE))?;
        file.read_exact(&mut ledger_bytes)
            .context("reading ledger pages")?;
        ensure!(crc32(&ledger_bytes) == lseg.crc,
                "ledger CRC mismatch (root epoch {epoch})");
        let (entries, free) = decode_ledger(&ledger_bytes)?;
        Ok((
            Committed {
                epoch,
                n_pages,
                active_slot,
                ledger: Some(lseg),
                entries,
                free: coalesce(free),
            },
            epochs,
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// One shadow-paged commit: optionally replace/insert `key` with
    /// `data` (`None` data = remove), never touching committed pages.
    /// With `truncate`, the new root's page count is cut to the last
    /// live page and the free list clipped to fit (the caller then
    /// shortens the file — AFTER the root flip, so a kill in between
    /// leaves an oversized file behind a correct root).
    fn commit(
        inner: &mut Inner,
        key: Option<&str>,
        data: Option<&[u8]>,
        truncate: bool,
    ) -> Result<()> {
        let epoch = inner.committed.epoch;
        let active_slot = inner.committed.active_slot;
        let mut n_pages = inner.committed.n_pages;
        let mut free = inner.committed.free.clone();
        let mut entries = inner.committed.entries.clone();
        // pages this commit releases: live under the OLD root, so
        // they are listed free in the new ledger but never allocated
        // from within this transaction
        let mut newly_freed: Vec<(u64, u64)> = Vec::new();
        if let Some(l) = inner.committed.ledger {
            newly_freed.push((l.start, l.pages as u64));
        }
        if let Some(key) = key {
            if let Some(old) = entries.remove(key) {
                newly_freed.push((old.start, old.pages as u64));
            }
            if let Some(data) = data {
                let want = pages_for(data.len() as u64);
                let start = alloc(&mut free, &mut n_pages, want);
                inner
                    .file
                    .seek(SeekFrom::Start(start * PAGE_SIZE))?;
                inner.file.write_all(data)?;
                entries.insert(
                    key.to_string(),
                    Segment {
                        start,
                        pages: want as u32,
                        len: data.len() as u64,
                        crc: crc32(data),
                    },
                );
            }
        }
        // the ledger's size depends on the final free-segment count;
        // allocate from an upper bound (allocation never grows the
        // count, merging never grows it either), then serialize the
        // exact free list — slack pages stay inside the ledger
        // segment and are reclaimed next commit
        let bound_free = free.len() + newly_freed.len();
        let bound_bytes = 4
            + entries
                .iter()
                .map(|(k, _)| 4 + k.len() + 24)
                .sum::<usize>()
            + 4
            + bound_free * 16;
        let lpages = pages_for(bound_bytes as u64);
        let lstart = alloc(&mut free, &mut n_pages, lpages);
        for seg in newly_freed {
            free.push(seg);
        }
        let mut final_free = coalesce(free);
        if truncate {
            // cut at the last live page (entries + the new ledger —
            // allocated above, so lstart + lpages is already known)
            let cut = entries
                .values()
                .map(|s| s.start + s.pages as u64)
                .chain(std::iter::once(lstart + lpages))
                .max()
                .unwrap_or(1)
                .max(1);
            let mut clipped = Vec::with_capacity(final_free.len());
            for (s, p) in final_free {
                if s < cut {
                    clipped.push((s, p.min(cut - s)));
                }
            }
            final_free = clipped;
            n_pages = cut;
        }
        let ledger = encode_ledger(&entries, &final_free);
        ensure!(ledger.len() <= (lpages * PAGE_SIZE) as usize,
                "ledger outgrew its allocation");
        let lseg = Segment {
            start: lstart,
            pages: lpages as u32,
            len: ledger.len() as u64,
            crc: crc32(&ledger),
        };
        inner.file.seek(SeekFrom::Start(lstart * PAGE_SIZE))?;
        inner.file.write_all(&ledger)?;
        if n_pages * PAGE_SIZE > inner.file.metadata()?.len() {
            inner.file.set_len(n_pages * PAGE_SIZE)?;
        }
        // barrier 1: data + ledger durable before the root flips
        inner.file.sync_all()?;
        let next = Committed {
            epoch: epoch + 1,
            n_pages,
            active_slot: 1 - active_slot,
            ledger: Some(lseg),
            entries,
            free: final_free,
        };
        inner.file.seek(SeekFrom::Start(
            next.active_slot as u64 * SLOT_SIZE,
        ))?;
        inner.file.write_all(&encode_slot(&next))?;
        // barrier 2: the root flip itself
        inner.file.sync_all()?;
        inner.committed = next;
        Ok(())
    }

    /// Compact in place: slide the highest-addressed blobs into the
    /// lowest free gaps (each move a shadow commit), then truncate
    /// the reclaimed tail.  Returns `(moved_blobs, bytes_reclaimed)`.
    pub fn compact(&self) -> Result<(usize, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.file.metadata()?.len();
        let mut moved = 0usize;
        loop {
            let c = &inner.committed;
            // highest-addressed live blob, and the lowest free gap
            // that fits it strictly below its current position
            let Some((key, seg)) = c
                .entries
                .iter()
                .max_by_key(|(_, s)| s.start)
                .map(|(k, s)| (k.clone(), *s))
            else {
                break;
            };
            let fits = c
                .free
                .iter()
                .find(|&&(s, p)| p >= seg.pages as u64 && s < seg.start)
                .copied();
            if fits.is_none() {
                break;
            }
            let mut data = vec![0u8; seg.len as usize];
            inner
                .file
                .seek(SeekFrom::Start(seg.start * PAGE_SIZE))?;
            inner.file.read_exact(&mut data)?;
            ensure!(crc32(&data) == seg.crc,
                    "blob {key:?} CRC mismatch during compaction");
            Self::commit(&mut inner, Some(&key), Some(&data), false)?;
            moved += 1;
        }
        // drop the free tail: a truncating commit relocates the
        // ledger below the cut and flips the root FIRST; only then is
        // the file shortened
        Self::commit(&mut inner, None, None, true)?;
        let expect = inner.committed.n_pages * PAGE_SIZE;
        if inner.file.metadata()?.len() > expect {
            inner.file.set_len(expect)?;
            inner.file.sync_all()?;
        }
        let after = inner.file.metadata()?.len();
        Ok((moved, before.saturating_sub(after)))
    }

    /// Offline consistency walk: roots, ledger, per-blob CRCs, page
    /// accounting.  Read-only; works on a store another process wrote.
    pub fn fsck(path: impl AsRef<Path>) -> Result<FsckReport> {
        let path = path.as_ref();
        let mut file = File::open(path).with_context(|| {
            format!("opening paged store {}", path.display())
        })?;
        let (c, epochs) = Self::read_committed(&mut file)?;
        let mut report = FsckReport {
            path: path.to_path_buf(),
            epoch: c.epoch,
            n_pages: c.n_pages,
            entries: c.entries.len(),
            images: 0,
            raw_blobs: 0,
            free_pages: c.free.iter().map(|&(_, p)| p).sum(),
            orphaned_pages: 0,
            warnings: Vec::new(),
            errors: Vec::new(),
        };
        let inactive = 1 - c.active_slot as usize;
        if epochs[inactive].is_none() {
            // routinely nonzero-but-torn after an interrupted commit;
            // all-zero only on a store that committed exactly once
            report.warnings.push(format!(
                "root slot {inactive} is torn or unwritten (expected \
                 after an interrupted commit; superseded by epoch {})",
                c.epoch
            ));
        }
        // page accounting: 0 = unclaimed, 1 = live (root/ledger/
        // blob), 2 = free-listed; page 0 is always the root page
        let mut marks = vec![0u8; c.n_pages as usize];
        if !marks.is_empty() {
            marks[0] = 1;
        }
        if let Some(l) = c.ledger {
            mark_pages(&mut marks, l.start, l.pages as u64, "ledger",
                       1, &mut report.errors);
        }
        for (key, seg) in &c.entries {
            mark_pages(&mut marks, seg.start, seg.pages as u64,
                       &format!("blob {key:?}"), 1,
                       &mut report.errors);
            let mut data = vec![0u8; seg.len as usize];
            let read = file
                .seek(SeekFrom::Start(seg.start * PAGE_SIZE))
                .and_then(|_| file.read_exact(&mut data));
            if let Err(e) = read {
                report.errors.push(format!(
                    "blob {key:?}: unreadable ({e})"
                ));
                continue;
            }
            if crc32(&data) != seg.crc {
                report.errors.push(format!(
                    "blob {key:?}: CRC mismatch (torn page?)"
                ));
                continue;
            }
            // the per-image walk: anything that looks like a session
            // image must fully decode, not just checksum
            if data.starts_with(super::image::MAGIC) {
                match super::image::SessionImage::decode(&data) {
                    Ok(_) => report.images += 1,
                    Err(e) => report.errors.push(format!(
                        "blob {key:?}: session image invalid ({e:#})"
                    )),
                }
            } else {
                report.raw_blobs += 1;
            }
        }
        for &(s, p) in &c.free {
            mark_pages(&mut marks, s, p, "free list", 2,
                       &mut report.errors);
        }
        report.orphaned_pages =
            marks.iter().filter(|&&m| m == 0).count() as u64;
        if report.orphaned_pages > 0 {
            report.warnings.push(format!(
                "{} orphaned page(s) reachable from no ledger \
                 (reclaim with `store compact`)",
                report.orphaned_pages
            ));
        }
        let disk = file.metadata()?.len();
        let expect = c.n_pages * PAGE_SIZE;
        if disk > expect {
            report.warnings.push(format!(
                "{} bytes past the committed root (interrupted \
                 commit; harmless, truncated by the next compaction)",
                disk - expect
            ));
        } else if disk < expect {
            report.errors.push(format!(
                "file truncated: {disk} bytes on disk, root expects \
                 {expect}"
            ));
        }
        Ok(report)
    }
}

fn mark_pages(
    marks: &mut [u8],
    start: u64,
    pages: u64,
    what: &str,
    mark: u8,
    errors: &mut Vec<String>,
) {
    for p in start..start + pages {
        match marks.get_mut(p as usize) {
            Some(slot) if *slot == 0 => *slot = mark,
            Some(_) => errors.push(format!(
                "page {p} claimed twice (by {what})"
            )),
            None => errors.push(format!(
                "{what} points past the file (page {p} of {})",
                marks.len()
            )),
        }
    }
}

fn read_up_to(file: &mut File, buf: &mut [u8]) -> Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        let n = file.read(&mut buf[got..])?;
        if n == 0 {
            break;
        }
        got += n;
    }
    Ok(got)
}

/// What [`PagedEngine::fsck`] found.  `errors` empty = clean (torn
/// inactive slots and reclaimable tails are warnings by design).
#[derive(Debug)]
pub struct FsckReport {
    pub path: PathBuf,
    pub epoch: u64,
    pub n_pages: u64,
    pub entries: usize,
    /// Blobs that decoded as valid session images.
    pub images: usize,
    /// CRC-valid blobs that are not session images (e.g. the fleet
    /// manifest).
    pub raw_blobs: usize,
    pub free_pages: u64,
    pub orphaned_pages: u64,
    pub warnings: Vec<String>,
    pub errors: Vec<String>,
}

impl FsckReport {
    pub fn is_clean(&self) -> bool {
        self.errors.is_empty()
    }
}

impl std::fmt::Display for FsckReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "fsck: {}", self.path.display())?;
        writeln!(
            f,
            "root: epoch {}, {} pages ({} bytes)",
            self.epoch,
            self.n_pages,
            self.n_pages * PAGE_SIZE
        )?;
        writeln!(
            f,
            "entries: {} ({} session images, {} raw blobs)",
            self.entries, self.images, self.raw_blobs
        )?;
        writeln!(
            f,
            "free pages: {}  orphaned pages: {}",
            self.free_pages, self.orphaned_pages
        )?;
        for w in &self.warnings {
            writeln!(f, "warning: {w}")?;
        }
        for e in &self.errors {
            writeln!(f, "error: {e}")?;
        }
        if self.is_clean() {
            write!(f, "status: clean")
        } else {
            write!(f, "status: CORRUPT ({} error(s))", self.errors.len())
        }
    }
}

impl StoreEngine for PagedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Paged
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        Self::commit(&mut inner, Some(key), Some(bytes), None)
            .with_context(|| format!("paged put of {key:?}"))?;
        inner.stats.puts += 1;
        inner.stats.bytes_written += bytes.len() as u64;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let Some(seg) = inner.committed.entries.get(key).copied()
        else {
            bail!("no store entry under {key:?}");
        };
        let mut data = vec![0u8; seg.len as usize];
        inner.file.seek(SeekFrom::Start(seg.start * PAGE_SIZE))?;
        inner
            .file
            .read_exact(&mut data)
            .with_context(|| format!("reading blob {key:?}"))?;
        ensure!(crc32(&data) == seg.crc,
                "blob {key:?} corrupt: stored CRC {:#010x}, computed \
                 {:#010x}",
                seg.crc, crc32(&data));
        inner.stats.gets += 1;
        Ok(data)
    }

    fn remove(&self, key: &str) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.committed.entries.contains_key(key) {
            return Ok(false);
        }
        Self::commit(&mut inner, Some(key), None, None)
            .with_context(|| format!("paged remove of {key:?}"))?;
        inner.stats.removes += 1;
        Ok(true)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .committed
            .entries
            .contains_key(key)
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().committed.entries.len()
    }

    fn iter_keys(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .committed
            .entries
            .keys()
            .cloned()
            .collect()
    }

    fn stats(&self) -> EngineStats {
        self.inner.lock().unwrap().stats
    }

    fn disk_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.file.metadata().map(|m| m.len()).unwrap_or(0)
    }

    fn file_count(&self) -> u64 {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pocketllm_paged_{name}.plpg"));
        let _ = std::fs::remove_file(&d);
        d
    }

    fn blob(tag: u8, len: usize) -> Vec<u8> {
        (0..len).map(|i| tag ^ (i as u8)).collect()
    }

    #[test]
    fn roundtrip_replace_remove_and_reopen() {
        let path = tmp("rt");
        {
            let e = PagedEngine::open(&path).unwrap();
            e.put("a", &blob(1, 100)).unwrap();
            e.put("b", &blob(2, 3 * PAGE_SIZE as usize + 7)).unwrap();
            assert_eq!(e.get("a").unwrap(), blob(1, 100));
            assert_eq!(e.get("b").unwrap(),
                       blob(2, 3 * PAGE_SIZE as usize + 7));
            e.put("a", &blob(9, 50)).unwrap();
            assert_eq!(e.get("a").unwrap(), blob(9, 50));
            assert!(e.remove("b").unwrap());
            assert!(!e.remove("b").unwrap());
            assert_eq!(e.iter_keys(), vec!["a"]);
        }
        // a fresh open (new process) reads the committed root
        let e = PagedEngine::open(&path).unwrap();
        assert_eq!(e.len(), 1);
        assert_eq!(e.get("a").unwrap(), blob(9, 50));
        assert!(PagedEngine::fsck(&path).unwrap().is_clean());
    }

    #[test]
    fn freed_pages_are_reused_not_leaked() {
        let path = tmp("reuse");
        let e = PagedEngine::open(&path).unwrap();
        let big = blob(3, 2 * PAGE_SIZE as usize);
        for _ in 0..20 {
            e.put("k", &big).unwrap();
        }
        // 20 rewrites of a 2-page blob must not grow the file 20x:
        // shadow commits ping-pong between freed segments
        let pages = e.disk_bytes() / PAGE_SIZE;
        assert!(pages < 12,
                "file grew to {pages} pages after 20 rewrites");
        assert!(PagedEngine::fsck(&path).unwrap().is_clean());
    }

    #[test]
    fn torn_root_slot_falls_back_to_the_valid_root() {
        let path = tmp("torn");
        {
            let e = PagedEngine::open(&path).unwrap();
            e.put("x", &blob(7, 500)).unwrap();
            e.put("x", &blob(8, 500)).unwrap(); // both slots now used
        }
        // simulate a kill mid-root-write: garble the ACTIVE slot's
        // crc region byte-by-byte; the store must fall back to the
        // previous epoch's root and still serve a consistent image
        let mut bytes = std::fs::read(&path).unwrap();
        let (committed, _) = {
            let mut f = File::open(&path).unwrap();
            PagedEngine::read_committed(&mut f).unwrap()
        };
        let off = committed.active_slot as usize * SLOT_SIZE as usize;
        for i in 0..SLOT_BODY + 4 {
            bytes[off + i] ^= 0xA5;
        }
        std::fs::write(&path, &bytes).unwrap();
        let e = PagedEngine::open(&path).unwrap();
        // previous root: the first put of "x"
        assert_eq!(e.get("x").unwrap(), blob(7, 500));
        let report = PagedEngine::fsck(&path).unwrap();
        assert!(report.is_clean(),
                "torn slot must be a warning, not corruption:\n\
                 {report}");
        assert!(!report.warnings.is_empty());
        assert!(format!("{report}").contains("status: clean"));
    }

    #[test]
    fn simulated_torn_data_write_leaves_a_clean_store() {
        // a crash mid-`put` = new pages written but the root never
        // flipped: emulate by appending garbage past the committed
        // tail; the store must read the old image and fsck clean
        let path = tmp("tornwrite");
        {
            let e = PagedEngine::open(&path).unwrap();
            e.put("img", &blob(4, PAGE_SIZE as usize + 3)).unwrap();
        }
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .unwrap();
        f.write_all(&blob(0xFF, 1000)).unwrap();
        drop(f);
        let e = PagedEngine::open(&path).unwrap();
        assert_eq!(e.get("img").unwrap(),
                   blob(4, PAGE_SIZE as usize + 3));
        let report = PagedEngine::fsck(&path).unwrap();
        assert!(report.is_clean(), "{report}");
        assert!(report
                    .warnings
                    .iter()
                    .any(|w| w.contains("past the committed root")),
                "{report}");
    }

    #[test]
    fn bit_flips_in_blob_pages_are_detected() {
        let path = tmp("bitflip");
        let e = PagedEngine::open(&path).unwrap();
        e.put("v", &blob(5, 4000)).unwrap();
        // find the blob's pages via the committed state and flip one
        // byte on disk behind the engine's back
        let seg = *e
            .inner
            .lock()
            .unwrap()
            .committed
            .entries
            .get("v")
            .unwrap();
        drop(e);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[(seg.start * PAGE_SIZE) as usize + 123] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let e = PagedEngine::open(&path).unwrap();
        let err = e.get("v").unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
        let report = PagedEngine::fsck(&path).unwrap();
        assert!(!report.is_clean());
        assert!(format!("{report}").contains("CRC mismatch"));
    }

    #[test]
    fn compaction_reclaims_holes_and_truncates() {
        let path = tmp("compact");
        let e = PagedEngine::open(&path).unwrap();
        for i in 0..8u8 {
            e.put(&format!("k{i}"),
                  &blob(i, 2 * PAGE_SIZE as usize))
                .unwrap();
        }
        for i in 0..7u8 {
            // free everything but the LAST blob: a big hole below it
            e.remove(&format!("k{i}")).unwrap();
        }
        let before = e.disk_bytes();
        let (moved, reclaimed) = e.compact().unwrap();
        assert!(moved >= 1, "the surviving blob must slide down");
        assert!(reclaimed > 0);
        let after = e.disk_bytes();
        assert!(after < before,
                "compaction must shrink the file ({before} -> \
                 {after})");
        assert_eq!(e.get("k7").unwrap(),
                   blob(7, 2 * PAGE_SIZE as usize));
        // survives reopen and fscks clean
        drop(e);
        let e = PagedEngine::open(&path).unwrap();
        assert_eq!(e.get("k7").unwrap(),
                   blob(7, 2 * PAGE_SIZE as usize));
        let report = PagedEngine::fsck(&path).unwrap();
        assert!(report.is_clean(), "{report}");
        assert_eq!(report.orphaned_pages, 0, "{report}");
    }

    #[test]
    fn empty_and_tiny_blobs_are_fine() {
        let path = tmp("tiny");
        let e = PagedEngine::open(&path).unwrap();
        e.put("empty", b"").unwrap();
        e.put("one", b"x").unwrap();
        assert_eq!(e.get("empty").unwrap(), b"");
        assert_eq!(e.get("one").unwrap(), b"x");
        assert_eq!(e.take("one").unwrap(), b"x");
        assert!(!e.contains("one"));
        assert!(PagedEngine::fsck(&path).unwrap().is_clean());
    }

    #[test]
    fn not_a_paged_store_is_a_loud_error() {
        let path = tmp("garbage");
        std::fs::write(&path, vec![0x42u8; 9000]).unwrap();
        let err = PagedEngine::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("no valid root"),
                "{err:#}");
        assert!(PagedEngine::fsck(&path).is_err());
    }
}
