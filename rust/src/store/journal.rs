//! Durable per-job journal of event / metric / span deltas.
//!
//! PR 6's recovery kept the *outcome* half of the determinism
//! contract across a crash but let the pre-crash event and metric
//! streams die with the process.  This module retires that gap: after
//! every simulated window a job appends one CRC'd journal record —
//! the window's event/metric/span delta — to the fleet's
//! [`SessionStore`], through `put_raw`, so the record rides the same
//! engine (dir or paged) and the same shadow-commit discipline as
//! session images, and `store fsck` validates its CRC like any other
//! blob.  `fleet --recover` replays the journal to rebuild each job's
//! full pre-crash streams bit-identically.
//!
//! ## Record format (version 1, little-endian throughout)
//!
//! ```text
//!   magic     4 B   b"PLJL"
//!   version   u32   1
//!   job       u32   job index
//!   window    u64   the job's window_idx AFTER this delta — the
//!                   replay truncation point (a record "ahead of" the
//!                   session image's recovery window is skipped)
//!   n_events  u32   then per event: tag u8 + fields (see encode)
//!   n_series  u32   then per series: name (u32 len + UTF-8),
//!                   n_points u64, then (step u64, value f64-bits)*
//!   n_spans   u32   then per span: job u32, window u32, kind u8,
//!                   label str, detail str, t u64, dur u64, bytes
//!                   u64, uwh u64, flops u64 — the wall-clock
//!                   `host_us` sidecar is deliberately NOT journaled
//!   crc32     u32   CRC-32/IEEE over every preceding byte
//! ```
//!
//! ## Keys and idempotence
//!
//! Record `seq` of job `j` lives under key `jrn{j}-{seq:08}`: the
//! zero-padding makes the store's sorted `iter_keys` enumeration
//! numeric, and the `-` terminator keeps job 1's prefix from matching
//! job 10's.  `seq` is a monotone per-job counter; recovery restores
//! it as the count of replayed records, so a window re-run after a
//! journal-ahead-of-image crash overwrites its own record — with
//! identical bytes, by the determinism contract — instead of
//! duplicating it.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::Event;
use crate::scheduler::policy::DenyReason;
use crate::telemetry::metrics::MetricLog;
use crate::telemetry::trace::{Span, SpanKind};

use super::image::Reader;
use super::{crc32, SessionStore};

const MAGIC: &[u8; 4] = b"PLJL";
const VERSION: u32 = 1;

/// One window's worth of a job's observability output.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JournalRecord {
    pub job: u32,
    /// The job's `window_idx` after this delta (replay truncation
    /// point).
    pub window: u64,
    pub events: Vec<Event>,
    pub metrics: MetricLog,
    pub spans: Vec<Span>,
}

impl JournalRecord {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
            && self.metrics.series.is_empty()
            && self.spans.is_empty()
    }
}

/// A job's replayed pre-crash streams.
#[derive(Debug, Clone, Default)]
pub struct Replay {
    pub events: Vec<Event>,
    pub metrics: MetricLog,
    pub spans: Vec<Span>,
    /// Records consumed — the restored per-job journal sequence
    /// counter.
    pub records: u64,
}

/// The store key of job `job`'s journal record `seq`.
pub fn journal_key(job: u32, seq: u64) -> String {
    format!("jrn{job}-{seq:08}")
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    ensure!(s.len() <= 4096, "implausible journal string: {} bytes",
            s.len());
    push_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn push_event(out: &mut Vec<u8>, e: &Event) -> Result<()> {
    match e {
        Event::Admitted { job, window } => {
            out.push(0);
            push_u64(out, *job as u64);
            push_u64(out, *window as u64);
        }
        Event::Denied { job, reason } => {
            out.push(1);
            push_u64(out, *job as u64);
            push_str(out, reason)?;
        }
        Event::StepsDone { job, steps, loss } => {
            out.push(2);
            push_u64(out, *job as u64);
            push_u64(out, *steps);
            push_u64(out, loss.to_bits());
        }
        Event::SplitDone { job, steps, loss, bytes } => {
            out.push(3);
            push_u64(out, *job as u64);
            push_u64(out, *steps);
            push_u64(out, loss.to_bits());
            push_u64(out, *bytes);
        }
        Event::Deferred { job, window } => {
            out.push(4);
            push_u64(out, *job as u64);
            push_u64(out, *window as u64);
        }
        Event::LinkDropped { job, window } => {
            out.push(5);
            push_u64(out, *job as u64);
            push_u64(out, *window as u64);
        }
        Event::OomFallback { job, from, to } => {
            out.push(6);
            push_u64(out, *job as u64);
            push_str(out, from)?;
            push_str(out, to)?;
        }
        Event::Completed { job, final_loss } => {
            out.push(7);
            push_u64(out, *job as u64);
            push_u64(out, final_loss.to_bits());
        }
        Event::Failed { job, error } => {
            out.push(8);
            push_u64(out, *job as u64);
            push_str(out, error)?;
        }
        Event::Recovered { job, window } => {
            out.push(9);
            push_u64(out, *job as u64);
            push_u64(out, *window as u64);
        }
    }
    Ok(())
}

/// Map a journaled deny-reason label back to the `&'static str` the
/// live coordinator would have produced, so replayed `Denied` events
/// compare equal to live ones.
fn static_deny_label(label: &str) -> Result<&'static str> {
    for r in DenyReason::ALL {
        if r.label() == label {
            return Ok(r.label());
        }
    }
    bail!("journal: unknown deny reason {label:?}")
}

/// Same idea for the OOM-fallback optimizer labels.
fn static_optimizer_label(label: &str) -> Result<&'static str> {
    match label {
        "adam" => Ok("adam"),
        "mezo" => Ok("mezo"),
        _ => bail!("journal: unknown optimizer label {label:?}"),
    }
}

fn read_event(r: &mut Reader) -> Result<Event> {
    let tag = r.u8()?;
    Ok(match tag {
        0 => Event::Admitted {
            job: r.u64()? as usize,
            window: r.u64()? as usize,
        },
        1 => Event::Denied {
            job: r.u64()? as usize,
            reason: static_deny_label(&r.string()?)?,
        },
        2 => Event::StepsDone {
            job: r.u64()? as usize,
            steps: r.u64()?,
            loss: f64::from_bits(r.u64()?),
        },
        3 => Event::SplitDone {
            job: r.u64()? as usize,
            steps: r.u64()?,
            loss: f64::from_bits(r.u64()?),
            bytes: r.u64()?,
        },
        4 => Event::Deferred {
            job: r.u64()? as usize,
            window: r.u64()? as usize,
        },
        5 => Event::LinkDropped {
            job: r.u64()? as usize,
            window: r.u64()? as usize,
        },
        6 => Event::OomFallback {
            job: r.u64()? as usize,
            from: static_optimizer_label(&r.string()?)?,
            to: static_optimizer_label(&r.string()?)?,
        },
        7 => Event::Completed {
            job: r.u64()? as usize,
            final_loss: f64::from_bits(r.u64()?),
        },
        8 => Event::Failed {
            job: r.u64()? as usize,
            error: r.string()?,
        },
        9 => Event::Recovered {
            job: r.u64()? as usize,
            window: r.u64()? as usize,
        },
        _ => bail!("journal: unknown event tag {tag}"),
    })
}

fn push_span(out: &mut Vec<u8>, s: &Span) -> Result<()> {
    push_u32(out, s.job);
    push_u32(out, s.window);
    out.push(s.kind.code());
    push_str(out, &s.label)?;
    push_str(out, &s.detail)?;
    push_u64(out, s.t_us);
    push_u64(out, s.dur_us);
    push_u64(out, s.bytes);
    push_u64(out, s.uwh);
    push_u64(out, s.flops);
    Ok(())
}

fn read_span(r: &mut Reader) -> Result<Span> {
    Ok(Span {
        job: r.u32()?,
        window: r.u32()?,
        kind: {
            let c = r.u8()?;
            SpanKind::from_code(c)
                .ok_or_else(|| {
                    anyhow::anyhow!("journal: unknown span kind {c}")
                })?
        },
        label: r.string()?,
        detail: r.string()?,
        t_us: r.u64()?,
        dur_us: r.u64()?,
        bytes: r.u64()?,
        uwh: r.u64()?,
        flops: r.u64()?,
        // wall clock is never journaled — a replayed trace is pure
        // deterministic content
        host_us: None,
    })
}

/// Serialize a record (magic/version header + CRC trailer included).
pub fn encode_record(rec: &JournalRecord) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(256);
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, VERSION);
    push_u32(&mut out, rec.job);
    push_u64(&mut out, rec.window);
    push_u32(&mut out, rec.events.len() as u32);
    for e in &rec.events {
        push_event(&mut out, e)?;
    }
    push_u32(&mut out, rec.metrics.series.len() as u32);
    for (name, s) in &rec.metrics.series {
        push_str(&mut out, name)?;
        push_u64(&mut out, s.points.len() as u64);
        for &(step, v) in &s.points {
            push_u64(&mut out, step);
            push_u64(&mut out, v.to_bits());
        }
    }
    push_u32(&mut out, rec.spans.len() as u32);
    for s in &rec.spans {
        push_span(&mut out, s)?;
    }
    let crc = crc32(&out);
    push_u32(&mut out, crc);
    Ok(out)
}

/// Parse and CRC-verify one record.
pub fn decode_record(bytes: &[u8]) -> Result<JournalRecord> {
    ensure!(bytes.len() >= MAGIC.len() + 8 + 4,
            "journal record truncated: {} bytes", bytes.len());
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([
        trailer[0], trailer[1], trailer[2], trailer[3],
    ]);
    let actual = crc32(body);
    ensure!(stored == actual,
            "journal record CRC mismatch: stored {stored:#010x}, \
             computed {actual:#010x}");
    let mut r = Reader { buf: body, pos: 0 };
    ensure!(r.bytes(4)? == MAGIC, "not a journal record (bad magic)");
    let version = r.u32()?;
    ensure!(version == VERSION,
            "journal record version {version} unsupported");
    let job = r.u32()?;
    let window = r.u64()?;
    let n_events = r.u32()?;
    let mut events = Vec::with_capacity(n_events as usize);
    for _ in 0..n_events {
        events.push(read_event(&mut r)?);
    }
    let n_series = r.u32()?;
    let mut metrics = MetricLog::new();
    for _ in 0..n_series {
        let name = r.string()?;
        let n_points = r.u64()?;
        let series = metrics.series.entry(name).or_default();
        for _ in 0..n_points {
            let step = r.u64()?;
            let v = f64::from_bits(r.u64()?);
            series.push(step, v);
        }
    }
    let n_spans = r.u32()?;
    let mut spans = Vec::with_capacity(n_spans as usize);
    for _ in 0..n_spans {
        spans.push(read_span(&mut r)?);
    }
    ensure!(r.pos == body.len(),
            "journal record has {} trailing bytes", body.len() - r.pos);
    Ok(JournalRecord { job, window, events, metrics, spans })
}

/// Append one record as journal entry `seq` of its job.  Rides
/// `SessionStore::put_raw`, so the record is committed with the same
/// shadow discipline as session images on either engine.
pub fn append(
    store: &SessionStore,
    seq: u64,
    rec: &JournalRecord,
) -> Result<()> {
    let bytes = encode_record(rec)?;
    store
        .put_raw(&journal_key(rec.job, seq), &bytes)
        .with_context(|| {
            format!("appending journal record {seq} of job {}", rec.job)
        })
}

/// Replay job `job`'s journal in sequence order, folding every record
/// at or before `up_to_window` (all records when `None`).  Replay
/// stops at the FIRST record past the limit: a journal can be at most
/// one window ahead of the session image (the crash landed between
/// the journal append and the image write), and that window will be
/// re-run live.
pub fn replay(
    store: &SessionStore,
    job: u32,
    up_to_window: Option<u64>,
) -> Result<Replay> {
    let prefix = format!("jrn{job}-");
    let mut out = Replay::default();
    // iter_keys is sorted; zero-padded seqs make that numeric order
    for key in store.iter_keys() {
        if !key.starts_with(&prefix) {
            continue;
        }
        let bytes = store
            .get_raw(&key)
            .with_context(|| format!("reading journal record {key}"))?;
        let rec = decode_record(&bytes)
            .with_context(|| format!("decoding journal record {key}"))?;
        ensure!(rec.job == job,
                "journal record {key} claims job {}", rec.job);
        if let Some(limit) = up_to_window {
            if rec.window > limit {
                break;
            }
        }
        out.events.extend(rec.events);
        out.metrics.merge(rec.metrics);
        out.spans.extend(rec.spans);
        out.records += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::trace;

    fn sample_record(job: u32, window: u64) -> JournalRecord {
        let mut metrics = MetricLog::new();
        metrics.record(&format!("job{job}.loss"), window * 4, 0.75);
        metrics.record(&format!("job{job}.loss"), window * 4 + 1, 0.5);
        metrics.record("fleet.mem", window, 123.0);
        JournalRecord {
            job,
            window,
            events: vec![
                Event::Admitted { job: job as usize,
                                  window: window as usize },
                Event::Denied { job: job as usize,
                                reason: "thermal" },
                Event::SplitDone { job: job as usize, steps: 8,
                                   loss: 0.5, bytes: 4096 },
                Event::OomFallback { job: job as usize,
                                     from: "adam", to: "mezo" },
                Event::Failed { job: job as usize,
                                error: "boom".into() },
            ],
            metrics,
            spans: vec![Span {
                job,
                window: window as u32,
                kind: SpanKind::Window,
                label: "split".into(),
                detail: "bw=0.75,up".into(),
                t_us: window * 600_000_000,
                dur_us: 2_000_000,
                bytes: 4096,
                uwh: 17,
                flops: 1 << 30,
                host_us: Some(999), // must NOT survive the round trip
            }],
        }
    }

    #[test]
    fn record_round_trips_and_strips_wall_clock() {
        let rec = sample_record(3, 5);
        let bytes = encode_record(&rec).unwrap();
        let back = decode_record(&bytes).unwrap();
        assert_eq!(back.job, 3);
        assert_eq!(back.window, 5);
        assert_eq!(back.events, rec.events);
        assert_eq!(back.metrics.to_csv(), rec.metrics.to_csv());
        assert_eq!(back.spans[0].host_us, None,
                   "wall clock must not be journaled");
        assert_eq!(trace::fingerprint(&back.spans),
                   trace::fingerprint(&rec.spans));
        // replayed &'static str labels are the live statics
        match (&back.events[1], &rec.events[1]) {
            (Event::Denied { reason: a, .. },
             Event::Denied { reason: b, .. }) => assert_eq!(a, b),
            _ => panic!("event order changed"),
        }
    }

    #[test]
    fn corruption_is_detected() {
        let rec = sample_record(0, 1);
        let mut bytes = encode_record(&rec).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode_record(&bytes).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        assert!(decode_record(&bytes[..8]).is_err());
    }

    #[test]
    fn keys_sort_numerically_and_do_not_collide() {
        assert_eq!(journal_key(1, 7), "jrn1-00000007");
        let mut keys: Vec<String> =
            (0..120).map(|s| journal_key(2, s)).collect();
        let sorted = {
            let mut k = keys.clone();
            k.sort();
            k
        };
        assert_eq!(keys, sorted, "zero-padded seqs sort numerically");
        keys.push(journal_key(10, 0));
        assert!(!keys.last().unwrap().starts_with("jrn1-"),
                "job 10's keys must not match job 1's prefix");
    }

    #[test]
    fn replay_truncates_at_the_image_window() {
        let dir = std::env::temp_dir().join(format!(
            "pljournal-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::with_mem_capacity(&dir, 0).unwrap();
        for (seq, window) in [(0u64, 1u64), (1, 2), (2, 4)] {
            append(&store, seq, &sample_record(7, window)).unwrap();
        }
        // a different job's journal must not leak in
        append(&store, 0, &sample_record(70, 1)).unwrap();

        let full = replay(&store, 7, None).unwrap();
        assert_eq!(full.records, 3);
        assert_eq!(full.events.len(), 15);
        assert_eq!(full.spans.len(), 3);
        assert_eq!(
            full.metrics.get("job7.loss").unwrap().points.len(),
            6
        );

        // image says window 2: the window-4 record is ahead of the
        // image (journal-then-crash) and must be dropped
        let cut = replay(&store, 7, Some(2)).unwrap();
        assert_eq!(cut.records, 2);
        assert_eq!(cut.spans.len(), 2);
        assert_eq!(cut.events.len(), 10);

        // idempotent overwrite: re-running window 4 rewrites seq 2
        // with identical bytes and replay sees no duplicates
        append(&store, 2, &sample_record(7, 4)).unwrap();
        let again = replay(&store, 7, None).unwrap();
        assert_eq!(again.records, 3);
        assert_eq!(again.events.len(), 15);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
