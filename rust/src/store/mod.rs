//! Durable session images + the fleet's hibernation store.
//!
//! PocketLLM's fleet multiplexes thousands of personalization jobs,
//! but a queued job's `Session` used to stay fully resident between
//! windows — parameters, optimizer moments, batch cache — so memory
//! grew linearly with queue depth.  This module makes a session a
//! *durable* object instead:
//!
//! * [`image`] — a versioned single-file binary **session image**
//!   (magic + header + CRC32): per-tensor records stored at their
//!   resident precision (f16/int8 bytes verbatim — no f32
//!   materialization), the Adam moments when present, the batcher
//!   stream position, the optimizer's `(master_seed, step)` clock, and
//!   a precision tag.  It is also the canonical checkpoint format
//!   ([`crate::tuner::checkpoint`] keeps a read shim for the legacy
//!   directory layout).
//! * [`session_store`] — a capacity-bounded LRU [`SessionStore`]
//!   keyed by job: `put` an image (recently used images stay in a
//!   bounded memory cache, older ones spill to disk), `take` it back
//!   on dispatch.  Hibernate → rehydrate is bit-identical — pinned
//!   against never-hibernated runs in `rust/tests/fleet.rs` and
//!   `rust/tests/integration.rs`.
//!
//! The MeZO/Adam asymmetry the paper measures in RAM (Table 1) holds
//! durably too: a MeZO image is the parameter bytes plus O(100) bytes
//! of metadata, while an Adam image carries the two f32 moment
//! tensors (~3x for f32 parameters, more for quantized ones).
//! `pocketllm store inspect` prints the breakdown.

pub mod engine;
pub mod image;
pub mod journal;
pub mod paged;
pub mod session_store;

pub use engine::{
    DirEngine, EngineKind, EngineStats, StoreEngine, PAGED_FILE_NAME,
};
pub use image::SessionImage;
pub use journal::JournalRecord;
pub use paged::{FsckReport, PagedEngine};
pub use session_store::{SessionStore, StoreStats};

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — the checksum the
/// session-image format trails with.  Table built at compile time; no
/// dependencies.
const CRC32_TABLE: [u32; 256] = build_crc32_table();

const fn build_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        let idx = ((crc ^ b as u32) & 0xFF) as usize;
        crc = (crc >> 8) ^ CRC32_TABLE[idx];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the classic check value for "123456789" under CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn crc32_detects_single_bit_flips() {
        let data = b"pocketllm session image".to_vec();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8u8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base,
                           "flip at byte {byte} bit {bit} undetected");
            }
        }
    }
}
