//! The hibernation store: bounded memory in front of durable disk.
//!
//! A [`SessionStore`] holds encoded [`SessionImage`]s by key.  The
//! most recently stored images stay in a memory cache bounded by
//! `mem_capacity` bytes; when the cache overflows, the
//! least-recently-used images spill to one file each under the store
//! directory (`<key>.plsi`).  `take` retrieves (and removes) an image
//! from wherever it lives — the bytes are identical either way, so
//! cache hits change latency only, never results.
//!
//! A capacity of 0 makes the store write-through: every image lands
//! on disk immediately and the store holds no parameter bytes in RAM
//! at all — the configuration the fleet scheduler uses, so a
//! 1000-job queue's memory profile is genuinely flat.
//!
//! Thread-safe: one internal lock, I/O performed inside `put`/`take`
//! by the calling worker.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use super::image::SessionImage;

/// Lifetime counters of one store (monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Images stored via `put`.
    pub puts: u64,
    /// Images retrieved via `take`.
    pub takes: u64,
    /// Takes served from the memory cache.
    pub mem_hits: u64,
    /// Takes served from disk.
    pub disk_hits: u64,
    /// LRU evictions written to disk.
    pub spills: u64,
    /// Total image bytes written to disk.
    pub bytes_spilled: u64,
}

#[derive(Default)]
struct Inner {
    /// Encoded images resident in memory.
    mem: HashMap<String, Vec<u8>>,
    /// Keys in recency order: front = least recently used.
    lru: VecDeque<String>,
    mem_bytes: u64,
    /// Keys whose image currently lives on disk.
    on_disk: HashSet<String>,
    stats: StoreStats,
}

/// A capacity-bounded, LRU, disk-backed store of session images.
pub struct SessionStore {
    dir: PathBuf,
    mem_capacity: u64,
    inner: Mutex<Inner>,
}

impl SessionStore {
    /// Open (creating the directory) with a 16 MiB memory cache.
    pub fn new(dir: impl AsRef<Path>) -> Result<SessionStore> {
        SessionStore::with_mem_capacity(dir, 16 * 1024 * 1024)
    }

    /// Open with an explicit memory-cache bound (0 = write-through,
    /// nothing retained in RAM).
    pub fn with_mem_capacity(
        dir: impl AsRef<Path>,
        mem_capacity: u64,
    ) -> Result<SessionStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| {
            format!("creating session store at {}", dir.display())
        })?;
        Ok(SessionStore {
            dir,
            mem_capacity,
            inner: Mutex::new(Inner::default()),
        })
    }

    /// Where `key`'s image lives when spilled.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.plsi"))
    }

    fn check_key(key: &str) -> Result<()> {
        ensure!(
            !key.is_empty()
                && key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_'
                        || c == '-'),
            "store keys must be [A-Za-z0-9_-]+, got {key:?}"
        );
        Ok(())
    }

    /// Store an image under `key` (replacing any previous image with
    /// that key).  Returns the encoded size in bytes.  May spill LRU
    /// entries — possibly this one — to disk to respect the memory
    /// bound.
    pub fn put(&self, key: &str, image: &SessionImage) -> Result<u64> {
        Self::check_key(key)?;
        image.validate()?;
        let bytes = image.encode();
        let len = bytes.len() as u64;
        let mut spill: Vec<(String, Vec<u8>)> = Vec::new();
        let stale_disk;
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.puts += 1;
            if let Some(old) = inner.mem.remove(key) {
                inner.mem_bytes -= old.len() as u64;
                inner.lru.retain(|k| k != key);
            }
            stale_disk = inner.on_disk.remove(key);
            inner.mem_bytes += len;
            inner.mem.insert(key.to_string(), bytes);
            inner.lru.push_back(key.to_string());
            while inner.mem_bytes > self.mem_capacity {
                let Some(victim) = inner.lru.pop_front() else {
                    break;
                };
                let data = inner
                    .mem
                    .remove(&victim)
                    .expect("lru key always resident");
                inner.mem_bytes -= data.len() as u64;
                spill.push((victim, data));
            }
        }
        if stale_disk {
            // the key's previous image had spilled; it is replaced now
            let _ = std::fs::remove_file(self.path_for(key));
        }
        // disk writes happen outside the lock; a victim is marked
        // on_disk only once its file actually exists, and a FAILED
        // write puts the bytes of EVERY not-yet-spilled victim back
        // into the memory cache (accepting transient over-capacity)
        // so an I/O error never loses an image.  Callers own their
        // keys (one job, one key), so a concurrent take() of a
        // mid-spill key is theoretical.
        let mut spill_iter = spill.into_iter();
        while let Some((victim, data)) = spill_iter.next() {
            match std::fs::write(self.path_for(&victim), &data) {
                Ok(()) => {
                    let vlen = data.len() as u64;
                    let mut inner = self.inner.lock().unwrap();
                    inner.on_disk.insert(victim);
                    inner.stats.spills += 1;
                    inner.stats.bytes_spilled += vlen;
                }
                Err(e) => {
                    let failed = victim.clone();
                    let unwritten: Vec<(String, Vec<u8>)> =
                        std::iter::once((victim, data))
                            .chain(spill_iter)
                            .collect();
                    let mut inner = self.inner.lock().unwrap();
                    // restore in reverse so the LRU front keeps the
                    // original oldest-first order
                    for (v, d) in unwritten.into_iter().rev() {
                        inner.mem_bytes += d.len() as u64;
                        inner.mem.insert(v.clone(), d);
                        inner.lru.push_front(v);
                    }
                    return Err(anyhow::Error::new(e).context(format!(
                        "spilling session image {failed}"
                    )));
                }
            }
        }
        Ok(len)
    }

    /// Retrieve and remove `key`'s image (memory first, disk second).
    /// A failed disk read leaves the entry in place (retryable); the
    /// entry is consumed only once its bytes are safely in hand.
    pub fn take(&self, key: &str) -> Result<SessionImage> {
        Self::check_key(key)?;
        let from_mem = {
            let mut inner = self.inner.lock().unwrap();
            if let Some(bytes) = inner.mem.remove(key) {
                inner.mem_bytes -= bytes.len() as u64;
                inner.lru.retain(|k| k != key);
                inner.stats.takes += 1;
                inner.stats.mem_hits += 1;
                Some(bytes)
            } else if inner.on_disk.contains(key) {
                None // read the file outside the lock
            } else {
                bail!("no session image stored under {key:?}")
            }
        };
        let bytes = match from_mem {
            Some(b) => b,
            None => {
                let path = self.path_for(key);
                let b = std::fs::read(&path).with_context(|| {
                    format!("reading spilled image {}", path.display())
                })?;
                let mut inner = self.inner.lock().unwrap();
                inner.on_disk.remove(key);
                inner.stats.takes += 1;
                inner.stats.disk_hits += 1;
                drop(inner);
                let _ = std::fs::remove_file(&path);
                b
            }
        };
        SessionImage::decode(&bytes)
            .with_context(|| format!("decoding session image {key:?}"))
    }

    /// Whether `key` currently has a stored image.
    pub fn contains(&self, key: &str) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.mem.contains_key(key) || inner.on_disk.contains(key)
    }

    /// Number of stored images (memory + disk).
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.mem.len() + inner.on_disk.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently held in the memory cache (always <= capacity
    /// after `put` returns).
    pub fn mem_bytes(&self) -> u64 {
        self.inner.lock().unwrap().mem_bytes
    }

    pub fn stats(&self) -> StoreStats {
        self.inner.lock().unwrap().stats
    }

    /// Best-effort removal of the store directory (for run-scoped
    /// stores; fails silently if images are still present elsewhere).
    pub fn cleanup(&self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;
    use crate::optim::OptimizerKind;
    use crate::runtime::literal::Literal;
    use crate::runtime::precision::Precision;

    fn image(tag: f32) -> SessionImage {
        SessionImage {
            config: "t".into(),
            optimizer: OptimizerKind::MeZo,
            precision: Precision::F32,
            task: TaskKind::Sst2,
            step: 1,
            master_seed: 2,
            data_seed: 3,
            batcher_pos: 0,
            last_loss: 0.5,
            batch: 4,
            params: vec![Literal::from_f32(vec![tag; 8], vec![8])
                .unwrap()],
            adam_m: Vec::new(),
            adam_v: Vec::new(),
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pocketllm_store_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_take_roundtrip_from_memory() {
        let store = SessionStore::new(tmp("mem")).unwrap();
        store.put("job0", &image(1.5)).unwrap();
        assert!(store.contains("job0"));
        assert_eq!(store.len(), 1);
        let back = store.take("job0").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![1.5; 8]);
        assert!(!store.contains("job0"));
        assert!(store.is_empty());
        let s = store.stats();
        assert_eq!((s.puts, s.takes, s.mem_hits, s.disk_hits, s.spills),
                   (1, 1, 1, 0, 0));
        assert!(store.take("job0").is_err(), "double take must fail");
    }

    #[test]
    fn lru_spills_oldest_to_disk_and_takes_still_work() {
        // capacity fits ~2 images; the third put evicts the oldest
        let one = image(0.0).encode().len() as u64;
        let store =
            SessionStore::with_mem_capacity(tmp("lru"), 2 * one)
                .unwrap();
        store.put("job0", &image(0.0)).unwrap();
        store.put("job1", &image(1.0)).unwrap();
        store.put("job2", &image(2.0)).unwrap();
        assert!(store.mem_bytes() <= 2 * one);
        let s = store.stats();
        assert_eq!(s.spills, 1, "oldest image must spill");
        assert!(store.path_for("job0").exists(),
                "job0 is the LRU victim");
        // all three still retrievable, with the right payloads
        for (k, want) in [("job0", 0.0f32), ("job1", 1.0), ("job2", 2.0)]
        {
            let img = store.take(k).unwrap();
            assert_eq!(img.params[0].f32_vec().unwrap(), vec![want; 8]);
        }
        let s = store.stats();
        assert_eq!(s.disk_hits, 1);
        assert_eq!(s.mem_hits, 2);
        assert!(!store.path_for("job0").exists(),
                "take must consume the spilled file");
    }

    #[test]
    fn zero_capacity_is_write_through() {
        let store =
            SessionStore::with_mem_capacity(tmp("wt"), 0).unwrap();
        store.put("a", &image(7.0)).unwrap();
        assert_eq!(store.mem_bytes(), 0,
                   "write-through must hold nothing in RAM");
        assert!(store.path_for("a").exists());
        assert_eq!(store.stats().spills, 1);
        let back = store.take("a").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![7.0; 8]);
        assert_eq!(store.stats().disk_hits, 1);
    }

    #[test]
    fn replacing_a_key_keeps_one_image() {
        let store = SessionStore::new(tmp("replace")).unwrap();
        store.put("k", &image(1.0)).unwrap();
        store.put("k", &image(2.0)).unwrap();
        assert_eq!(store.len(), 1);
        let back = store.take("k").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![2.0; 8]);
    }

    #[test]
    fn corrupt_spilled_file_fails_loudly() {
        let store =
            SessionStore::with_mem_capacity(tmp("corrupt"), 0).unwrap();
        store.put("x", &image(3.0)).unwrap();
        // flip one payload byte on disk
        let path = store.path_for("x");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, bytes).unwrap();
        let err = store.take("x").unwrap_err();
        assert!(format!("{err:#}").contains("CRC"),
                "corruption must surface as a CRC error: {err:#}");
    }

    #[test]
    fn bad_keys_rejected() {
        let store = SessionStore::new(tmp("keys")).unwrap();
        assert!(store.put("../evil", &image(0.0)).is_err());
        assert!(store.put("", &image(0.0)).is_err());
        assert!(store.take("no/slash").is_err());
        store.put("ok_key-1", &image(0.0)).unwrap();
    }
}
