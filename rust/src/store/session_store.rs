//! The hibernation store: bounded memory in front of a durable
//! [`StoreEngine`].
//!
//! A [`SessionStore`] holds encoded [`SessionImage`]s by key.  The
//! most recently stored images stay in a memory cache bounded by
//! `mem_capacity` bytes; when the cache overflows, the
//! least-recently-used images spill to the engine — one `<key>.plsi`
//! file each under the dir engine, one shadow-committed page segment
//! each under the paged engine.  `take` retrieves (and removes) an
//! image from wherever it lives — the bytes are identical either way,
//! so cache hits change latency only, never results.
//!
//! A capacity of 0 makes the store write-through: every image lands
//! on the engine immediately and the store holds no parameter bytes
//! in RAM at all — the configuration the fleet scheduler uses, so a
//! 1000-job queue's memory profile is genuinely flat, and every
//! hibernated job is durable the moment `put` returns (what
//! `FleetScheduler::recover` relies on).
//!
//! Failure contract: a failed spill re-caches every unwritten victim
//! (an I/O error never loses an image), and a failed `take` decode
//! leaves the stored bytes in place — corrupt spilled images stay on
//! disk for `store fsck` to report.
//!
//! Thread-safe: one internal lock, I/O performed outside it (dir
//! engine) or under the engine's own lock (paged).

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::engine::{
    DirEngine, EngineKind, EngineStats, StoreEngine, PAGED_FILE_NAME,
};
use super::image::SessionImage;
use super::paged::PagedEngine;

/// Lifetime counters of one store (monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Images stored via `put`.
    pub puts: u64,
    /// Images retrieved via `take`.
    pub takes: u64,
    /// Images read (non-consuming) via `get`.
    pub gets: u64,
    /// Retrievals served from the memory cache.
    pub mem_hits: u64,
    /// Retrievals served from the engine.
    pub disk_hits: u64,
    /// LRU evictions written to the engine.
    pub spills: u64,
    /// Total image bytes written to the engine.
    pub bytes_spilled: u64,
}

#[derive(Default)]
struct Inner {
    /// Encoded images resident in memory.  BTreeMap, not HashMap:
    /// `iter_keys` feeds fleet recovery, so key order must be
    /// process-independent (D001 / bit-identity contract).
    mem: BTreeMap<String, Vec<u8>>,
    /// Keys in recency order: front = least recently used.
    lru: VecDeque<String>,
    mem_bytes: u64,
    stats: StoreStats,
}

/// A capacity-bounded, LRU, engine-backed store of session images.
pub struct SessionStore {
    engine: Arc<dyn StoreEngine>,
    dir: PathBuf,
    mem_capacity: u64,
    inner: Mutex<Inner>,
}

impl SessionStore {
    /// Open (creating the directory) with a 16 MiB memory cache on
    /// the dir engine.
    pub fn new(dir: impl AsRef<Path>) -> Result<SessionStore> {
        SessionStore::with_mem_capacity(dir, 16 * 1024 * 1024)
    }

    /// Open on the dir engine with an explicit memory-cache bound
    /// (0 = write-through, nothing retained in RAM).
    pub fn with_mem_capacity(
        dir: impl AsRef<Path>,
        mem_capacity: u64,
    ) -> Result<SessionStore> {
        SessionStore::open_with(EngineKind::Dir, dir, mem_capacity)
    }

    /// Open on an explicit engine kind.
    pub fn open_with(
        kind: EngineKind,
        dir: impl AsRef<Path>,
        mem_capacity: u64,
    ) -> Result<SessionStore> {
        let dir = dir.as_ref().to_path_buf();
        let engine: Arc<dyn StoreEngine> = match kind {
            EngineKind::Dir => Arc::new(DirEngine::open(&dir)?),
            EngineKind::Paged => {
                std::fs::create_dir_all(&dir).with_context(|| {
                    format!(
                        "creating session store at {}",
                        dir.display()
                    )
                })?;
                Arc::new(PagedEngine::open(
                    dir.join(PAGED_FILE_NAME),
                )?)
            }
        };
        Ok(SessionStore::with_engine(engine, dir, mem_capacity))
    }

    /// Open whichever engine already lives under `dir`: paged if its
    /// store file exists, the dir layout otherwise — what
    /// `fleet --recover` and `store inspect` use, so neither needs to
    /// be told the engine.
    pub fn open_auto(
        dir: impl AsRef<Path>,
        mem_capacity: u64,
    ) -> Result<SessionStore> {
        let kind = if dir.as_ref().join(PAGED_FILE_NAME).is_file() {
            EngineKind::Paged
        } else {
            EngineKind::Dir
        };
        SessionStore::open_with(kind, dir, mem_capacity)
    }

    /// Build on an already-open engine (tests inject failing engines
    /// here; `dir` is what `cleanup` removes and `root` reports).
    pub fn with_engine(
        engine: Arc<dyn StoreEngine>,
        dir: PathBuf,
        mem_capacity: u64,
    ) -> SessionStore {
        SessionStore {
            engine,
            dir,
            mem_capacity,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// The store's directory.
    pub fn root(&self) -> &Path {
        &self.dir
    }

    /// Which engine backs the store.
    pub fn engine_kind(&self) -> EngineKind {
        self.engine.kind()
    }

    /// The backing engine's lifetime counters.
    pub fn engine_stats(&self) -> EngineStats {
        self.engine.stats()
    }

    /// Bytes the backing engine currently occupies on disk.
    pub fn disk_bytes(&self) -> u64 {
        self.engine.disk_bytes()
    }

    /// Filesystem objects the backing engine uses.
    pub fn file_count(&self) -> u64 {
        self.engine.file_count()
    }

    /// Where `key`'s image lives when spilled under the DIR engine
    /// (the paged engine keeps every key inside one store file).
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.plsi"))
    }

    fn check_key(key: &str) -> Result<()> {
        ensure!(
            !key.is_empty()
                && key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_'
                        || c == '-'),
            "store keys must be [A-Za-z0-9_-]+, got {key:?}"
        );
        Ok(())
    }

    /// Store an image under `key` (replacing any previous image with
    /// that key).  Returns the encoded size in bytes.  May spill LRU
    /// entries — possibly this one — to the engine to respect the
    /// memory bound.
    pub fn put(&self, key: &str, image: &SessionImage) -> Result<u64> {
        Self::check_key(key)?;
        image.validate()?;
        let bytes = image.encode();
        let len = bytes.len() as u64;
        self.put_encoded(key, bytes)?;
        Ok(len)
    }

    /// Store pre-encoded bytes without image validation — the fleet
    /// manifest's path.  Always written through to the engine (the
    /// caller wants durability, not caching).
    pub fn put_raw(&self, key: &str, bytes: &[u8]) -> Result<()> {
        Self::check_key(key)?;
        self.engine.put(key, bytes)
    }

    /// Read raw bytes previously stored with [`put_raw`] (or an
    /// image's encoded bytes, if it has spilled).
    ///
    /// [`put_raw`]: SessionStore::put_raw
    pub fn get_raw(&self, key: &str) -> Result<Vec<u8>> {
        Self::check_key(key)?;
        if let Some(bytes) =
            self.inner.lock().unwrap().mem.get(key).cloned()
        {
            return Ok(bytes);
        }
        self.engine.get(key)
    }

    fn put_encoded(&self, key: &str, bytes: Vec<u8>) -> Result<()> {
        let len = bytes.len() as u64;
        let mut spill: Vec<(String, Vec<u8>)> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.puts += 1;
            if let Some(old) = inner.mem.remove(key) {
                inner.mem_bytes -= old.len() as u64;
                inner.lru.retain(|k| k != key);
            }
            inner.mem_bytes += len;
            inner.mem.insert(key.to_string(), bytes);
            inner.lru.push_back(key.to_string());
            while inner.mem_bytes > self.mem_capacity {
                let Some(victim) = inner.lru.pop_front() else {
                    break;
                };
                // lint:allow(D004): lru and mem insert under one lock
                let data = inner.mem.remove(&victim).expect("resident");
                inner.mem_bytes -= data.len() as u64;
                spill.push((victim, data));
            }
        }
        // engine writes happen outside the cache lock; a FAILED write
        // puts the bytes of EVERY not-yet-spilled victim back into
        // the memory cache (accepting transient over-capacity) so an
        // I/O error never loses an image.  Callers own their keys
        // (one job, one key), so a concurrent take() of a mid-spill
        // key is theoretical.
        let spilled_self = spill.iter().any(|(v, _)| v == key);
        let mut spill_iter = spill.into_iter();
        while let Some((victim, data)) = spill_iter.next() {
            match self.engine.put(&victim, &data) {
                Ok(()) => {
                    let vlen = data.len() as u64;
                    let mut inner = self.inner.lock().unwrap();
                    inner.stats.spills += 1;
                    inner.stats.bytes_spilled += vlen;
                }
                Err(e) => {
                    let failed = victim.clone();
                    let unwritten: Vec<(String, Vec<u8>)> =
                        std::iter::once((victim, data))
                            .chain(spill_iter)
                            .collect();
                    let mut inner = self.inner.lock().unwrap();
                    // restore in reverse so the LRU front keeps the
                    // original oldest-first order
                    for (v, d) in unwritten.into_iter().rev() {
                        inner.mem_bytes += d.len() as u64;
                        inner.mem.insert(v.clone(), d);
                        inner.lru.push_front(v);
                    }
                    return Err(e.context(format!(
                        "spilling session image {failed}"
                    )));
                }
            }
        }
        // the new image stayed resident, but an OLDER spilled copy of
        // this key may survive in the engine; drop it only now that
        // every spill has landed — never before the replacement is
        // durable or cached
        if !spilled_self && self.engine.contains(key) {
            let _ = self.engine.remove(key);
        }
        Ok(())
    }

    /// Retrieve and remove `key`'s image (memory first, engine
    /// second).  The entry is consumed only once its bytes decode:
    /// a failed engine read OR a corrupt image leaves the stored
    /// bytes exactly where they were — retryable, and visible to
    /// `store fsck`.
    pub fn take(&self, key: &str) -> Result<SessionImage> {
        Self::check_key(key)?;
        let from_mem = {
            let mut inner = self.inner.lock().unwrap();
            if let Some(bytes) = inner.mem.remove(key) {
                inner.mem_bytes -= bytes.len() as u64;
                inner.lru.retain(|k| k != key);
                Some(bytes)
            } else {
                None
            }
        };
        if let Some(bytes) = from_mem {
            let decoded = SessionImage::decode(&bytes).with_context(
                || format!("decoding session image {key:?}"),
            );
            let mut inner = self.inner.lock().unwrap();
            return match decoded {
                Ok(image) => {
                    inner.stats.takes += 1;
                    inner.stats.mem_hits += 1;
                    Ok(image)
                }
                Err(e) => {
                    // put the bytes back: a failed take must not
                    // destroy the only copy
                    inner.mem_bytes += bytes.len() as u64;
                    inner.mem.insert(key.to_string(), bytes);
                    inner.lru.push_back(key.to_string());
                    Err(e)
                }
            };
        }
        if !self.engine.contains(key) {
            bail!("no session image stored under {key:?}");
        }
        let bytes = self
            .engine
            .get(key)
            .with_context(|| format!("reading spilled image {key:?}"))?;
        let image = SessionImage::decode(&bytes).with_context(|| {
            format!("decoding session image {key:?}")
        })?;
        // decode succeeded — only NOW is the stored copy consumed
        let _ = self.engine.remove(key);
        let mut inner = self.inner.lock().unwrap();
        inner.stats.takes += 1;
        inner.stats.disk_hits += 1;
        Ok(image)
    }

    /// Read `key`'s image without removing it — recovery's path, so
    /// a crash between read and resume can always be retried.
    pub fn get(&self, key: &str) -> Result<SessionImage> {
        Self::check_key(key)?;
        let from_mem = {
            let mut inner = self.inner.lock().unwrap();
            let bytes = inner.mem.get(key).cloned();
            if bytes.is_some() {
                // refresh recency
                inner.lru.retain(|k| k != key);
                inner.lru.push_back(key.to_string());
            }
            bytes
        };
        let (bytes, from_mem) = match from_mem {
            Some(b) => (b, true),
            None => {
                if !self.engine.contains(key) {
                    bail!("no session image stored under {key:?}");
                }
                let b = self.engine.get(key).with_context(|| {
                    format!("reading spilled image {key:?}")
                })?;
                (b, false)
            }
        };
        let image = SessionImage::decode(&bytes).with_context(|| {
            format!("decoding session image {key:?}")
        })?;
        let mut inner = self.inner.lock().unwrap();
        inner.stats.gets += 1;
        if from_mem {
            inner.stats.mem_hits += 1;
        } else {
            inner.stats.disk_hits += 1;
        }
        Ok(image)
    }

    /// Remove `key` wherever it lives; `Ok(true)` if it existed.
    pub fn remove(&self, key: &str) -> Result<bool> {
        Self::check_key(key)?;
        let in_mem = {
            let mut inner = self.inner.lock().unwrap();
            match inner.mem.remove(key) {
                Some(bytes) => {
                    inner.mem_bytes -= bytes.len() as u64;
                    inner.lru.retain(|k| k != key);
                    true
                }
                None => false,
            }
        };
        let on_engine = self.engine.remove(key)?;
        Ok(in_mem || on_engine)
    }

    /// Whether `key` currently has a stored image.
    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().mem.contains_key(key)
            || self.engine.contains(key)
    }

    /// Number of stored keys (memory + engine, deduplicated).
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        let extra = self
            .engine
            .iter_keys()
            .iter()
            .filter(|k| !inner.mem.contains_key(k.as_str()))
            .count();
        inner.mem.len() + extra
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All stored keys, sorted (memory + engine, deduplicated).
    pub fn iter_keys(&self) -> Vec<String> {
        let mut keys: std::collections::BTreeSet<String> =
            self.engine.iter_keys().into_iter().collect();
        let inner = self.inner.lock().unwrap();
        keys.extend(inner.mem.keys().cloned());
        keys.into_iter().collect()
    }

    /// Bytes currently held in the memory cache (always <= capacity
    /// after `put` returns).
    pub fn mem_bytes(&self) -> u64 {
        self.inner.lock().unwrap().mem_bytes
    }

    pub fn stats(&self) -> StoreStats {
        self.inner.lock().unwrap().stats
    }

    /// Best-effort removal of the store directory (for run-scoped
    /// stores; fails silently if images are still present elsewhere).
    pub fn cleanup(&self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;
    use crate::optim::OptimizerKind;
    use crate::runtime::literal::Literal;
    use crate::runtime::precision::Precision;

    fn image(tag: f32) -> SessionImage {
        SessionImage {
            config: "t".into(),
            optimizer: OptimizerKind::MeZo,
            precision: Precision::F32,
            task: TaskKind::Sst2,
            step: 1,
            master_seed: 2,
            data_seed: 3,
            batcher_pos: 0,
            last_loss: 0.5,
            batch: 4,
            params: vec![Literal::from_f32(vec![tag; 8], vec![8])
                .unwrap()],
            adam_m: Vec::new(),
            adam_v: Vec::new(),
            recovery: None,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pocketllm_store_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_take_roundtrip_from_memory() {
        let store = SessionStore::new(tmp("mem")).unwrap();
        store.put("job0", &image(1.5)).unwrap();
        assert!(store.contains("job0"));
        assert_eq!(store.len(), 1);
        let back = store.take("job0").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![1.5; 8]);
        assert!(!store.contains("job0"));
        assert!(store.is_empty());
        let s = store.stats();
        assert_eq!((s.puts, s.takes, s.mem_hits, s.disk_hits, s.spills),
                   (1, 1, 1, 0, 0));
        assert!(store.take("job0").is_err(), "double take must fail");
    }

    #[test]
    fn lru_spills_oldest_to_disk_and_takes_still_work() {
        // capacity fits ~2 images; the third put evicts the oldest
        let one = image(0.0).encode().len() as u64;
        let store =
            SessionStore::with_mem_capacity(tmp("lru"), 2 * one)
                .unwrap();
        store.put("job0", &image(0.0)).unwrap();
        store.put("job1", &image(1.0)).unwrap();
        store.put("job2", &image(2.0)).unwrap();
        assert!(store.mem_bytes() <= 2 * one);
        let s = store.stats();
        assert_eq!(s.spills, 1, "oldest image must spill");
        assert!(store.path_for("job0").exists(),
                "job0 is the LRU victim");
        // all three still retrievable, with the right payloads
        for (k, want) in [("job0", 0.0f32), ("job1", 1.0), ("job2", 2.0)]
        {
            let img = store.take(k).unwrap();
            assert_eq!(img.params[0].f32_vec().unwrap(), vec![want; 8]);
        }
        let s = store.stats();
        assert_eq!(s.disk_hits, 1);
        assert_eq!(s.mem_hits, 2);
        assert!(!store.path_for("job0").exists(),
                "take must consume the spilled file");
    }

    #[test]
    fn zero_capacity_is_write_through() {
        let store =
            SessionStore::with_mem_capacity(tmp("wt"), 0).unwrap();
        store.put("a", &image(7.0)).unwrap();
        assert_eq!(store.mem_bytes(), 0,
                   "write-through must hold nothing in RAM");
        assert!(store.path_for("a").exists());
        assert_eq!(store.stats().spills, 1);
        let back = store.take("a").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![7.0; 8]);
        assert_eq!(store.stats().disk_hits, 1);
    }

    #[test]
    fn replacing_a_key_keeps_one_image() {
        let store = SessionStore::new(tmp("replace")).unwrap();
        store.put("k", &image(1.0)).unwrap();
        store.put("k", &image(2.0)).unwrap();
        assert_eq!(store.len(), 1);
        let back = store.take("k").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![2.0; 8]);
    }

    #[test]
    fn replacing_a_spilled_key_drops_the_stale_engine_copy() {
        // cap fits exactly one image: the first put of "a" spills
        // when "b" arrives; re-putting "a" (resident) must leave the
        // store with ONE copy of "a" — the new one — and no stale
        // engine entry resurrectable after the cache empties
        let one = image(0.0).encode().len() as u64;
        let store =
            SessionStore::with_mem_capacity(tmp("stale"), one)
                .unwrap();
        store.put("a", &image(1.0)).unwrap();
        store.put("b", &image(2.0)).unwrap(); // spills "a"
        assert!(store.path_for("a").exists());
        store.put("a", &image(9.0)).unwrap(); // spills "b"
        assert_eq!(store.len(), 2);
        assert!(!store.path_for("a").exists(),
                "stale spilled copy of a replaced key must go");
        let a = store.take("a").unwrap();
        assert_eq!(a.params[0].f32_vec().unwrap(), vec![9.0; 8]);
        let b = store.take("b").unwrap();
        assert_eq!(b.params[0].f32_vec().unwrap(), vec![2.0; 8]);
        assert!(store.is_empty());
    }

    #[test]
    fn corrupt_spilled_file_fails_loudly_and_stays_on_disk() {
        let store =
            SessionStore::with_mem_capacity(tmp("corrupt"), 0).unwrap();
        store.put("x", &image(3.0)).unwrap();
        // flip one payload byte on disk
        let path = store.path_for("x");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, bytes).unwrap();
        let err = store.take("x").unwrap_err();
        assert!(format!("{err:#}").contains("CRC"),
                "corruption must surface as a CRC error: {err:#}");
        // the satellite bugfix: a corrupt image is NOT silently
        // destroyed — it stays on disk for `store fsck`, and the
        // take stays retryable
        assert!(path.exists(),
                "corrupt spilled image must survive a failed take");
        assert!(store.contains("x"));
        assert!(store.take("x").is_err(), "still corrupt on retry");
        assert!(path.exists());
    }

    #[test]
    fn get_is_non_consuming_on_both_tiers() {
        let store =
            SessionStore::with_mem_capacity(tmp("get"), 0).unwrap();
        store.put("j", &image(4.0)).unwrap();
        let a = store.get("j").unwrap();
        let b = store.get("j").unwrap();
        assert_eq!(a.params[0].f32_vec().unwrap(),
                   b.params[0].f32_vec().unwrap());
        assert!(store.contains("j"), "get must not consume");
        let s = store.stats();
        assert_eq!((s.gets, s.takes), (2, 0));
        // and the entry is still takeable afterwards
        store.take("j").unwrap();
        assert!(store.is_empty());
    }

    #[test]
    fn bad_keys_rejected() {
        let store = SessionStore::new(tmp("keys")).unwrap();
        assert!(store.put("../evil", &image(0.0)).is_err());
        assert!(store.put("", &image(0.0)).is_err());
        assert!(store.take("no/slash").is_err());
        store.put("ok_key-1", &image(0.0)).unwrap();
    }

    #[test]
    fn iter_keys_order_is_insertion_invariant() {
        // the recovery scan replays jobs in iter_keys order, so the
        // order must depend only on the key SET — never on insertion
        // order, hash seeds, or the memory/engine split (D001)
        let keys = ["job7", "job0", "job3", "job11", "job1"];
        let mut sorted: Vec<String> =
            keys.iter().map(|k| k.to_string()).collect();
        sorted.sort();

        // fully resident
        let a = SessionStore::with_mem_capacity(tmp("order_a"),
                                                1 << 20)
            .unwrap();
        for k in keys {
            a.put(k, &image(1.0)).unwrap();
        }
        assert_eq!(a.iter_keys(), sorted);

        // reversed insertion, zero capacity: every key lives in the
        // engine instead of the memory map
        let b = SessionStore::with_mem_capacity(tmp("order_b"), 0)
            .unwrap();
        for k in keys.iter().rev() {
            b.put(k, &image(1.0)).unwrap();
        }
        assert_eq!(b.iter_keys(), sorted);
        assert_eq!(a.iter_keys(), b.iter_keys());
    }

    #[test]
    fn paged_engine_roundtrip_and_auto_detection() {
        let dir = tmp("paged");
        {
            let store = SessionStore::open_with(
                EngineKind::Paged,
                &dir,
                0,
            )
            .unwrap();
            assert_eq!(store.engine_kind(), EngineKind::Paged);
            store.put("job0", &image(5.0)).unwrap();
            store.put("job1", &image(6.0)).unwrap();
            assert_eq!(store.file_count(), 1,
                       "paged store is one file, any key count");
            assert_eq!(store.iter_keys(), vec!["job0", "job1"]);
        }
        // a fresh process: open_auto sniffs the paged store file
        let store = SessionStore::open_auto(&dir, 0).unwrap();
        assert_eq!(store.engine_kind(), EngineKind::Paged);
        assert_eq!(store.len(), 2);
        let back = store.take("job1").unwrap();
        assert_eq!(back.params[0].f32_vec().unwrap(), vec![6.0; 8]);
        let j0 = store.get("job0").unwrap();
        assert_eq!(j0.params[0].f32_vec().unwrap(), vec![5.0; 8]);
        assert!(store.contains("job0"));
    }

    #[test]
    fn open_auto_defaults_to_dir_engine() {
        let store = SessionStore::open_auto(tmp("auto_dir"), 0)
            .unwrap();
        assert_eq!(store.engine_kind(), EngineKind::Dir);
    }

    #[test]
    fn raw_blobs_roundtrip_without_image_validation() {
        let store =
            SessionStore::with_mem_capacity(tmp("raw"), 0).unwrap();
        store.put_raw("fleet-manifest", b"not an image").unwrap();
        assert_eq!(store.get_raw("fleet-manifest").unwrap(),
                   b"not an image");
        assert!(store.contains("fleet-manifest"));
        assert!(store.take("fleet-manifest").is_err(),
                "raw bytes must not decode as an image");
        assert!(store.contains("fleet-manifest"),
                "failed decode must leave the blob in place");
        assert!(store.remove("fleet-manifest").unwrap());
        assert!(!store.contains("fleet-manifest"));
    }

    /// An engine whose writes fail on command — exercises the
    /// spill-failure re-cache path without needing filesystem
    /// permission tricks (this suite runs as root in CI, where
    /// read-only directories don't block anything).
    struct FailingEngine {
        inner: DirEngine,
        fail_puts: std::sync::atomic::AtomicBool,
    }

    impl StoreEngine for FailingEngine {
        fn kind(&self) -> EngineKind {
            self.inner.kind()
        }
        fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
            if self.fail_puts.load(
                std::sync::atomic::Ordering::SeqCst,
            ) {
                bail!("injected I/O failure writing {key:?}");
            }
            self.inner.put(key, bytes)
        }
        fn get(&self, key: &str) -> Result<Vec<u8>> {
            self.inner.get(key)
        }
        fn remove(&self, key: &str) -> Result<bool> {
            self.inner.remove(key)
        }
        fn contains(&self, key: &str) -> bool {
            self.inner.contains(key)
        }
        fn len(&self) -> usize {
            self.inner.len()
        }
        fn iter_keys(&self) -> Vec<String> {
            self.inner.iter_keys()
        }
        fn stats(&self) -> EngineStats {
            self.inner.stats()
        }
        fn disk_bytes(&self) -> u64 {
            self.inner.disk_bytes()
        }
        fn file_count(&self) -> u64 {
            self.inner.file_count()
        }
    }

    #[test]
    fn failed_spill_recaches_every_victim() {
        let dir = tmp("failspill");
        let engine = Arc::new(FailingEngine {
            inner: DirEngine::open(&dir).unwrap(),
            fail_puts: std::sync::atomic::AtomicBool::new(true),
        });
        let one = image(0.0).encode().len() as u64;
        let store = SessionStore::with_engine(
            engine.clone(),
            dir,
            2 * one,
        );
        store.put("job0", &image(0.0)).unwrap();
        store.put("job1", &image(1.0)).unwrap();
        // the third put forces a spill of job0, which fails: the put
        // must error, but EVERY image — including the victim — must
        // still be retrievable from memory
        let err = store.put("job2", &image(2.0)).unwrap_err();
        assert!(format!("{err:#}").contains("injected"), "{err:#}");
        assert!(store.mem_bytes() > 2 * one,
                "re-cache accepts transient over-capacity");
        assert_eq!(store.stats().spills, 0);
        for (k, want) in [("job0", 0.0f32), ("job1", 1.0), ("job2", 2.0)]
        {
            let img = store.take(k).unwrap();
            assert_eq!(img.params[0].f32_vec().unwrap(), vec![want; 8],
                       "{k} must survive the failed spill");
        }
        // once the engine heals, spills work again
        engine
            .fail_puts
            .store(false, std::sync::atomic::Ordering::SeqCst);
        store.put("job3", &image(3.0)).unwrap();
        store.put("job4", &image(4.0)).unwrap();
        store.put("job5", &image(5.0)).unwrap();
        assert_eq!(store.stats().spills, 1);
    }
}
