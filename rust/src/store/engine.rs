//! The storage engine behind [`SessionStore`]: a small key→bytes
//! contract with two backends.
//!
//! * [`DirEngine`] — the historical layout: one `<key>.plsi` file per
//!   key under a directory.  Writes go through a temp file + rename,
//!   so a kill mid-write leaves either the old file or the new one,
//!   never a torn hybrid.
//! * [`PagedEngine`](super::paged::PagedEngine) — a single paged
//!   store file with shadow-page commits (see [`super::paged`]).
//!
//! [`SessionStore`] layers its LRU memory cache and image-level
//! validation on top; engines traffic in opaque bytes only.  Every
//! engine keeps its key set in memory, so `contains`/`len`/
//! `iter_keys` never touch the filesystem.
//!
//! [`SessionStore`]: super::SessionStore

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// File name of the paged store inside a store directory (the
/// directory stays the unit of configuration for both engines).
pub const PAGED_FILE_NAME: &str = "sessions.plpg";

/// Which storage engine backs a store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// One file per key under the store directory.
    Dir,
    /// One paged, CRC-ledgered, shadow-committed store file.
    Paged,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "dir" => Ok(EngineKind::Dir),
            "paged" => Ok(EngineKind::Paged),
            other => bail!("unknown store engine '{other}' (dir|paged)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Dir => "dir",
            EngineKind::Paged => "paged",
        }
    }
}

/// Lifetime counters of one engine (monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub puts: u64,
    pub gets: u64,
    pub removes: u64,
    /// Payload bytes durably written (excludes engine metadata).
    pub bytes_written: u64,
}

/// The key→bytes contract [`SessionStore`](super::SessionStore) is
/// built on.  `put` must be atomic-replace and durable (fsync'd):
/// after it returns, a kill at any point leaves `key` readable with
/// either the old or the new bytes.
pub trait StoreEngine: Send + Sync {
    fn kind(&self) -> EngineKind;

    /// Durably store `bytes` under `key`, replacing atomically.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()>;

    /// Read a copy of `key`'s bytes without consuming the entry.
    fn get(&self, key: &str) -> Result<Vec<u8>>;

    /// Remove `key`; `Ok(true)` if it existed.
    fn remove(&self, key: &str) -> Result<bool>;

    fn contains(&self, key: &str) -> bool;

    /// Number of stored keys.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All stored keys, sorted.
    fn iter_keys(&self) -> Vec<String>;

    /// Read and consume: the entry is removed only after the bytes
    /// are safely in hand, so a failed read stays retryable.
    fn take(&self, key: &str) -> Result<Vec<u8>> {
        let bytes = self.get(key)?;
        self.remove(key)?;
        Ok(bytes)
    }

    fn stats(&self) -> EngineStats;

    /// Bytes the engine currently occupies on disk.
    fn disk_bytes(&self) -> u64;

    /// Filesystem objects the engine uses (files, not directories) —
    /// the inode-pressure axis `BENCH_store.json` compares.
    fn file_count(&self) -> u64;
}

struct DirInner {
    keys: BTreeSet<String>,
    stats: EngineStats,
}

/// One `<key>.plsi` file per key, temp-file + rename writes.
pub struct DirEngine {
    dir: PathBuf,
    inner: Mutex<DirInner>,
}

impl DirEngine {
    /// Open (creating the directory), discovering any keys a previous
    /// process left behind — what `FleetScheduler::recover` scans.
    pub fn open(dir: impl AsRef<Path>) -> Result<DirEngine> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| {
            format!("creating store directory {}", dir.display())
        })?;
        let mut keys = BTreeSet::new();
        for entry in std::fs::read_dir(&dir)? {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(key) = name.strip_suffix(".plsi") {
                keys.insert(key.to_string());
            }
        }
        Ok(DirEngine {
            dir,
            inner: Mutex::new(DirInner {
                keys,
                stats: EngineStats::default(),
            }),
        })
    }

    /// Where `key`'s bytes live on disk.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.plsi"))
    }
}

impl StoreEngine for DirEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Dir
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        let path = self.path_for(key);
        let tmp = self.dir.join(format!(".{key}.plsi.tmp"));
        let write = || -> std::io::Result<()> {
            {
                use std::io::Write;
                let mut f = std::fs::File::create(&tmp)?;
                f.write_all(bytes)?;
                f.sync_all()?;
            }
            std::fs::rename(&tmp, &path)
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow::Error::new(e).context(format!(
                "writing store entry {}",
                path.display()
            )));
        }
        let mut inner = self.inner.lock().unwrap();
        inner.keys.insert(key.to_string());
        inner.stats.puts += 1;
        inner.stats.bytes_written += bytes.len() as u64;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        {
            let inner = self.inner.lock().unwrap();
            if !inner.keys.contains(key) {
                bail!("no store entry under {key:?}");
            }
        }
        let path = self.path_for(key);
        let bytes = std::fs::read(&path).with_context(|| {
            format!("reading store entry {}", path.display())
        })?;
        self.inner.lock().unwrap().stats.gets += 1;
        Ok(bytes)
    }

    fn remove(&self, key: &str) -> Result<bool> {
        let existed = {
            let mut inner = self.inner.lock().unwrap();
            let existed = inner.keys.remove(key);
            if existed {
                inner.stats.removes += 1;
            }
            existed
        };
        if existed {
            let _ = std::fs::remove_file(self.path_for(key));
        }
        Ok(existed)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().keys.contains(key)
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().keys.len()
    }

    fn iter_keys(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys.iter().cloned().collect()
    }

    fn stats(&self) -> EngineStats {
        self.inner.lock().unwrap().stats
    }

    fn disk_bytes(&self) -> u64 {
        let keys = self.iter_keys();
        keys.iter()
            .filter_map(|k| {
                std::fs::metadata(self.path_for(k)).ok().map(|m| m.len())
            })
            .sum()
    }

    fn file_count(&self) -> u64 {
        self.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pocketllm_engine_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn dir_engine_roundtrip_and_counters() {
        let e = DirEngine::open(tmp("rt")).unwrap();
        assert!(e.is_empty());
        e.put("a", b"hello").unwrap();
        e.put("b", b"world!").unwrap();
        assert_eq!(e.len(), 2);
        assert_eq!(e.iter_keys(), vec!["a", "b"]);
        assert_eq!(e.get("a").unwrap(), b"hello");
        assert!(e.contains("a"), "get must not consume");
        assert_eq!(e.take("a").unwrap(), b"hello");
        assert!(!e.contains("a"));
        assert!(e.get("a").is_err());
        let s = e.stats();
        assert_eq!((s.puts, s.gets, s.removes), (2, 2, 1));
        assert_eq!(s.bytes_written, 11);
        assert_eq!(e.file_count(), 1);
        assert_eq!(e.disk_bytes(), 6);
    }

    #[test]
    fn dir_engine_put_replaces_atomically_by_rename() {
        let dir = tmp("replace");
        let e = DirEngine::open(&dir).unwrap();
        e.put("k", b"old").unwrap();
        e.put("k", b"new-bytes").unwrap();
        assert_eq!(e.get("k").unwrap(), b"new-bytes");
        assert_eq!(e.len(), 1);
        // no temp litter after successful writes
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|d| d.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names, vec!["k.plsi"]);
    }

    #[test]
    fn dir_engine_discovers_surviving_keys_on_open() {
        let dir = tmp("discover");
        {
            let e = DirEngine::open(&dir).unwrap();
            e.put("job0", b"x").unwrap();
            e.put("job1", b"y").unwrap();
        }
        // a fresh open (new process, after a crash) sees both keys
        let e = DirEngine::open(&dir).unwrap();
        assert_eq!(e.iter_keys(), vec!["job0", "job1"]);
        assert_eq!(e.get("job1").unwrap(), b"y");
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!(EngineKind::parse("dir").unwrap(), EngineKind::Dir);
        assert_eq!(EngineKind::parse("paged").unwrap(),
                   EngineKind::Paged);
        assert!(EngineKind::parse("lsm").is_err());
        assert_eq!(EngineKind::Paged.label(), "paged");
    }
}
