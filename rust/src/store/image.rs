//! The durable session image: one file, one session.
//!
//! ## Format (version 3, little-endian throughout)
//!
//! ```text
//!   magic        4 B   b"PLSI"
//!   version      u32   3 (v1 files — no recovery record — and v2
//!                      files — no link/mode fields — still load)
//!   optimizer    u8    0 = mezo, 1 = adam
//!   precision    u8    Precision::code (0 f32, 1 f16, 2 int8)
//!   flags        u8    bit0 = Adam m/v moment payload present
//!                      bit1 = fleet recovery record present (v2)
//!   reserved     u8    0
//!   config       u32 len + UTF-8 bytes (manifest config name)
//!   task         u32 len + UTF-8 bytes (TaskKind label)
//!   step         u64   completed optimization steps
//!   master_seed  u64   MeZO seed-schedule master (0 for Adam)
//!   data_seed    u64   session seed driving the data pipeline
//!   batcher_pos  u64   batches consumed from the deterministic stream
//!   last_loss    u64   f64 bits (NaN when unknown)
//!   batch        u32   batch size the step program was compiled for
//!   n_tensors    u32   parameter tensor count
//!   directory    n_tensors x { dtype u8 (Precision::code), elems u64 }
//!   payload      parameter records, each Literal::to_le_bytes —
//!                tensors are stored AT THEIR RESIDENT PRECISION
//!                (2 B/elem f16, 1 B/elem + 4 B scale int8); then,
//!                iff flags bit0, the Adam m and v records (f32)
//!   recovery     iff flags bit1, 117 B: job_idx u32, status u8
//!                (0 live, 1 completed, 2 stalled, 3 failed), then 8
//!                u64-width fields — steps_target, deadline_minutes
//!                (f64 bits, NaN = none), window_idx, windows_used,
//!                windows_denied, sim_step_seconds (f64 bits),
//!                job_last_loss (f64 bits), thermal_sustained_s (f64
//!                bits) — and (v3) 6 more u64-width fields for split
//!                tuning: link_pos, windows_split, windows_deferred,
//!                link_drops, link_bytes, link_wh (f64 bits).  A v2
//!                record is the same layout truncated after
//!                thermal_sustained_s (69 B); the link/mode fields
//!                decode as zero.  Everything `FleetScheduler::recover`
//!                needs to rebuild the job's scheduler state bit-exactly
//!   crc32        u32   CRC-32/IEEE over every preceding byte
//! ```
//!
//! Shapes are not stored: tensors travel flat and are re-attached to
//! the manifest's parameter specs at load ([`ExecState::from_storage`]
//! (crate::runtime::ExecState::from_storage) validates element
//! counts).  That keeps a MeZO image at params + ~100 bytes + 9 bytes
//! per tensor of metadata — the paper's Table-1 asymmetry, durable.
//!
//! Every load verifies magic, version, and CRC before parsing; a
//! truncated or bit-flipped file is an error, never a garbled session.

use anyhow::{bail, ensure, Context, Result};

use crate::data::task::TaskKind;
use crate::optim::OptimizerKind;
use crate::runtime::literal::Literal;
use crate::runtime::precision::Precision;

use super::crc32;

pub const MAGIC: &[u8; 4] = b"PLSI";
pub const VERSION: u32 = 3;
/// Oldest version this build still reads (v1 = no recovery record,
/// v2 = no link/mode fields in the record).
pub const MIN_VERSION: u32 = 1;

const FLAG_ADAM: u8 = 1;
const FLAG_RECOVERY: u8 = 2;
/// Encoded size of a v3 [`RecoveryRecord`] (a v2 record is 48 bytes
/// shorter: the same layout truncated after `thermal_sustained_s`).
const RECOVERY_BYTES: u64 = 4 + 1 + 8 * 14;

/// How the job stood when its image was written.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryStatus {
    /// Mid-run: hibernated between windows, work remaining.
    Live,
    Completed,
    Stalled,
    Failed,
}

impl RecoveryStatus {
    fn code(self) -> u8 {
        match self {
            RecoveryStatus::Live => 0,
            RecoveryStatus::Completed => 1,
            RecoveryStatus::Stalled => 2,
            RecoveryStatus::Failed => 3,
        }
    }

    fn from_code(c: u8) -> Option<RecoveryStatus> {
        match c {
            0 => Some(RecoveryStatus::Live),
            1 => Some(RecoveryStatus::Completed),
            2 => Some(RecoveryStatus::Stalled),
            3 => Some(RecoveryStatus::Failed),
            _ => None,
        }
    }
}

/// The fleet-scheduler state a session image carries beyond the
/// session itself: which job it is, how far its window clock ran, and
/// the device thermal debt — everything `FleetScheduler::recover`
/// needs to rebuild the job's `JobRun` bit-exactly.  `Session` state
/// (parameters, seeds, batcher position) lives in the image proper.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryRecord {
    pub job_idx: u32,
    pub status: RecoveryStatus,
    /// Total steps the job was asked to run (`JobSpec::steps`).
    pub steps_target: u64,
    /// `JobSpec::deadline_minutes`; NaN encodes "no deadline".
    pub deadline_minutes: f64,
    /// Trace windows consumed (admitted or denied).
    pub window_idx: u64,
    /// Windows in which the job actually stepped.
    pub windows_used: u64,
    /// Windows denied by policy.
    pub windows_denied: u64,
    /// Accumulated simulated step-seconds (exact f64 partial sum —
    /// resuming from it keeps later additions bit-identical).
    pub sim_step_seconds: f64,
    /// The job-level last loss (NaN before the first step).
    pub job_last_loss: f64,
    /// The device's sustained-thermal clock at hibernation, in
    /// seconds — the ONLY mutable device state that affects outcomes.
    pub thermal_sustained_s: f64,
    /// Link-trace windows consumed (one per policy-admitted window;
    /// see `coordinator::JobRun`).  Zero when decoded from v2 images.
    pub link_pos: u64,
    /// Admitted windows that ran in split mode.
    pub windows_split: u64,
    /// Admitted windows the mode policy spent deferring.
    pub windows_deferred: u64,
    /// Mid-flight link drops (each fell back to a local window).
    pub link_drops: u64,
    /// Payload bytes moved over the simulated link so far.
    pub link_bytes: u64,
    /// Radio energy charged for those bytes (Wh) — an exact f64
    /// partial sum, like `sim_step_seconds`.
    pub link_wh: f64,
}

impl RecoveryRecord {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.job_idx.to_le_bytes());
        out.push(self.status.code());
        for v in [
            self.steps_target,
            self.deadline_minutes.to_bits(),
            self.window_idx,
            self.windows_used,
            self.windows_denied,
            self.sim_step_seconds.to_bits(),
            self.job_last_loss.to_bits(),
            self.thermal_sustained_s.to_bits(),
            self.link_pos,
            self.windows_split,
            self.windows_deferred,
            self.link_drops,
            self.link_bytes,
            self.link_wh.to_bits(),
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_from(
        r: &mut Reader<'_>,
        version: u32,
    ) -> Result<RecoveryRecord> {
        let job_idx = r.u32()?;
        let status = RecoveryStatus::from_code(r.u8()?)
            .context("unknown recovery status code")?;
        let mut rec = RecoveryRecord {
            job_idx,
            status,
            steps_target: r.u64()?,
            deadline_minutes: f64::from_bits(r.u64()?),
            window_idx: r.u64()?,
            windows_used: r.u64()?,
            windows_denied: r.u64()?,
            sim_step_seconds: f64::from_bits(r.u64()?),
            job_last_loss: f64::from_bits(r.u64()?),
            thermal_sustained_s: f64::from_bits(r.u64()?),
            link_pos: 0,
            windows_split: 0,
            windows_deferred: 0,
            link_drops: 0,
            link_bytes: 0,
            link_wh: 0.0,
        };
        // v2 records stop here: a pre-split fleet never consulted the
        // link, so zeroed counters ARE its exact state
        if version >= 3 {
            rec.link_pos = r.u64()?;
            rec.windows_split = r.u64()?;
            rec.windows_deferred = r.u64()?;
            rec.link_drops = r.u64()?;
            rec.link_bytes = r.u64()?;
            rec.link_wh = f64::from_bits(r.u64()?);
        }
        Ok(rec)
    }
}

/// A decoded session image — everything durable about one session.
/// The non-durable rest (compiled programs, shared data artifacts,
/// the simulated device clock) lives in
/// [`HibernatedSession`](crate::tuner::session::HibernatedSession) or
/// is rebuilt from the manifest.
#[derive(Debug, Clone)]
pub struct SessionImage {
    pub config: String,
    pub optimizer: OptimizerKind,
    /// Storage precision of the parameter records.
    pub precision: Precision,
    pub task: TaskKind,
    pub step: u64,
    /// MeZO seed-schedule master seed (0 for Adam images).
    pub master_seed: u64,
    /// The session seed that drives the data pipeline.
    pub data_seed: u64,
    /// Batches consumed from the deterministic batch stream (the
    /// entire durable batcher state — `Batcher::skip` rebuilds the
    /// resume snapshot from it).
    pub batcher_pos: u64,
    pub last_loss: f64,
    pub batch: u32,
    /// Parameter tensors at their resident precision, manifest order.
    pub params: Vec<Literal>,
    /// Adam first moments (f32); empty for derivative-free images.
    pub adam_m: Vec<Vec<f32>>,
    /// Adam second moments (f32); empty for derivative-free images.
    pub adam_v: Vec<Vec<f32>>,
    /// Fleet-scheduler recovery state (v2 images written by the
    /// fleet; `None` for plain checkpoints and v1 files).
    pub recovery: Option<RecoveryRecord>,
}

fn optimizer_code(o: OptimizerKind) -> u8 {
    match o {
        OptimizerKind::MeZo => 0,
        OptimizerKind::Adam => 1,
    }
}

fn optimizer_from_code(c: u8) -> Option<OptimizerKind> {
    match c {
        0 => Some(OptimizerKind::MeZo),
        1 => Some(OptimizerKind::Adam),
        _ => None,
    }
}

impl SessionImage {
    /// Bytes the parameter payload occupies (on disk and resident —
    /// the storage form is the same): the "no f32 materialization"
    /// guarantee in number form.
    pub fn param_bytes(&self) -> u64 {
        self.params.iter().map(|l| l.storage_len()).sum()
    }

    /// Bytes the Adam moment payload occupies (always f32; 0 for
    /// MeZO images — the paper's asymmetry).
    pub fn moment_bytes(&self) -> u64 {
        let elems: usize = self
            .adam_m
            .iter()
            .chain(self.adam_v.iter())
            .map(|t| t.len())
            .sum();
        4 * elems as u64
    }

    /// Structural sanity of the image: the optimizer and the moment
    /// payload must agree (an Adam image carries m AND v, one per
    /// parameter tensor; a MeZO image carries none).  Both write
    /// paths ([`Checkpoint::save`](crate::tuner::Checkpoint::save)
    /// and [`SessionStore::put`](super::SessionStore::put)) call this
    /// so a malformed image fails at the writer, not at a much later
    /// restore.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.adam_m.len() == self.adam_v.len(),
                "adam moments disagree: {} m vs {} v tensors",
                self.adam_m.len(), self.adam_v.len());
        match self.optimizer {
            OptimizerKind::Adam => ensure!(
                self.adam_m.len() == self.params.len(),
                "adam image needs one m/v pair per tensor (got {} \
                 for {} tensors)",
                self.adam_m.len(),
                self.params.len()
            ),
            OptimizerKind::MeZo => ensure!(
                self.adam_m.is_empty(),
                "mezo image must not carry optimizer moments"
            ),
        }
        Ok(())
    }

    /// Header + directory + CRC overhead for this image.
    pub fn metadata_bytes(&self) -> u64 {
        // magic+version(8) + codes(4) + 2 length-prefixed strings +
        // 5 u64 counters(40) + batch+n_tensors(8) + 9 B/tensor dir +
        // trailing crc(4)
        8 + 4
            + (4 + self.config.len() as u64)
            + (4 + self.task.label().len() as u64)
            + 40
            + 8
            + 9 * self.params.len() as u64
            + if self.recovery.is_some() { RECOVERY_BYTES } else { 0 }
            + 4
    }

    /// Serialize (the exact layout documented at module level).
    pub fn encode(&self) -> Vec<u8> {
        let cap = (self.metadata_bytes() + self.param_bytes()
            + self.moment_bytes()) as usize;
        let mut out = Vec::with_capacity(cap);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(optimizer_code(self.optimizer));
        out.push(self.precision.code());
        let has_adam = !self.adam_m.is_empty();
        let mut flags = 0u8;
        if has_adam {
            flags |= FLAG_ADAM;
        }
        if self.recovery.is_some() {
            flags |= FLAG_RECOVERY;
        }
        out.push(flags);
        out.push(0); // reserved
        for s in [self.config.as_str(), self.task.label()] {
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        for v in [self.step, self.master_seed, self.data_seed,
                  self.batcher_pos, self.last_loss.to_bits()]
        {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.batch.to_le_bytes());
        out.extend_from_slice(
            &(self.params.len() as u32).to_le_bytes(),
        );
        for p in &self.params {
            out.push(self.precision.code());
            out.extend_from_slice(
                &(p.element_count() as u64).to_le_bytes(),
            );
        }
        for p in &self.params {
            out.extend_from_slice(&p.to_le_bytes());
        }
        if has_adam {
            for set in [&self.adam_m, &self.adam_v] {
                for t in set.iter() {
                    for x in t {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        if let Some(rec) = &self.recovery {
            rec.encode_into(&mut out);
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse + verify an image.  Magic, version, and CRC are checked
    /// before any payload is trusted; truncation at any point is an
    /// error.
    pub fn decode(bytes: &[u8]) -> Result<SessionImage> {
        ensure!(bytes.len() >= 12,
                "session image truncated ({} bytes)", bytes.len());
        ensure!(&bytes[0..4] == MAGIC,
                "not a session image (bad magic)");
        let version = u32::from_le_bytes([
            bytes[4], bytes[5], bytes[6], bytes[7],
        ]);
        ensure!((MIN_VERSION..=VERSION).contains(&version),
                "session image version {version} (this build reads \
                 {MIN_VERSION}..={VERSION})");
        let body = &bytes[..bytes.len() - 4];
        let stored = u32::from_le_bytes([
            bytes[bytes.len() - 4],
            bytes[bytes.len() - 3],
            bytes[bytes.len() - 2],
            bytes[bytes.len() - 1],
        ]);
        let actual = crc32(body);
        ensure!(stored == actual,
                "session image corrupt: CRC {stored:#010x} on disk, \
                 {actual:#010x} computed");

        let mut r = Reader { buf: body, pos: 8 };
        let optimizer = optimizer_from_code(r.u8()?)
            .context("unknown optimizer code")?;
        let precision = Precision::from_code(r.u8()?)
            .context("unknown precision code")?;
        let flags = r.u8()?;
        let _reserved = r.u8()?;
        // the moment payload and the optimizer must agree: a MeZO
        // image with a moment payload is a writer bug, not something
        // to round-trip quietly.  (The other direction — an Adam
        // image without moments — is checked after the directory is
        // read: it is legal only for the zero-tensor terminal stubs
        // the fleet recovery path writes.)
        ensure!(flags & FLAG_ADAM == 0
                    || optimizer == OptimizerKind::Adam,
                "image optimizer {} disagrees with its moment payload",
                optimizer.label());
        ensure!(version >= 2 || flags & FLAG_RECOVERY == 0,
                "v1 session image claims a recovery record (flag from \
                 a later version)");
        let config = r.string()?;
        let task_label = r.string()?;
        let task = TaskKind::parse(&task_label).with_context(|| {
            format!("unknown task '{task_label}' in session image")
        })?;
        let step = r.u64()?;
        let master_seed = r.u64()?;
        let data_seed = r.u64()?;
        let batcher_pos = r.u64()?;
        let last_loss = f64::from_bits(r.u64()?);
        let batch = r.u32()?;
        let n_tensors = r.u32()? as usize;
        ensure!(n_tensors <= 1 << 20,
                "implausible tensor count {n_tensors}");
        ensure!(flags & FLAG_ADAM != 0
                    || optimizer != OptimizerKind::Adam
                    || n_tensors == 0,
                "adam session image carries no moment payload");
        let mut dir = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let dt = Precision::from_code(r.u8()?)
                .context("unknown tensor dtype code")?;
            ensure!(dt == precision,
                    "tensor stored as {dt}, image tagged {precision}");
            let elems = r.u64()?;
            // every element costs >= 1 payload byte, so a valid count
            // can never exceed the file size — this also keeps the
            // payload-size arithmetic below far from overflow
            ensure!(elems <= body.len() as u64,
                    "implausible tensor size {elems} in a {}-byte \
                     image",
                    body.len());
            dir.push(elems as usize);
        }
        let mut params = Vec::with_capacity(n_tensors);
        for &elems in &dir {
            let lit = if precision == Precision::Int8Pc {
                // per-channel payloads are self-describing
                // ([u32 n_scales][scales][codes]): read the scale
                // count to size the read, then hand the reassembled
                // payload to the literal parser
                let ns = r.u32()? as usize;
                ensure!(4 * ns as u64 <= body.len() as u64,
                        "implausible scale count {ns} in a {}-byte \
                         image",
                        body.len());
                let rest = r.bytes(4 * ns + elems)?;
                let mut buf = Vec::with_capacity(4 + rest.len());
                buf.extend_from_slice(&(ns as u32).to_le_bytes());
                buf.extend_from_slice(rest);
                Literal::from_storage_bytes(precision, vec![elems],
                                            &buf)?
            } else {
                let len = precision.storage_bytes(elems) as usize;
                let payload = r.bytes(len)?;
                Literal::from_storage_bytes(precision, vec![elems],
                                            payload)?
            };
            params.push(lit);
        }
        fn read_moments(
            r: &mut Reader<'_>,
            dir: &[usize],
        ) -> Result<Vec<Vec<f32>>> {
            let mut set = Vec::with_capacity(dir.len());
            for &elems in dir {
                let raw = r.bytes(4 * elems)?;
                let t: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| {
                        f32::from_le_bytes([c[0], c[1], c[2], c[3]])
                    })
                    .collect();
                set.push(t);
            }
            Ok(set)
        }
        let (adam_m, adam_v) = if flags & FLAG_ADAM != 0 {
            let m = read_moments(&mut r, &dir)?;
            let v = read_moments(&mut r, &dir)?;
            (m, v)
        } else {
            (Vec::new(), Vec::new())
        };
        let recovery = if flags & FLAG_RECOVERY != 0 {
            Some(RecoveryRecord::decode_from(&mut r, version)
                .context("reading recovery record")?)
        } else {
            None
        };
        ensure!(r.pos == body.len(),
                "session image has {} trailing bytes",
                body.len() - r.pos);
        Ok(SessionImage {
            config,
            optimizer,
            precision,
            task,
            step,
            master_seed,
            data_seed,
            batcher_pos,
            last_loss,
            batch,
            params,
            adam_m,
            adam_v,
            recovery,
        })
    }
}

/// Bounds-checked little-endian cursor (shared with the fleet
/// manifest decoder in [`crate::coordinator::fleet`]).
pub(crate) struct Reader<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.pos {
            bail!("session image truncated at byte {}", self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub(crate) fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        ensure!(len <= 4096, "implausible string length {len}");
        let b = self.bytes(len)?;
        Ok(String::from_utf8(b.to_vec())
            .map_err(|_| anyhow::anyhow!("non-UTF-8 string in image"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(precision: Precision, adam: bool) -> SessionImage {
        let data = [0.51f32, -1.25, 0.0, 0.125, 3.7, -0.002];
        let params = vec![
            Literal::quantize_from_f32(&data, &[6], precision).unwrap(),
            Literal::quantize_from_f32(&data[..4], &[4], precision)
                .unwrap(),
        ];
        let (adam_m, adam_v) = if adam {
            (
                vec![vec![0.1f32; 6], vec![0.2f32; 4]],
                vec![vec![0.3f32; 6], vec![0.4f32; 4]],
            )
        } else {
            (Vec::new(), Vec::new())
        };
        SessionImage {
            config: "pocket-tiny".into(),
            optimizer: if adam {
                OptimizerKind::Adam
            } else {
                OptimizerKind::MeZo
            },
            precision,
            task: TaskKind::Sst2,
            step: (1u64 << 53) + 3,
            master_seed: u64::MAX - 1,
            data_seed: 42,
            batcher_pos: 17,
            last_loss: 0.625,
            batch: 4,
            params,
            adam_m,
            adam_v,
            recovery: None,
        }
    }

    #[test]
    fn roundtrip_every_precision() {
        for p in Precision::ALL {
            let img = sample(p, false);
            let bytes = img.encode();
            let back = SessionImage::decode(&bytes).unwrap();
            assert_eq!(back.config, "pocket-tiny");
            assert_eq!(back.optimizer, OptimizerKind::MeZo);
            assert_eq!(back.precision, p);
            assert_eq!(back.task, TaskKind::Sst2);
            assert_eq!(back.step, (1u64 << 53) + 3, "u64 exact");
            assert_eq!(back.master_seed, u64::MAX - 1, "u64 exact");
            assert_eq!(back.batcher_pos, 17);
            assert_eq!(back.last_loss, 0.625);
            assert_eq!(back.batch, 4);
            // tensor payloads are byte-identical at storage precision
            for (a, b) in img.params.iter().zip(&back.params) {
                assert_eq!(a.to_le_bytes(), b.to_le_bytes(), "{p}");
                assert_eq!(b.storage_precision(), Some(p));
            }
            assert!(back.adam_m.is_empty());
        }
    }

    #[test]
    fn adam_image_carries_moments_mezo_image_does_not() {
        let adam = sample(Precision::F32, true);
        let bytes = adam.encode();
        let back = SessionImage::decode(&bytes).unwrap();
        assert_eq!(back.adam_m, adam.adam_m);
        assert_eq!(back.adam_v, adam.adam_v);
        assert_eq!(adam.moment_bytes(), 2 * 10 * 4);
        // the Table-1 asymmetry on disk: adam ~= 3x params + metadata
        let mezo = sample(Precision::F32, false);
        assert_eq!(mezo.moment_bytes(), 0);
        assert_eq!(bytes.len() as u64,
                   adam.param_bytes() + adam.moment_bytes()
                       + adam.metadata_bytes());
        assert_eq!(mezo.encode().len() as u64,
                   mezo.param_bytes() + mezo.metadata_bytes());
    }

    #[test]
    fn quantized_images_store_reduced_bytes_on_disk() {
        // 10 elements across 2 tensors: f32 40 B, f16 20 B,
        // int8 10 B + 2 scales
        let f32b = sample(Precision::F32, false).param_bytes();
        let f16b = sample(Precision::F16, false).param_bytes();
        let i8b = sample(Precision::Int8, false).param_bytes();
        assert_eq!(f32b, 40);
        assert_eq!(f16b, 20, "f16 must be 2 B/element on disk");
        assert_eq!(i8b, 10 + 8, "int8 must be 1 B/element + scales");
        // and the file sizes differ by exactly the payload difference
        let lf32 = sample(Precision::F32, false).encode().len() as u64;
        let lf16 = sample(Precision::F16, false).encode().len() as u64;
        assert_eq!(lf32 - lf16, f32b - f16b);
    }

    #[test]
    fn mezo_metadata_is_small() {
        // the durable MeZO optimizer state is (master_seed, step) plus
        // framing: metadata must stay ~100 bytes + 9 B/tensor
        let img = sample(Precision::F32, false);
        let meta = img.encode().len() as u64 - img.param_bytes();
        assert_eq!(meta, img.metadata_bytes());
        assert!(meta <= 100 + 9 * img.params.len() as u64,
                "metadata {meta} B");
    }

    #[test]
    fn corrupt_and_truncated_images_are_rejected() {
        let bytes = sample(Precision::F16, false).encode();
        // pristine decodes
        SessionImage::decode(&bytes).unwrap();
        // every single-byte corruption is caught by the CRC (or the
        // magic/version gate)
        for pos in [0usize, 5, 9, 20, bytes.len() / 2, bytes.len() - 1]
        {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            let err = SessionImage::decode(&bad)
                .expect_err("corruption undetected");
            let msg = format!("{err:#}");
            assert!(msg.contains("CRC") || msg.contains("magic")
                        || msg.contains("version"),
                    "byte {pos}: {msg}");
        }
        // truncation anywhere is an error
        for cut in [0usize, 3, 11, 20, bytes.len() - 5, bytes.len() - 1]
        {
            assert!(SessionImage::decode(&bytes[..cut]).is_err(),
                    "truncation to {cut} bytes undetected");
        }
        // a file of garbage is not an image
        assert!(SessionImage::decode(&[0u8; 64]).is_err());
    }

    #[test]
    fn validate_pins_the_optimizer_moment_invariant() {
        assert!(sample(Precision::F32, false).validate().is_ok());
        assert!(sample(Precision::F32, true).validate().is_ok());
        let mut adam = sample(Precision::F32, true);
        adam.adam_v.pop();
        assert!(adam.validate().is_err(), "lopsided m/v");
        let mut adam = sample(Precision::F32, true);
        adam.adam_m.clear();
        adam.adam_v.clear();
        assert!(adam.validate().is_err(), "adam without moments");
        let mut mezo = sample(Precision::F32, false);
        mezo.adam_m = vec![vec![0.0; 6], vec![0.0; 4]];
        mezo.adam_v = mezo.adam_m.clone();
        assert!(mezo.validate().is_err(), "mezo with moments");
    }

    #[test]
    fn decoded_flags_must_match_the_optimizer() {
        // a hand-built MeZO image that smuggles a moment payload (the
        // encoder keys flags off adam_m) must be rejected at decode
        let mut img = sample(Precision::F32, true);
        img.optimizer = OptimizerKind::MeZo;
        let bytes = img.encode();
        let err = SessionImage::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("disagrees"), "{err:#}");
    }

    #[test]
    fn implausible_tensor_sizes_error_instead_of_panicking() {
        // craft a CRC-valid image whose directory claims a huge
        // tensor: decode must return an error, never overflow/panic
        let mut bytes = sample(Precision::Int8, false).encode();
        let body_len = bytes.len() - 4;
        // the first directory entry's elems u64 sits right after the
        // fixed header + two strings + counters + batch + n_tensors +
        // 1-byte dtype; locate it structurally instead of hardcoding
        let dir_off = 8 + 4 + (4 + "pocket-tiny".len())
            + (4 + "sst2".len()) + 40 + 8 + 1;
        bytes[dir_off..dir_off + 8]
            .copy_from_slice(&(u64::MAX - 1).to_le_bytes());
        let crc = crate::store::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        let err = SessionImage::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("implausible"), "{err:#}");
    }

    #[test]
    fn unknown_version_is_rejected_not_misparsed() {
        let mut bytes = sample(Precision::F32, false).encode();
        bytes[4] = 4; // version 4: from the future
        let err = SessionImage::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
        let mut bytes = sample(Precision::F32, false).encode();
        bytes[4] = 0; // version 0: never existed
        let err = SessionImage::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
    }

    #[test]
    fn v1_images_without_recovery_still_load() {
        // a v1 file is byte-identical to a v2 file with no recovery
        // record, except for the version word — emulate one and prove
        // the forward-compat path
        let img = sample(Precision::F16, true);
        let mut bytes = img.encode();
        bytes[4] = 1;
        let body_len = bytes.len() - 4;
        let crc = crate::store::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        let back = SessionImage::decode(&bytes).unwrap();
        assert!(back.recovery.is_none());
        assert_eq!(back.step, img.step);
        assert_eq!(back.adam_m, img.adam_m);
        // but a v1 file CLAIMING a recovery record is corrupt
        let mut bad = bytes.clone();
        bad[10] |= 2; // FLAG_RECOVERY
        let crc = crate::store::crc32(&bad[..body_len]);
        bad[body_len..].copy_from_slice(&crc.to_le_bytes());
        let err = SessionImage::decode(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("recovery"), "{err:#}");
    }

    #[test]
    fn recovery_record_roundtrips_bit_exactly() {
        let mut img = sample(Precision::Int8, false);
        img.recovery = Some(RecoveryRecord {
            job_idx: 7,
            status: RecoveryStatus::Live,
            steps_target: 4096,
            deadline_minutes: 90.5,
            window_idx: 13,
            windows_used: 9,
            windows_denied: 4,
            sim_step_seconds: 123.456789,
            job_last_loss: 0.03125,
            thermal_sustained_s: 55.25,
            link_pos: 11,
            windows_split: 5,
            windows_deferred: 3,
            link_drops: 2,
            link_bytes: 987_654,
            link_wh: 0.0123456789,
        });
        let bytes = img.encode();
        assert_eq!(bytes.len() as u64,
                   img.param_bytes() + img.metadata_bytes(),
                   "metadata accounting must include the record");
        let back = SessionImage::decode(&bytes).unwrap();
        let rec = back.recovery.expect("record must survive");
        assert_eq!(rec, img.recovery.unwrap());
        // NaN deadline = "no deadline" must roundtrip too (NaN != NaN,
        // so compare bits)
        let mut img = sample(Precision::F32, false);
        img.recovery = Some(RecoveryRecord {
            job_idx: 0,
            status: RecoveryStatus::Completed,
            steps_target: 1,
            deadline_minutes: f64::NAN,
            window_idx: 0,
            windows_used: 0,
            windows_denied: 0,
            sim_step_seconds: 0.0,
            job_last_loss: f64::NAN,
            thermal_sustained_s: 0.0,
            link_pos: 0,
            windows_split: 0,
            windows_deferred: 0,
            link_drops: 0,
            link_bytes: 0,
            link_wh: 0.0,
        });
        let back = SessionImage::decode(&img.encode()).unwrap();
        let rec = back.recovery.unwrap();
        assert!(rec.deadline_minutes.is_nan());
        assert_eq!(rec.status, RecoveryStatus::Completed);
    }

    #[test]
    fn v2_recovery_records_decode_with_zeroed_link_fields() {
        // a v2 record is the v3 layout truncated after
        // thermal_sustained_s: emulate one by stripping the trailing
        // 48 link/mode bytes and rewinding the version word
        let mut img = sample(Precision::F32, false);
        img.recovery = Some(RecoveryRecord {
            job_idx: 3,
            status: RecoveryStatus::Live,
            steps_target: 64,
            deadline_minutes: 45.0,
            window_idx: 6,
            windows_used: 4,
            windows_denied: 2,
            sim_step_seconds: 77.5,
            job_last_loss: 1.5,
            thermal_sustained_s: 10.0,
            link_pos: 99,
            windows_split: 9,
            windows_deferred: 9,
            link_drops: 9,
            link_bytes: 9,
            link_wh: 9.0,
        });
        let mut bytes = img.encode();
        bytes[4] = 2;
        let cut = bytes.len() - 4 - 48;
        bytes.truncate(cut);
        let crc = crate::store::crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        let back = SessionImage::decode(&bytes).unwrap();
        let rec = back.recovery.expect("v2 record must still load");
        // the pre-split fields survive verbatim...
        assert_eq!(rec.job_idx, 3);
        assert_eq!(rec.status, RecoveryStatus::Live);
        assert_eq!(rec.window_idx, 6);
        assert_eq!(rec.sim_step_seconds, 77.5);
        assert_eq!(rec.thermal_sustained_s, 10.0);
        // ...and the link/mode fields decode as zero (a pre-split
        // fleet never touched the link, so zero IS its exact state)
        assert_eq!(rec.link_pos, 0);
        assert_eq!(rec.windows_split, 0);
        assert_eq!(rec.windows_deferred, 0);
        assert_eq!(rec.link_drops, 0);
        assert_eq!(rec.link_bytes, 0);
        assert_eq!(rec.link_wh, 0.0);
    }
}
